"""Layer 2 — GPT-2-style transformer graphs in JAX, calling the Pallas
kernels from ``kernels/``.

These functions are the *author-time* definition of the model compute that
the rust coordinator executes at runtime via PJRT. ``aot.py`` lowers them
at canonical shapes to HLO text in ``artifacts/``.

Conventions shared with the rust side (rust/src/model, rust/src/runtime):

  * All compute is f32 ("FP16" in the paper is a storage format; byte
    accounting uses 2 B/element — see DESIGN.md).
  * Attention caches are laid out (H, L, d_k); PQ codes (H, L, m) int32
    (uint8 in rust storage, widened at the PJRT boundary); codebooks
    (H, m, K, d_sub).
  * The cache validity mask is (L,) f32, 1.0 = valid slot.
  * Decode-step block graphs attend over {cache ∪ current token}: the
    current token's K/V never round-trips through the cache inside the
    graph; rust appends (and PQ-encodes) it afterwards.
  * Per-block parameter order (must match rust/src/model/weights.rs):
      ln1_g, ln1_b, w_qkv (d_model, 3·d_model), b_qkv,
      w_proj (d_model, d_model), b_proj, ln2_g, ln2_b,
      w_fc (d_model, d_ff), b_fc, w_out (d_ff, d_model), b_out
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import lookat as kern
from .kernels import ref

# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------


def layernorm(x, g, b, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu(x):
    """GPT-2's tanh-approximation GELU."""
    return 0.5 * x * (1.0 + jnp.tanh(
        0.7978845608028654 * (x + 0.044715 * x * x * x)))


# ---------------------------------------------------------------------------
# Attention-step graphs (the serving hot path artifacts)
# ---------------------------------------------------------------------------


def attn_step_fp16(q, k, v, mask):
    """Multi-head exact-attention decode step (FP16-storage baseline).

    q (H, d_k), k/v (H, L, d_k), mask (L,) -> (H, d_k).
    """
    return kern.exact_attention_mh(q, k, v, mask)


def attn_step_lookat(q, codes, codebooks, v, mask):
    """Multi-head LOOKAT decode step: ADC scores over PQ codes.

    q (H, d_k), codes (H, L, m) int32, codebooks (H, m, K, d_sub),
    v (H, L, d_k), mask (L,) -> (H, d_k).
    """
    return kern.lookat_attention_mh(q, codes, codebooks, v, mask)


# ---------------------------------------------------------------------------
# Transformer-block decode graphs
# ---------------------------------------------------------------------------


def _qkv(x, ln1_g, ln1_b, w_qkv, b_qkv, n_head, d_head):
    """LN + fused QKV projection for a single token. -> 3 × (H, d_k)"""
    h = layernorm(x, ln1_g, ln1_b)
    qkv = h @ w_qkv + b_qkv                        # (3·d_model,)
    d_model = n_head * d_head
    q = qkv[:d_model].reshape(n_head, d_head)
    k = qkv[d_model:2 * d_model].reshape(n_head, d_head)
    v = qkv[2 * d_model:].reshape(n_head, d_head)
    return q, k, v


def _attend_with_self(scores_cache, self_score, mask, v_cache, v_self, d_k):
    """Softmax over {cache scores, self score} and reduce values.

    scores_cache (H, L) unscaled, self_score (H,) unscaled, mask (L,),
    v_cache (H, L, d_k), v_self (H, d_k) -> (H, d_k).
    """
    inv = 1.0 / jnp.sqrt(jnp.asarray(d_k, jnp.float32))
    s = jnp.where(mask[None, :] > 0, scores_cache * inv, ref.NEG_INF)
    ss = self_score[:, None] * inv                          # (H, 1)
    full = jnp.concatenate([s, ss], axis=1)                 # (H, L+1)
    mx = jnp.max(full, axis=1, keepdims=True)
    e = jnp.exp(full - mx)
    denom = jnp.sum(e, axis=1, keepdims=True)
    a = e / denom                                           # (H, L+1)
    out = jnp.einsum("hl,hld->hd", a[:, :-1], v_cache)
    out = out + a[:, -1:] * v_self
    return out


def block_decode_fp16(x, k_cache, v_cache, mask,
                      ln1_g, ln1_b, w_qkv, b_qkv, w_proj, b_proj,
                      ln2_g, ln2_b, w_fc, b_fc, w_out, b_out,
                      *, n_head, d_head):
    """One pre-LN transformer block, single-token decode, exact keys.

    Returns (y (d_model,), k_new (H, d_k), v_new (H, d_k)). The caller
    appends k_new/v_new to the cache after this step.
    """
    q, k_new, v_new = _qkv(x, ln1_g, ln1_b, w_qkv, b_qkv, n_head, d_head)
    scores = jnp.einsum("hld,hd->hl", k_cache, q)           # (H, L)
    self_score = jnp.einsum("hd,hd->h", k_new, q)           # (H,)
    attn = _attend_with_self(scores, self_score, mask, v_cache, v_new,
                             d_head)                        # (H, d_k)
    attn_flat = attn.reshape(-1)
    x = x + attn_flat @ w_proj + b_proj
    h = layernorm(x, ln2_g, ln2_b)
    x = x + gelu(h @ w_fc + b_fc) @ w_out + b_out
    return x, k_new, v_new


def block_decode_lookat(x, codes, codebooks, v_cache, mask,
                        ln1_g, ln1_b, w_qkv, b_qkv, w_proj, b_proj,
                        ln2_g, ln2_b, w_fc, b_fc, w_out, b_out,
                        *, n_head, d_head):
    """One transformer block decode with LOOKAT key compression.

    Cached keys exist only as PQ codes; scores come from the Pallas ADC
    kernel. The current token's own K stays full-precision inside the
    step (rust encodes it when appending to the cache).
    """
    m = codebooks.shape[1]
    q, k_new, v_new = _qkv(x, ln1_g, ln1_b, w_qkv, b_qkv, n_head, d_head)
    H = q.shape[0]
    q_sub = q.reshape(H, m, d_head // m)
    lut = jnp.einsum("hmd,hmkd->hmk", q_sub, codebooks)     # (H, m, K)
    gathered = jnp.take_along_axis(
        lut[:, None, :, :], codes[:, :, :, None].astype(jnp.int32), axis=3
    )[..., 0]                                               # (H, L, m)
    scores = jnp.sum(gathered, axis=-1)                     # (H, L)
    self_score = jnp.einsum("hd,hd->h", k_new, q)
    attn = _attend_with_self(scores, self_score, mask, v_cache, v_new,
                             d_head)
    attn_flat = attn.reshape(-1)
    x = x + attn_flat @ w_proj + b_proj
    h = layernorm(x, ln2_g, ln2_b)
    x = x + gelu(h @ w_fc + b_fc) @ w_out + b_out
    return x, k_new, v_new


# ---------------------------------------------------------------------------
# Full-model reference (pytest-only; not lowered). Mirrors rust/src/model.
# ---------------------------------------------------------------------------


def init_params(rng, *, vocab, n_layer, n_head, d_head, d_ff, max_pos):
    """Random-init a GPT-2-shaped parameter pytree (pytest use only)."""
    d_model = n_head * d_head
    keys = jax.random.split(rng, 4 + n_layer)

    def dense(key, fan_in, shape):
        return jax.random.normal(key, shape) * (fan_in ** -0.5)

    params = {
        "wte": dense(keys[0], d_model, (vocab, d_model)),
        "wpe": dense(keys[1], d_model, (max_pos, d_model)) * 0.1,
        "ln_f_g": jnp.ones((d_model,)),
        "ln_f_b": jnp.zeros((d_model,)),
        "blocks": [],
    }
    for i in range(n_layer):
        ks = jax.random.split(keys[4 + i], 4)
        params["blocks"].append({
            "ln1_g": jnp.ones((d_model,)), "ln1_b": jnp.zeros((d_model,)),
            "w_qkv": dense(ks[0], d_model, (d_model, 3 * d_model)),
            "b_qkv": jnp.zeros((3 * d_model,)),
            "w_proj": dense(ks[1], d_model, (d_model, d_model)),
            "b_proj": jnp.zeros((d_model,)),
            "ln2_g": jnp.ones((d_model,)), "ln2_b": jnp.zeros((d_model,)),
            "w_fc": dense(ks[2], d_model, (d_model, d_ff)),
            "b_fc": jnp.zeros((d_ff,)),
            "w_out": dense(ks[3], d_ff, (d_ff, d_model)),
            "b_out": jnp.zeros((d_model,)),
        })
    return params


def prefill(params, token_ids, *, n_head, d_head):
    """Causal full-context forward. Returns (logits (T, V), per-layer
    (k, v) caches each (H, T, d_k))."""
    T = token_ids.shape[0]
    x = params["wte"][token_ids] + params["wpe"][:T]        # (T, d_model)
    caches = []
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    for blk in params["blocks"]:
        h = layernorm(x, blk["ln1_g"], blk["ln1_b"])
        qkv = h @ blk["w_qkv"] + blk["b_qkv"]               # (T, 3·d_model)
        d_model = n_head * d_head
        q = qkv[:, :d_model].reshape(T, n_head, d_head).transpose(1, 0, 2)
        k = qkv[:, d_model:2 * d_model].reshape(T, n_head, d_head
                                                ).transpose(1, 0, 2)
        v = qkv[:, 2 * d_model:].reshape(T, n_head, d_head
                                         ).transpose(1, 0, 2)
        s = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(
            jnp.asarray(d_head, jnp.float32))
        s = jnp.where(causal[None], s, ref.NEG_INF)
        a = ref.softmax(s, axis=-1)
        attn = jnp.einsum("hts,hsd->htd", a, v)             # (H, T, d_k)
        attn = attn.transpose(1, 0, 2).reshape(T, d_model)
        x = x + attn @ blk["w_proj"] + blk["b_proj"]
        h2 = layernorm(x, blk["ln2_g"], blk["ln2_b"])
        x = x + gelu(h2 @ blk["w_fc"] + blk["b_fc"]) @ blk["w_out"] \
            + blk["b_out"]
        caches.append((k, v))
    x = layernorm(x, params["ln_f_g"], params["ln_f_b"])
    logits = x @ params["wte"].T
    return logits, caches
