"""AOT lowering: JAX graphs -> HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per graph plus ``manifest.json`` describing
every artifact's inputs/outputs — the rust loader
(rust/src/runtime/artifact.rs) is driven entirely by the manifest.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import lookat as kern

# Canonical shapes (paper §4: GPT-2, H=12, d_k=64, K=256 centroids).
H = 12
D_K = 64
K = 256
D_MODEL = H * D_K
D_FF = 4 * D_MODEL
SEQ_LENS = (128, 512, 1024)
SUBSPACES = (2, 4, 8, 16)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _spec_desc(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_one(name, fn, specs, out_desc, meta, out_dir, manifest):
    """Lower fn at the given input specs and record it in the manifest."""
    lowered = jax.jit(fn).lower(*[s for _, s in specs])
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    manifest.append({
        "name": name,
        "file": fname,
        "inputs": [{"name": n, **_spec_desc(s)} for n, s in specs],
        "outputs": out_desc,
        "meta": meta,
    })
    print(f"  {fname:40s} {len(text) / 1024:8.1f} KiB")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only lower the L=128 artifacts (CI smoke)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []

    seq_lens = (128,) if args.quick else SEQ_LENS
    subspaces = (4,) if args.quick else SUBSPACES

    # --- attention decode steps (the serving hot-path artifacts) ---------
    for L in seq_lens:
        lower_one(
            f"attn_fp16_L{L}", model.attn_step_fp16,
            [("q", f32(H, D_K)), ("k", f32(H, L, D_K)),
             ("v", f32(H, L, D_K)), ("mask", f32(L))],
            [{"name": "out", "shape": [H, D_K], "dtype": "float32"}],
            {"kind": "attn_fp16", "H": H, "d_k": D_K, "L": L},
            args.out_dir, manifest)

    lookat_shapes = [(m, 512) for m in subspaces]
    for L in seq_lens:
        if (4, L) not in lookat_shapes:
            lookat_shapes.append((4, L))
    for m, L in lookat_shapes:
        d_sub = D_K // m
        lower_one(
            f"attn_lookat_m{m}_L{L}", model.attn_step_lookat,
            [("q", f32(H, D_K)), ("codes", i32(H, L, m)),
             ("codebooks", f32(H, m, K, d_sub)), ("v", f32(H, L, D_K)),
             ("mask", f32(L))],
            [{"name": "out", "shape": [H, D_K], "dtype": "float32"}],
            {"kind": "attn_lookat", "H": H, "d_k": D_K, "L": L,
             "m": m, "K": K},
            args.out_dir, manifest)

    # --- full transformer-block decode steps -----------------------------
    blk_params = [
        ("ln1_g", f32(D_MODEL)), ("ln1_b", f32(D_MODEL)),
        ("w_qkv", f32(D_MODEL, 3 * D_MODEL)), ("b_qkv", f32(3 * D_MODEL)),
        ("w_proj", f32(D_MODEL, D_MODEL)), ("b_proj", f32(D_MODEL)),
        ("ln2_g", f32(D_MODEL)), ("ln2_b", f32(D_MODEL)),
        ("w_fc", f32(D_MODEL, D_FF)), ("b_fc", f32(D_FF)),
        ("w_out", f32(D_FF, D_MODEL)), ("b_out", f32(D_MODEL)),
    ]
    blk_out = [
        {"name": "y", "shape": [D_MODEL], "dtype": "float32"},
        {"name": "k_new", "shape": [H, D_K], "dtype": "float32"},
        {"name": "v_new", "shape": [H, D_K], "dtype": "float32"},
    ]
    L = 128 if args.quick else 512
    lower_one(
        f"block_fp16_L{L}",
        functools.partial(model.block_decode_fp16, n_head=H, d_head=D_K),
        [("x", f32(D_MODEL)), ("k_cache", f32(H, L, D_K)),
         ("v_cache", f32(H, L, D_K)), ("mask", f32(L))] + blk_params,
        blk_out,
        {"kind": "block_fp16", "H": H, "d_k": D_K, "L": L,
         "d_model": D_MODEL, "d_ff": D_FF},
        args.out_dir, manifest)
    m = 4
    lower_one(
        f"block_lookat_m{m}_L{L}",
        functools.partial(model.block_decode_lookat, n_head=H, d_head=D_K),
        [("x", f32(D_MODEL)), ("codes", i32(H, L, m)),
         ("codebooks", f32(H, m, K, D_K // m)),
         ("v_cache", f32(H, L, D_K)), ("mask", f32(L))] + blk_params,
        blk_out,
        {"kind": "block_lookat", "H": H, "d_k": D_K, "L": L, "m": m,
         "K": K, "d_model": D_MODEL, "d_ff": D_FF},
        args.out_dir, manifest)

    # --- kernel-level micro artifacts (runtime integration tests) --------
    m = 4
    lower_one(
        "lut_build_m4", kern.lut_build,
        [("q_sub", f32(m, D_K // m)), ("codebooks", f32(m, K, D_K // m))],
        [{"name": "lut", "shape": [m, K], "dtype": "float32"}],
        {"kind": "lut_build", "m": m, "K": K, "d_k": D_K},
        args.out_dir, manifest)
    Ls = 128 if args.quick else 512
    lower_one(
        f"adc_scores_m4_L{Ls}", kern.adc_scores,
        [("codes", i32(Ls, m)), ("lut", f32(m, K))],
        [{"name": "scores", "shape": [Ls], "dtype": "float32"}],
        {"kind": "adc_scores", "m": m, "K": K, "L": Ls},
        args.out_dir, manifest)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"version": 1, "artifacts": manifest}, f, indent=1)
    print(f"wrote {len(manifest)} artifacts + manifest.json "
          f"to {args.out_dir}")


if __name__ == "__main__":
    main()
