"""Pure-jnp reference oracles for the LOOKAT kernels.

Everything in this file is deliberately written in the most obvious way
possible — no tiling, no fusion, no cleverness — so it can serve as the
ground truth that both the Pallas kernels (python/tests/) and the rust
implementation (rust/src/attention, rust/src/pq) are validated against.

Shape conventions (single attention head unless noted):
    q          : (d_k,)            full-precision query
    k, v       : (L, d_k)          key / value cache
    codebooks  : (m, K, d_sub)     PQ codebooks, d_sub = d_k / m
    codes      : (L, m)  int32     PQ codes, values in [0, K)
    lut        : (m, K)            ADC lookup tables for one query
"""

from __future__ import annotations

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Exact attention (the FP16 baseline of the paper, computed in f32 here; the
# "FP16" in the paper is a storage format — all our quality metrics compare
# against this oracle, and byte accounting uses 2 bytes/element).
# ---------------------------------------------------------------------------

def exact_scores(q, k):
    """Unscaled dot-product scores q·k_l for every cached key. -> (L,)"""
    return k @ q


def softmax(x, axis=-1):
    """Numerically-stable softmax (subtract-max trick)."""
    x = x - jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def exact_attention(q, k, v):
    """Standard single-head attention for one decode step. -> (d_k,)"""
    d_k = q.shape[-1]
    s = exact_scores(q, k) / jnp.sqrt(jnp.asarray(d_k, q.dtype))
    a = softmax(s)
    return a @ v


def exact_attention_weights(q, k):
    """Attention distribution alpha over the cache. -> (L,)"""
    d_k = q.shape[-1]
    s = exact_scores(q, k) / jnp.sqrt(jnp.asarray(d_k, q.dtype))
    return softmax(s)


# ---------------------------------------------------------------------------
# Product quantization (paper §3.4)
# ---------------------------------------------------------------------------

def split_subspaces(x, m):
    """(..., d_k) -> (..., m, d_sub): contiguous subspace decomposition."""
    d_k = x.shape[-1]
    assert d_k % m == 0, f"d_k={d_k} not divisible by m={m}"
    return x.reshape(*x.shape[:-1], m, d_k // m)


def pq_encode(keys, codebooks):
    """Encode keys to PQ codes by nearest centroid per subspace.

    keys (L, d_k), codebooks (m, K, d_sub) -> codes (L, m) int32.
    """
    m = codebooks.shape[0]
    sub = split_subspaces(keys, m)                      # (L, m, d_sub)
    # squared L2 distance to every centroid: (L, m, K)
    d2 = jnp.sum(
        (sub[:, :, None, :] - codebooks[None, :, :, :]) ** 2, axis=-1
    )
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)    # (L, m)


def pq_decode(codes, codebooks):
    """Reconstruct approximate keys from codes. -> (L, d_k)"""
    m, K, d_sub = codebooks.shape
    recon = jnp.take_along_axis(
        codebooks[None, :, :, :],                        # (1, m, K, d_sub)
        codes[:, :, None, None].astype(jnp.int32),       # (L, m, 1, 1)
        axis=2,
    )[:, :, 0, :]                                        # (L, m, d_sub)
    return recon.reshape(codes.shape[0], m * d_sub)


# ---------------------------------------------------------------------------
# Asymmetric distance computation (paper §3.5)
# ---------------------------------------------------------------------------

def adc_lut(q, codebooks):
    """LUT_i = q^(i) · C_i^T for every subspace. -> (m, K)"""
    m = codebooks.shape[0]
    qs = split_subspaces(q, m)                          # (m, d_sub)
    return jnp.einsum("md,mkd->mk", qs, codebooks)


def adc_scores(codes, lut):
    """Score every key by summing its m table entries. -> (L,)

    s_l = sum_i LUT_i[codes[l, i]]   — the paper's Algorithm 1 lines 6-8.
    """
    gathered = jnp.take_along_axis(
        lut[None, :, :],                                 # (1, m, K)
        codes[:, :, None].astype(jnp.int32),             # (L, m, 1)
        axis=2,
    )[:, :, 0]                                           # (L, m)
    return jnp.sum(gathered, axis=-1)


def lookat_attention(q, codes, codebooks, v):
    """Full LOOKAT decode step (paper Algorithm 1). -> (d_k,)

    Scores come from ADC lookups; softmax and the value reduction are
    unchanged from standard attention (values stay FP16 in the paper).
    """
    d_k = q.shape[-1]
    lut = adc_lut(q, codebooks)
    s = adc_scores(codes, lut) / jnp.sqrt(jnp.asarray(d_k, q.dtype))
    a = softmax(s)
    return a @ v


def lookat_attention_weights(q, codes, codebooks):
    """LOOKAT attention distribution. -> (L,)"""
    d_k = q.shape[-1]
    lut = adc_lut(q, codebooks)
    s = adc_scores(codes, lut) / jnp.sqrt(jnp.asarray(d_k, q.dtype))
    return softmax(s)


# ---------------------------------------------------------------------------
# Value compression (paper §5.2 extension; mirrors rust/src/pq/values.rs)
# ---------------------------------------------------------------------------

def value_weighted_decode(weights, codes, codebooks):
    """Weighted sum of PQ-coded values via weight aggregation.

    o = Σ_l w_l·decode(codes_l) = Σ_i Σ_c (Σ_{l:codes_l[i]=c} w_l)·C_i[c]

    weights (L,), codes (L, m) int32, codebooks (m, K, d_sub) -> (d_k,).
    Cost O(L·m + m·K·d_sub) instead of O(L·d_k).
    """
    m, K, d_sub = codebooks.shape
    onehot = (codes[:, :, None] ==
              jnp.arange(K)[None, None, :]).astype(weights.dtype)
    acc = jnp.einsum("l,lmk->mk", weights, onehot)        # (m, K)
    out = jnp.einsum("mk,mkd->md", acc, codebooks)        # (m, d_sub)
    return out.reshape(m * d_sub)


def value_weighted_decode_dense(weights, codes, codebooks):
    """Dense oracle for value_weighted_decode: per-token decode+scale."""
    recon = pq_decode(codes, codebooks)                    # (L, d_k)
    return weights @ recon


# ---------------------------------------------------------------------------
# Multi-head wrappers (vmap-free einsum form) with a validity mask, matching
# the decode-step artifacts lowered by aot.py. mask is (L,) with 1.0 for
# valid cache slots and 0.0 for padding (scores of padded slots -> -inf).
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def masked_exact_attention_mh(q, k, v, mask):
    """q (H, d_k), k/v (H, L, d_k), mask (L,) -> (H, d_k)"""
    d_k = q.shape[-1]
    s = jnp.einsum("hld,hd->hl", k, q) / jnp.sqrt(jnp.asarray(d_k, q.dtype))
    s = jnp.where(mask[None, :] > 0, s, NEG_INF)
    a = softmax(s, axis=-1)
    return jnp.einsum("hl,hld->hd", a, v)


def masked_lookat_attention_mh(q, codes, codebooks, v, mask):
    """q (H, d_k), codes (H, L, m), codebooks (H, m, K, d_sub),
    v (H, L, d_k), mask (L,) -> (H, d_k)"""
    d_k = q.shape[-1]
    m = codebooks.shape[1]
    qs = split_subspaces(q, m)                           # (H, m, d_sub)
    lut = jnp.einsum("hmd,hmkd->hmk", qs, codebooks)     # (H, m, K)
    gathered = jnp.take_along_axis(
        lut[:, None, :, :],                              # (H, 1, m, K)
        codes[:, :, :, None].astype(jnp.int32),          # (H, L, m, 1)
        axis=3,
    )[..., 0]                                            # (H, L, m)
    s = jnp.sum(gathered, axis=-1) / jnp.sqrt(jnp.asarray(d_k, q.dtype))
    s = jnp.where(mask[None, :] > 0, s, NEG_INF)
    a = softmax(s, axis=-1)
    return jnp.einsum("hl,hld->hd", a, v)
