"""Scalar-quantization baselines (INT4 / INT8) — python mirror of
rust/src/quant/.

The paper's baselines (§4.1) are symmetric per-tensor quantizers: a single
scale maps the tensor's max-|x| to the top of the signed integer range.
Attention with scalar-quantized keys must dequantize before the Q·Kᵀ
matmul (§3.2) — that round trip is exactly what these helpers model.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref


def quantize_symmetric(x, bits):
    """Quantize to signed `bits`-bit integers with per-tensor scale.

    Returns (q, scale) with q integer-valued (stored in int32 for jnp
    convenience; storage accounting uses bits/8 bytes per element).
    """
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int32)
    return q, scale


def dequantize(q, scale):
    """Reconstruct FP values: x ≈ q · scale."""
    return q.astype(jnp.float32) * scale


def quant_roundtrip(x, bits):
    """quantize → dequantize in one step (what the INT4/INT8 baselines do
    to keys before the exact attention matmul)."""
    q, scale = quantize_symmetric(x, bits)
    return dequantize(q, scale)


def int8_attention(q, k, v):
    """Exact attention over INT8-roundtripped keys. Single head."""
    return ref.exact_attention(q, quant_roundtrip(k, 8), v)


def int4_attention(q, k, v):
    """Exact attention over INT4-roundtripped keys. Single head."""
    return ref.exact_attention(q, quant_roundtrip(k, 4), v)
