"""Pallas kernels for LOOKAT (Layer 1 of the stack).

Three kernels, all run with ``interpret=True`` (the CPU image cannot
execute Mosaic custom-calls — see /opt/xla-example/README.md):

  * ``lut_build``    — per-query ADC lookup tables  LUT_i = q^(i) · C_i^T
  * ``adc_scores``   — scores via table lookups, tiled over L
  * ``lookat_attention`` — fused decode step: LUT → scores → softmax → α·V

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets
edge NPUs where per-key scalar gathers are cheap. On TPU the MXU wants
matmuls, so ``adc_scores`` reformulates the gather-and-sum as a one-hot
matmul: the (L_tile, m) int codes become a (L_tile, m·K) one-hot plane
multiplied against the flattened (m·K,) LUT. Under interpret=True this is
also what the CPU backend vectorizes best. Codebooks (m·K·d_sub ≤ 16 KB
f32 for d_k=64) and the LUT (m·K ≤ 4 KB) are VMEM-resident; only codes and
values stream from HBM, which is exactly the paper's bandwidth story.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Tile size for the L dimension of the ADC score scan. 128 keeps the
# one-hot plane (128 × m·256 f32 ≤ 2 MB for m=16) comfortably in VMEM.
L_TILE = 128


# ---------------------------------------------------------------------------
# Kernel 1: LUT build
# ---------------------------------------------------------------------------

def _lut_build_kernel(q_ref, cb_ref, lut_ref):
    """q (m, d_sub), codebooks (m, K, d_sub) -> lut (m, K).

    One small einsum; for d_k=64 this is the paper's O(m·K·d_sub) = O(4096)
    FLOP precompute done once per query.
    """
    q = q_ref[...]                       # (m, d_sub)
    cb = cb_ref[...]                     # (m, K, d_sub)
    lut_ref[...] = jnp.einsum(
        "md,mkd->mk", q, cb, preferred_element_type=jnp.float32
    )


def lut_build(q_sub, codebooks):
    """Build ADC lookup tables. q_sub (m, d_sub), codebooks (m, K, d_sub)."""
    m, K, _ = codebooks.shape
    return pl.pallas_call(
        _lut_build_kernel,
        out_shape=jax.ShapeDtypeStruct((m, K), jnp.float32),
        interpret=True,
    )(q_sub, codebooks)


# ---------------------------------------------------------------------------
# Kernel 2: ADC score scan (tiled over L, one-hot matmul formulation)
# ---------------------------------------------------------------------------

def _adc_scores_kernel(codes_ref, lut_ref, out_ref, *, K):
    """codes tile (T, m) int32, lut (m, K) -> scores tile (T,).

    One-hot matmul: onehot (T, m, K) contracted with lut (m, K). XLA fuses
    the iota-compare into the reduction, so no (T, m·K) buffer actually
    materializes in the interpret path; on real TPU this shape feeds the
    MXU as a (T, m·K) × (m·K, 1) matmul.
    """
    codes = codes_ref[...]                                # (T, m)
    lut = lut_ref[...]                                    # (m, K)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, K), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32)  # (T, m, K)
    out_ref[...] = jnp.einsum(
        "tmk,mk->t", onehot, lut, preferred_element_type=jnp.float32
    )


def adc_scores(codes, lut):
    """ADC scores for a whole cache. codes (L, m) int32, lut (m, K) -> (L,).

    L must be a multiple of L_TILE (the cache manager pads; the validity
    mask downstream ignores padded slots).
    """
    L, m = codes.shape
    mK, K = lut.shape
    assert m == mK
    assert L % L_TILE == 0, f"L={L} must be a multiple of {L_TILE}"
    grid = (L // L_TILE,)
    return pl.pallas_call(
        functools.partial(_adc_scores_kernel, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((L_TILE, m), lambda i: (i, 0)),   # stream codes
            pl.BlockSpec((m, K), lambda i: (0, 0)),        # LUT pinned
        ],
        out_specs=pl.BlockSpec((L_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((L,), jnp.float32),
        interpret=True,
    )(codes, lut)


# ---------------------------------------------------------------------------
# Kernel 3: fused LOOKAT decode step (Algorithm 1, single head)
# ---------------------------------------------------------------------------

def _lookat_attention_kernel(q_ref, codes_ref, cb_ref, v_ref, mask_ref,
                             out_ref, *, K, d_k):
    """Fused: LUT build + ADC scores + masked softmax + α·V.

    q (m, d_sub), codes (L, m), codebooks (m, K, d_sub), v (L, d_k),
    mask (L,) -> out (d_k,). Whole cache in VMEM: for L=1024, m≤16 this is
    codes 64 KB + v 256 KB + codebooks 16 KB — fine for a 16 MB VMEM.
    """
    q = q_ref[...]
    cb = cb_ref[...]
    codes = codes_ref[...]
    v = v_ref[...]
    mask = mask_ref[...]

    lut = jnp.einsum("md,mkd->mk", q, cb,
                     preferred_element_type=jnp.float32)       # (m, K)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, K), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32)   # (L, m, K)
    s = jnp.einsum("lmk,mk->l", onehot, lut,
                   preferred_element_type=jnp.float32)         # (L,)
    s = s / jnp.sqrt(jnp.asarray(d_k, jnp.float32))
    s = jnp.where(mask > 0, s, NEG_INF)
    s = s - jnp.max(s)
    e = jnp.exp(s)
    a = e / jnp.sum(e)                                          # (L,)
    out_ref[...] = a @ v                                        # (d_k,)


def lookat_attention(q_sub, codes, codebooks, v, mask):
    """Fused LOOKAT decode step for one head.

    q_sub (m, d_sub), codes (L, m) int32, codebooks (m, K, d_sub),
    v (L, d_k), mask (L,) -> (d_k,)
    """
    m, K, d_sub = codebooks.shape
    d_k = m * d_sub
    return pl.pallas_call(
        functools.partial(_lookat_attention_kernel, K=K, d_k=d_k),
        out_shape=jax.ShapeDtypeStruct((d_k,), jnp.float32),
        interpret=True,
    )(q_sub, codes, codebooks, v, mask)


# ---------------------------------------------------------------------------
# Kernel 4: value-side weighted decode (paper §5.2 extension).
# Same one-hot-matmul trick, transposed: attention weights aggregate into
# a (m, K) table, then one small (m·K × d_sub) contraction reconstructs
# the output — per-token values never materialize.
# ---------------------------------------------------------------------------

def _value_decode_kernel(w_ref, codes_ref, cb_ref, out_ref, *, K):
    w = w_ref[...]                                        # (L,)
    codes = codes_ref[...]                                # (L, m)
    cb = cb_ref[...]                                      # (m, K, d_sub)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, K), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32)  # (L, m, K)
    acc = jnp.einsum("l,lmk->mk", w, onehot,
                     preferred_element_type=jnp.float32)      # (m, K)
    out = jnp.einsum("mk,mkd->md", acc, cb,
                     preferred_element_type=jnp.float32)      # (m, d_sub)
    out_ref[...] = out.reshape(-1)


def value_decode(weights, codes, codebooks):
    """Weighted decode of PQ-coded values. weights (L,), codes (L, m)
    int32, codebooks (m, K, d_sub) -> (d_k,)."""
    m, K, d_sub = codebooks.shape
    return pl.pallas_call(
        functools.partial(_value_decode_kernel, K=K),
        out_shape=jax.ShapeDtypeStruct((m * d_sub,), jnp.float32),
        interpret=True,
    )(weights, codes, codebooks)


# ---------------------------------------------------------------------------
# Baseline kernel: exact (FP16-storage) attention decode step, for the
# speedup comparison and as the FP16 serving path's compute.
# ---------------------------------------------------------------------------

def _exact_attention_kernel(q_ref, k_ref, v_ref, mask_ref, out_ref, *, d_k):
    q = q_ref[...]                       # (d_k,)
    k = k_ref[...]                       # (L, d_k)
    v = v_ref[...]
    mask = mask_ref[...]
    s = k @ q / jnp.sqrt(jnp.asarray(d_k, jnp.float32))
    s = jnp.where(mask > 0, s, NEG_INF)
    s = s - jnp.max(s)
    e = jnp.exp(s)
    a = e / jnp.sum(e)
    out_ref[...] = a @ v


def exact_attention(q, k, v, mask):
    """Exact single-head decode step. q (d_k,), k/v (L, d_k), mask (L,)."""
    L, d_k = k.shape
    return pl.pallas_call(
        functools.partial(_exact_attention_kernel, d_k=d_k),
        out_shape=jax.ShapeDtypeStruct((d_k,), jnp.float32),
        interpret=True,
    )(q, k, v, mask)


# ---------------------------------------------------------------------------
# Multi-head entry points used by the L2 model (vmap over heads).
# ---------------------------------------------------------------------------

def lookat_attention_mh(q, codes, codebooks, v, mask):
    """q (H, d_k), codes (H, L, m), codebooks (H, m, K, d_sub),
    v (H, L, d_k), mask (L,) -> (H, d_k)"""
    m = codebooks.shape[1]
    H, d_k = q.shape
    q_sub = q.reshape(H, m, d_k // m)
    return jax.vmap(lookat_attention, in_axes=(0, 0, 0, 0, None))(
        q_sub, codes, codebooks, v, mask
    )


def exact_attention_mh(q, k, v, mask):
    """q (H, d_k), k/v (H, L, d_k), mask (L,) -> (H, d_k)"""
    return jax.vmap(exact_attention, in_axes=(0, 0, 0, None))(q, k, v, mask)
