"""L2 model graph tests: block decode vs prefill consistency, LOOKAT block
fidelity, and quant baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import quant, ref

H, D_K = 4, 32
D_MODEL = H * D_K
D_FF = 4 * D_MODEL
VOCAB = 64


@pytest.fixture(scope="module")
def params():
    return model.init_params(
        jax.random.PRNGKey(0), vocab=VOCAB, n_layer=2, n_head=H,
        d_head=D_K, d_ff=D_FF, max_pos=64)


def blk_args(blk):
    return (blk["ln1_g"], blk["ln1_b"], blk["w_qkv"], blk["b_qkv"],
            blk["w_proj"], blk["b_proj"], blk["ln2_g"], blk["ln2_b"],
            blk["w_fc"], blk["b_fc"], blk["w_out"], blk["b_out"])


def test_prefill_shapes(params):
    T = 16
    ids = jax.random.randint(jax.random.PRNGKey(1), (T,), 0, VOCAB)
    logits, caches = model.prefill(params, ids, n_head=H, d_head=D_K)
    assert logits.shape == (T, VOCAB)
    assert len(caches) == 2
    assert caches[0][0].shape == (H, T, D_K)


def test_block_decode_matches_prefill_incremental(params):
    """Decoding token T with block_decode_fp16 against the cache of the
    first T-1 tokens must reproduce prefill's hidden state at position T."""
    T = 12
    L = 16  # padded cache
    ids = jax.random.randint(jax.random.PRNGKey(2), (T,), 0, VOCAB)
    _, caches = model.prefill(params, ids, n_head=H, d_head=D_K)

    # hidden state entering layer 0 at position T-1
    x = params["wte"][ids[T - 1]] + params["wpe"][T - 1]

    # reference hidden state leaving every block, computed by re-running
    # prefill and taking position T-1 (prefill is causal so this matches)
    x_ref = x
    mask = (jnp.arange(L) < T - 1).astype(jnp.float32)
    for li, blk in enumerate(params["blocks"]):
        k_c, v_c = caches[li]
        pad = L - (T - 1)
        k_pad = jnp.pad(k_c[:, :T - 1], ((0, 0), (0, pad), (0, 0)))
        v_pad = jnp.pad(v_c[:, :T - 1], ((0, 0), (0, pad), (0, 0)))
        y, k_new, v_new = model.block_decode_fp16(
            x_ref, k_pad, v_pad, mask, *blk_args(blk),
            n_head=H, d_head=D_K)
        # the k/v the block emits must equal what prefill cached at T-1
        np.testing.assert_allclose(k_new, k_c[:, T - 1], rtol=2e-4,
                                   atol=2e-4)
        np.testing.assert_allclose(v_new, v_c[:, T - 1], rtol=2e-4,
                                   atol=2e-4)
        x_ref = y

    # full-model check: project final state to logits, compare to prefill
    logits_ref, _ = model.prefill(params, ids, n_head=H, d_head=D_K)
    xf = model.layernorm(x_ref, params["ln_f_g"], params["ln_f_b"])
    logits = xf @ params["wte"].T
    np.testing.assert_allclose(logits, logits_ref[T - 1], rtol=2e-3,
                               atol=2e-3)


def test_block_lookat_close_to_fp16(params):
    """With dense random codebooks the LOOKAT block output should be close
    (not identical) to the fp16 block; with centroid-coincident keys it
    must be near-exact."""
    L, m, K = 16, 4, 256
    blk = params["blocks"][0]
    x = jax.random.normal(jax.random.PRNGKey(3), (D_MODEL,), jnp.float32)
    codebooks = jax.random.normal(jax.random.PRNGKey(4),
                                  (H, m, K, D_K // m), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(5), (H, L, m), 0, K)
    k_cache = jnp.stack([ref.pq_decode(idx[h].astype(jnp.int32),
                                       codebooks[h]) for h in range(H)])
    v_cache = jax.random.normal(jax.random.PRNGKey(6), (H, L, D_K),
                                jnp.float32)
    codes = jnp.stack([ref.pq_encode(k_cache[h], codebooks[h])
                       for h in range(H)])
    mask = jnp.ones((L,), jnp.float32)

    y_fp, k_fp, v_fp = model.block_decode_fp16(
        x, k_cache, v_cache, mask, *blk_args(blk), n_head=H, d_head=D_K)
    y_lk, k_lk, v_lk = model.block_decode_lookat(
        x, codes, codebooks, v_cache, mask, *blk_args(blk),
        n_head=H, d_head=D_K)

    # keys coincide with centroids -> ADC scores exact -> outputs match
    np.testing.assert_allclose(y_lk, y_fp, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(k_lk, k_fp, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(v_lk, v_fp, rtol=1e-5, atol=1e-5)


def test_layernorm_normalizes():
    x = jax.random.normal(jax.random.PRNGKey(7), (D_MODEL,)) * 5 + 3
    y = model.layernorm(x, jnp.ones((D_MODEL,)), jnp.zeros((D_MODEL,)))
    assert abs(float(jnp.mean(y))) < 1e-4
    assert abs(float(jnp.std(y)) - 1.0) < 1e-2


def test_gelu_reference_points():
    np.testing.assert_allclose(model.gelu(jnp.asarray(0.0)), 0.0, atol=1e-7)
    assert float(model.gelu(jnp.asarray(3.0))) == pytest.approx(2.9964,
                                                                abs=1e-3)
    assert float(model.gelu(jnp.asarray(-3.0))) == pytest.approx(-0.0036,
                                                                 abs=1e-3)


# ---------------------------------------------------------------------------
# scalar quantization baselines
# ---------------------------------------------------------------------------

def test_int8_roundtrip_near_lossless():
    x = jax.random.normal(jax.random.PRNGKey(8), (512, 64))
    y = quant.quant_roundtrip(x, 8)
    err = float(jnp.max(jnp.abs(x - y)))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert err <= scale * 0.5 + 1e-6


def test_int4_coarser_than_int8():
    x = jax.random.normal(jax.random.PRNGKey(9), (512, 64))
    e4 = float(jnp.mean((x - quant.quant_roundtrip(x, 4)) ** 2))
    e8 = float(jnp.mean((x - quant.quant_roundtrip(x, 8)) ** 2))
    assert e4 > e8 * 10


def test_quantize_integer_range():
    x = jax.random.normal(jax.random.PRNGKey(10), (256,)) * 10
    q4, _ = quant.quantize_symmetric(x, 4)
    assert int(q4.min()) >= -8 and int(q4.max()) <= 7
    q8, _ = quant.quantize_symmetric(x, 8)
    assert int(q8.min()) >= -128 and int(q8.max()) <= 127


def test_quantize_zero_tensor():
    q, scale = quant.quantize_symmetric(jnp.zeros((16,)), 4)
    assert float(scale) == 1.0
    assert jnp.all(q == 0)


def test_int8_attention_close_to_exact():
    q = jax.random.normal(jax.random.PRNGKey(11), (64,))
    k = jax.random.normal(jax.random.PRNGKey(12), (128, 64))
    v = jax.random.normal(jax.random.PRNGKey(13), (128, 64))
    got = quant.int8_attention(q, k, v)
    want = ref.exact_attention(q, k, v)
    cos = float(jnp.dot(got, want) /
                (jnp.linalg.norm(got) * jnp.linalg.norm(want)))
    assert cos > 0.999
