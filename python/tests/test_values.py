"""Value-compression extension (paper §5.2): Pallas kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lookat as kern
from compile.kernels import ref


def make_case(seed, L, d_k, m, K=64):
    kw, kv, kc = [jax.random.PRNGKey(seed * 11 + i) for i in range(3)]
    w = jax.nn.softmax(jax.random.normal(kw, (L,), jnp.float32))
    values = jax.random.normal(kv, (L, d_k), jnp.float32)
    codebooks = jax.random.normal(kc, (m, K, d_k // m), jnp.float32)
    codes = ref.pq_encode(values, codebooks)
    return w, values, codebooks, codes


@pytest.mark.parametrize("L,m", [(64, 2), (128, 4), (256, 8), (100, 4)])
def test_aggregated_matches_dense_oracle(L, m):
    w, _, codebooks, codes = make_case(1, L, 64, m)
    got = ref.value_weighted_decode(w, codes, codebooks)
    want = ref.value_weighted_decode_dense(w, codes, codebooks)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("L,m", [(64, 2), (128, 4), (256, 8)])
def test_pallas_value_decode_matches_ref(L, m):
    w, _, codebooks, codes = make_case(2, L, 64, m)
    got = kern.value_decode(w, codes, codebooks)
    want = ref.value_weighted_decode(w, codes, codebooks)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_one_hot_weight_reconstructs_single_value():
    _, _, codebooks, codes = make_case(3, 32, 32, 4)
    w = jnp.zeros((32,)).at[5].set(1.0)
    got = kern.value_decode(w, codes, codebooks)
    want = ref.pq_decode(codes, codebooks)[5]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_zero_weights_zero_output():
    _, _, codebooks, codes = make_case(4, 32, 32, 4)
    got = kern.value_decode(jnp.zeros((32,)), codes, codebooks)
    assert jnp.all(got == 0.0)


def test_fidelity_against_uncompressed_values():
    # iid gaussian values are the PQ worst case (no structure to exploit;
    # random codebooks here, not even trained) — the weighted sum still
    # tracks the exact reduction directionally; trained codebooks on real
    # value distributions score ~0.98 (see rust ablation_values report)
    w, values, codebooks, codes = make_case(5, 256, 64, 8, K=256)
    approx = ref.value_weighted_decode(w, codes, codebooks)
    exact = w @ values
    cos = float(jnp.dot(approx, exact) /
                (jnp.linalg.norm(approx) * jnp.linalg.norm(exact)))
    assert cos > 0.5, cos


@settings(max_examples=15, deadline=None)
@given(
    L=st.sampled_from([32, 64, 128]),
    m=st.sampled_from([2, 4, 8]),
    K=st.sampled_from([16, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_value_decode_equivalence(L, m, K, seed):
    w, _, codebooks, codes = make_case(seed % 997, L, 32, m, K)
    got = kern.value_decode(w, codes, codebooks)
    want = ref.value_weighted_decode_dense(w, codes, codebooks)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
