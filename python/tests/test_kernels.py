"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lookat as kern
from compile.kernels import ref

RTOL = 1e-5
ATOL = 1e-5


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             dtype=jnp.float32)


def make_case(seed, L, d_k, m, K=256):
    """Random q, keys, values, learned-ish codebooks and codes."""
    kq, kk, kv, kc = [jax.random.PRNGKey(seed * 7 + i) for i in range(4)]
    q = jax.random.normal(kq, (d_k,), jnp.float32)
    keys = jax.random.normal(kk, (L, d_k), jnp.float32)
    v = jax.random.normal(kv, (L, d_k), jnp.float32)
    d_sub = d_k // m
    # "codebooks" = random centroids; quality doesn't matter for kernel
    # equivalence, only for the end-to-end fidelity experiments.
    codebooks = jax.random.normal(kc, (m, K, d_sub), jnp.float32)
    codes = ref.pq_encode(keys, codebooks)
    return q, keys, v, codebooks, codes


# ---------------------------------------------------------------------------
# lut_build
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,d_k", [(2, 64), (4, 64), (8, 64), (16, 64),
                                   (4, 32), (8, 128)])
def test_lut_build_matches_ref(m, d_k):
    q, _, _, codebooks, _ = make_case(1, 128, d_k, m)
    got = kern.lut_build(q.reshape(m, d_k // m), codebooks)
    want = ref.adc_lut(q, codebooks)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_lut_build_zero_query_gives_zero_tables():
    _, _, _, codebooks, _ = make_case(2, 128, 64, 4)
    got = kern.lut_build(jnp.zeros((4, 16)), codebooks)
    assert jnp.all(got == 0.0)


# ---------------------------------------------------------------------------
# adc_scores
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L", [128, 256, 512, 1024])
@pytest.mark.parametrize("m", [2, 4, 8, 16])
def test_adc_scores_matches_ref(L, m):
    q, _, _, codebooks, codes = make_case(3, L, 64, m)
    lut = ref.adc_lut(q, codebooks)
    got = kern.adc_scores(codes, lut)
    want = ref.adc_scores(codes, lut)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_adc_scores_exact_when_keys_are_centroids():
    """If every key IS a centroid, ADC scores equal exact scores."""
    m, K, d_k, L = 4, 256, 64, 128
    _, _, _, codebooks, _ = make_case(4, L, d_k, m)
    # build keys from randomly chosen centroids
    idx = jax.random.randint(jax.random.PRNGKey(9), (L, m), 0, K)
    keys = ref.pq_decode(idx.astype(jnp.int32), codebooks)
    codes = ref.pq_encode(keys, codebooks)
    q = rand(5, d_k)
    lut = ref.adc_lut(q, codebooks)
    got = kern.adc_scores(codes, lut)
    np.testing.assert_allclose(got, ref.exact_scores(q, keys),
                               rtol=1e-4, atol=1e-4)


def test_adc_scores_rejects_unaligned_L():
    q, _, _, codebooks, codes = make_case(6, 128, 64, 4)
    lut = ref.adc_lut(q, codebooks)
    with pytest.raises(AssertionError):
        kern.adc_scores(codes[:100], lut)


# ---------------------------------------------------------------------------
# fused lookat_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,m", [(128, 2), (128, 4), (256, 8), (512, 4),
                                 (1024, 16)])
def test_lookat_attention_matches_ref(L, m):
    q, _, v, codebooks, codes = make_case(7, L, 64, m)
    mask = jnp.ones((L,), jnp.float32)
    got = kern.lookat_attention(q.reshape(m, 64 // m), codes, codebooks,
                                v, mask)
    want = ref.lookat_attention(q, codes, codebooks, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_lookat_attention_respects_mask():
    """Masked-out slots must not contribute: compare against the oracle
    run on only the valid prefix."""
    L, m, valid = 256, 4, 100
    q, _, v, codebooks, codes = make_case(8, L, 64, m)
    mask = (jnp.arange(L) < valid).astype(jnp.float32)
    got = kern.lookat_attention(q.reshape(m, 16), codes, codebooks, v, mask)
    want = ref.lookat_attention(q, codes[:valid], codebooks, v[:valid])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_exact_attention_kernel_matches_ref():
    L, d_k = 256, 64
    q, k, v, _, _ = make_case(9, L, d_k, 4)
    mask = jnp.ones((L,), jnp.float32)
    got = kern.exact_attention(q, k, v, mask)
    want = ref.exact_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_exact_attention_kernel_respects_mask():
    L, d_k, valid = 256, 64, 37
    q, k, v, _, _ = make_case(10, L, d_k, 4)
    mask = (jnp.arange(L) < valid).astype(jnp.float32)
    got = kern.exact_attention(q, k, v, mask)
    want = ref.exact_attention(q, k[:valid], v[:valid])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# multi-head wrappers
# ---------------------------------------------------------------------------

def test_lookat_attention_mh_matches_ref():
    H, L, d_k, m, K = 4, 128, 64, 4, 256
    kq, kk, kv, kc = [jax.random.PRNGKey(20 + i) for i in range(4)]
    q = jax.random.normal(kq, (H, d_k), jnp.float32)
    keys = jax.random.normal(kk, (H, L, d_k), jnp.float32)
    v = jax.random.normal(kv, (H, L, d_k), jnp.float32)
    codebooks = jax.random.normal(kc, (H, m, K, d_k // m), jnp.float32)
    codes = jnp.stack([ref.pq_encode(keys[h], codebooks[h])
                       for h in range(H)])
    mask = jnp.ones((L,), jnp.float32)
    got = kern.lookat_attention_mh(q, codes, codebooks, v, mask)
    want = ref.masked_lookat_attention_mh(q, codes, codebooks, v, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_exact_attention_mh_matches_ref():
    H, L, d_k = 3, 128, 64
    kq, kk, kv = [jax.random.PRNGKey(30 + i) for i in range(3)]
    q = jax.random.normal(kq, (H, d_k), jnp.float32)
    k = jax.random.normal(kk, (H, L, d_k), jnp.float32)
    v = jax.random.normal(kv, (H, L, d_k), jnp.float32)
    mask = jnp.ones((L,), jnp.float32)
    got = kern.exact_attention_mh(q, k, v, mask)
    want = ref.masked_exact_attention_mh(q, k, v, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes, m, and dtype-robustness of the kernel vs ref
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    L_tiles=st.integers(min_value=1, max_value=8),
    m=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_adc_scores_equivalence(L_tiles, m, seed):
    L = L_tiles * kern.L_TILE
    q, _, _, codebooks, codes = make_case(seed % 1000, L, 64, m, K=256)
    lut = ref.adc_lut(q, codebooks)
    got = kern.adc_scores(codes, lut)
    want = ref.adc_scores(codes, lut)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    d_k=st.sampled_from([32, 64, 128]),
    m=st.sampled_from([2, 4, 8]),
    K=st.sampled_from([16, 64, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_lut_build_equivalence(d_k, m, K, seed):
    kq, kc = jax.random.PRNGKey(seed), jax.random.PRNGKey(seed + 1)
    q = jax.random.normal(kq, (d_k,), jnp.float32)
    codebooks = jax.random.normal(kc, (m, K, d_k // m), jnp.float32)
    got = kern.lut_build(q.reshape(m, d_k // m), codebooks)
    np.testing.assert_allclose(got, ref.adc_lut(q, codebooks),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    L=st.sampled_from([128, 256, 512]),
    m=st.sampled_from([2, 4, 8, 16]),
    valid_frac=st.floats(min_value=0.1, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_fused_lookat_masked(L, m, valid_frac, seed):
    q, _, v, codebooks, codes = make_case(seed % 1000, L, 64, m)
    valid = max(1, int(L * valid_frac))
    mask = (jnp.arange(L) < valid).astype(jnp.float32)
    got = kern.lookat_attention(q.reshape(m, 64 // m), codes, codebooks,
                                v, mask)
    want = ref.lookat_attention(q, codes[:valid], codebooks, v[:valid])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# PQ oracle invariants (shared ground truth with rust/src/pq)
# ---------------------------------------------------------------------------

def test_pq_roundtrip_exact_for_centroid_keys():
    m, K, d_k, L = 4, 64, 64, 96
    codebooks = rand(40, m, K, d_k // m)
    idx = jax.random.randint(jax.random.PRNGKey(41), (L, m), 0, K)
    keys = ref.pq_decode(idx.astype(jnp.int32), codebooks)
    codes = ref.pq_encode(keys, codebooks)
    np.testing.assert_allclose(ref.pq_decode(codes, codebooks), keys,
                               rtol=1e-5, atol=1e-5)


def test_pq_codes_in_range():
    q, _, _, codebooks, codes = make_case(42, 256, 64, 8)
    assert int(codes.min()) >= 0
    assert int(codes.max()) < codebooks.shape[1]


def test_pq_encode_picks_nearest():
    """Brute-force check on a tiny case."""
    m, K, d_sub = 2, 8, 4
    codebooks = rand(43, m, K, d_sub)
    keys = rand(44, 10, m * d_sub)
    codes = ref.pq_encode(keys, codebooks)
    sub = keys.reshape(10, m, d_sub)
    for l in range(10):
        for i in range(m):
            d2 = jnp.sum((codebooks[i] - sub[l, i]) ** 2, axis=-1)
            assert int(codes[l, i]) == int(jnp.argmin(d2))
