"""AOT lowering smoke tests: every artifact graph lowers to parseable,
non-trivial HLO text with the expected parameter count."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import lookat as kern


def lower_text(fn, *specs):
    return aot.to_hlo_text(jax.jit(fn).lower(*specs))


def entry_param_count(text):
    """Count parameters of the ENTRY computation only (sub-computations
    like fused reducers declare their own `parameter(` lines)."""
    entry = text.split("ENTRY")[1]
    return entry.count("parameter(")


def test_attn_fp16_lowers():
    H, L, D = 4, 128, 64
    text = lower_text(
        model.attn_step_fp16,
        aot.f32(H, D), aot.f32(H, L, D), aot.f32(H, L, D), aot.f32(L))
    assert "HloModule" in text
    assert entry_param_count(text) == 4


def test_attn_lookat_lowers():
    H, L, D, m, K = 4, 128, 64, 4, 256
    text = lower_text(
        model.attn_step_lookat,
        aot.f32(H, D), aot.i32(H, L, m), aot.f32(H, m, K, D // m),
        aot.f32(H, L, D), aot.f32(L))
    assert "HloModule" in text
    assert entry_param_count(text) == 5


def test_lut_build_lowers():
    m, K, d_sub = 4, 256, 16
    text = lower_text(kern.lut_build, aot.f32(m, d_sub), aot.f32(m, K, d_sub))
    assert "HloModule" in text


def test_adc_scores_lowers():
    L, m, K = 256, 4, 256
    text = lower_text(kern.adc_scores, aot.i32(L, m), aot.f32(m, K))
    assert "HloModule" in text
    # the one-hot matmul formulation should show up as a dot or reduce
    assert ("dot(" in text) or ("reduce(" in text)


def test_block_decode_lowers_with_three_outputs():
    import functools
    H, D, L = 2, 16, 32
    DM, DF = H * D, 4 * H * D
    fn = functools.partial(model.block_decode_fp16, n_head=H, d_head=D)
    text = lower_text(
        fn, aot.f32(DM), aot.f32(H, L, D), aot.f32(H, L, D), aot.f32(L),
        aot.f32(DM), aot.f32(DM), aot.f32(DM, 3 * DM), aot.f32(3 * DM),
        aot.f32(DM, DM), aot.f32(DM), aot.f32(DM), aot.f32(DM),
        aot.f32(DM, DF), aot.f32(DF), aot.f32(DF, DM), aot.f32(DM))
    assert "HloModule" in text
    # root should be a 3-tuple
    assert "tuple(" in text


def test_hlo_text_is_stable_across_lowerings():
    """Two lowerings of the same graph produce identical text (determinism
    matters for `make artifacts` caching)."""
    H, L, D = 2, 128, 32
    a = lower_text(model.attn_step_fp16, aot.f32(H, D), aot.f32(H, L, D),
                   aot.f32(H, L, D), aot.f32(L))
    b = lower_text(model.attn_step_fp16, aot.f32(H, D), aot.f32(H, L, D),
                   aot.f32(H, L, D), aot.f32(L))
    assert a == b


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)")
def test_manifest_consistent_with_files():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) >= 5
    for art in manifest["artifacts"]:
        path = os.path.join(root, art["file"])
        assert os.path.exists(path), art["file"]
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head
        assert len(art["inputs"]) >= 2
        assert len(art["outputs"]) >= 1
