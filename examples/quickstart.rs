//! Quickstart: the LOOKAT pipeline on one attention head in ~40 lines.
//!
//!   cargo run --release --example quickstart
//!
//! 1. extract keys from a model layer, 2. train PQ codebooks,
//! 3. encode the cache, 4. score a query via lookup tables,
//! 5. compare against exact attention.

use lookat::attention::{exact_attention, lookat_attention};
use lookat::metrics::FidelityReport;
use lookat::model::{ByteTokenizer, Gpt2, ModelConfig, Weights};
use lookat::pq::{PqCodec, TrainOpts};
use lookat::workload::{Corpus, Genre};

fn main() -> anyhow::Result<()> {
    // A GPT-2-geometry model (H=12, d_k=64) and some text.
    let cfg = ModelConfig::gpt2_layer0();
    let model = Gpt2::new(Weights::random(&cfg, 42));
    let text = Corpus::new(Genre::Prose, 1).generate(1200);
    let ids = ByteTokenizer::new().encode_clamped(&text, 256);
    println!("prefilling {} tokens...", ids.len());
    let out = model.prefill(&ids);

    // Layer-0, head-0 cache: the paper's §4.1 extraction.
    let (head, d_k, n) = (0usize, cfg.d_head, ids.len());
    let keys = out.head_keys(0, head, d_k);
    let values = out.head_values(0, head, d_k);
    let queries = out.head_queries(0, head, d_k);

    // LOOKAT-4: 4 subspaces × 256 centroids -> 32× key compression.
    // Codebooks are trained on a *held-out* calibration text (training
    // on the evaluated cache itself would let K-Means memorize it).
    let calib_text = Corpus::new(Genre::Prose, 2).generate(1200);
    let calib_ids = ByteTokenizer::new().encode_clamped(&calib_text, 256);
    let calib_keys = model.prefill(&calib_ids).head_keys(0, head, d_k);
    let codec =
        PqCodec::train(&calib_keys, d_k, 4, 256, &TrainOpts::default());
    let codes = codec.encode_batch(&keys, n);
    println!(
        "trained codebooks: {} bytes of codes vs {} bytes of FP16 keys \
         ({}x compression)",
        codes.len(),
        n * d_k * 2,
        codec.compression_ratio()
    );

    // Decode-style attention for the last query, both ways.
    let q = &queries[(n - 1) * d_k..n * d_k];
    let exact = exact_attention(q, &keys, &values, n);
    let approx = lookat_attention(q, &codes, &codec, &values, n);

    let rep = FidelityReport::compare(
        &exact.out, &approx.out, &exact.weights, &approx.weights);
    println!("cosine similarity : {:.4}", rep.cosine);
    println!("KL divergence     : {:.4} nats", rep.kl);
    println!("Spearman rho      : {:.4}", rep.spearman);
    println!("top-5 overlap     : {:.2}", rep.top5);
    anyhow::ensure!(rep.cosine > 0.9, "unexpectedly low fidelity");
    println!("\nLOOKAT quickstart OK — keys were never dequantized.");
    Ok(())
}
