//! Compression–quality sweep (paper Figure 3's Pareto view) across every
//! method on all three text genres, printed as one table.
//!
//!   cargo run --release --example compression_sweep

use lookat::experiments::{EvalContext, Method};

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::build(256, 0x5EED);
    let methods = [
        Method::Fp16,
        Method::Int8,
        Method::Int4,
        Method::Lookat { m: 16 },
        Method::Lookat { m: 8 },
        Method::Lookat { m: 4 },
        Method::Lookat { m: 2 },
    ];
    println!(
        "{:<18} {:>7} {:>9} {:>9} {:>9} {:>9}",
        "method", "comp", "cosine", "KL", "rho", "top5"
    );
    let d_k = ctx.model_cfg.d_head;
    for m in methods {
        let (_, agg) = ctx.evaluate(m, 8);
        println!(
            "{:<18} {:>6.0}x {:>9.4} {:>9.4} {:>9.4} {:>9.4}",
            m.name(),
            m.compression(d_k),
            agg.cosine.0,
            agg.kl.0,
            agg.spearman.0,
            agg.top5.0
        );
    }
    println!(
        "\nLOOKAT occupies the >=8x regime with rho > 0.9 while scalar \
         quantization stops at 4x under exact byte accounting."
    );
    Ok(())
}
