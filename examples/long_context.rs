//! Long-context scaling demo (paper §4.5): LOOKAT-4 fidelity and cache
//! bytes as a single sequence grows from 64 to 1024 tokens.
//!
//!   cargo run --release --example long_context

use lookat::experiments::{EvalContext, Method};
use lookat::model::ModelConfig;

fn main() -> anyhow::Result<()> {
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>14} {:>14}",
        "L", "cosine", "KL (nats)", "spearman", "fp16 key B", "lookat key B"
    );
    for &len in &[64usize, 128, 256, 512, 1024] {
        // calibration pinned at 512 tokens so L is the only variable
        let ctx = EvalContext::build_with_calib(
            ModelConfig::gpt2_layer0(), len, 512, 0x10C);
        let (_, agg) = ctx.evaluate(Method::Lookat { m: 4 }, 16);
        let d_k = ctx.model_cfg.d_head;
        let h = ctx.model_cfg.n_head;
        println!(
            "{:>6} {:>12.4} {:>12.4} {:>12.4} {:>14} {:>14}",
            len,
            agg.cosine.0,
            agg.kl.0,
            agg.spearman.0,
            len * h * d_k * 2,
            len * h * 4,
        );
    }
    println!(
        "\nrank correlation stays high as L grows 16x — the paper's \
         long-context capability claim (Table 3)."
    );
    Ok(())
}
