//! End-to-end serving driver (the repo's headline E2E validation run —
//! results are recorded in EXPERIMENTS.md).
//!
//!   cargo run --release --example serve
//!
//! Loads a small GPT-2-geometry model, serves the same batched Poisson
//! trace under the FP16 baseline cache and the LOOKAT-4 compressed
//! cache, and reports latency / throughput / peak key-cache bytes.
//! Pass `--pjrt` to route attention through the AOT artifacts (requires
//! `make artifacts`).

use lookat::coordinator::{
    AttentionBackend, BatcherConfig, CompressionPolicy, EngineConfig,
    Router, RouterConfig, ValueBackend,
};
use lookat::model::ModelConfig;
use lookat::workload::{TraceConfig, TraceGenerator};

fn run_backend(backend: AttentionBackend) -> anyhow::Result<()> {
    run_backend_kv(backend, ValueBackend::Fp32)
}

fn run_backend_kv(
    backend: AttentionBackend,
    value_backend: ValueBackend,
) -> anyhow::Result<()> {
    let mut model = ModelConfig::gpt2_layer0();
    model.n_layer = 2;
    let mut router = Router::build(RouterConfig {
        engine: EngineConfig {
            model,
            backend,
            value_backend,
            seed: 11,
            cache_blocks: 512,
            calib_tokens: 256,
            decode_threads: 0,
            prefill_chunk: 0,
            pipeline: true,
            prefix_cache: false,
            policy: CompressionPolicy::Uniform,
            faults: Default::default(),
        },
        batcher: BatcherConfig {
            max_batch: 4,
            max_queue: 128,
            policy: lookat::coordinator::SchedulerPolicy::Fcfs,
            swap: true,
            ..BatcherConfig::default()
        },
        max_prompt_tokens: 120,
    })?;
    let trace = TraceGenerator::new(TraceConfig {
        rate: 6.0,
        num_requests: 24,
        prompt_chars: (150, 500),
        gen_tokens: (8, 24),
        seed: 33,
    })
    .generate();
    let reqs = router.tokenize_trace(&trace);
    let report = router.serve_trace(reqs)?;
    println!("{}", report.pretty());
    // persist for EXPERIMENTS.md
    let dir = lookat::experiments::report::reports_dir();
    std::fs::create_dir_all(&dir)?;
    std::fs::write(
        dir.join(format!("serve_{}.json", report.backend)),
        report.to_json().to_string_pretty(),
    )?;
    anyhow::ensure!(report.completed.len() == 24, "requests lost");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let pjrt = std::env::args().any(|a| a == "--pjrt");
    println!("== serving the same 24-request trace on each backend ==");
    if pjrt {
        run_backend(AttentionBackend::PjrtFp16)?;
        run_backend(AttentionBackend::PjrtLookat { m: 4 })?;
    } else {
        run_backend(AttentionBackend::Fp16Exact)?;
        run_backend(AttentionBackend::Lookat { m: 4, k: 256 })?;
        run_backend(AttentionBackend::Lookat { m: 2, k: 256 })?;
        // fully-compressed cache: PQ keys + PQ values, fused decode
        run_backend_kv(
            AttentionBackend::Lookat { m: 4, k: 256 },
            ValueBackend::Pq { m: 8, k: 256 },
        )?;
    }
    println!("\nserve example OK");
    Ok(())
}
