//! End-to-end coordinator integration: serving through the PJRT-executed
//! AOT artifacts (python-authored, rust-served — the 3-layer contract in
//! the actual serving loop). Skips when artifacts aren't built.

use lookat::coordinator::{
    AttentionBackend, Batcher, BatcherConfig, CompressionPolicy, Engine,
    EngineConfig, Request, ValueBackend,
};
use lookat::model::{ByteTokenizer, ModelConfig};
use lookat::runtime::default_artifacts_dir;

fn artifacts_built() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn paper_cfg(backend: AttentionBackend) -> EngineConfig {
    EngineConfig {
        model: ModelConfig::gpt2_layer0(), // H=12, d_k=64: artifact geometry
        backend,
        value_backend: ValueBackend::Fp32,
        seed: 21,
        cache_blocks: 64,
        calib_tokens: 128,
        decode_threads: 0,
        prefill_chunk: 0,
        pipeline: true,
        prefix_cache: false,
        policy: CompressionPolicy::Uniform,
        faults: Default::default(),
    }
}

#[test]
fn pjrt_fp16_backend_matches_rust_backend_tokens() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ids = ByteTokenizer::new().encode("compare the two backends");

    let mut rust_engine =
        Engine::build(&paper_cfg(AttentionBackend::Fp16Exact)).unwrap();
    rust_engine.start_seq(1, &ids).unwrap();
    let rust_toks: Vec<u32> =
        (0..4).map(|_| rust_engine.decode_one(1).unwrap()).collect();

    let mut pjrt_engine =
        Engine::build(&paper_cfg(AttentionBackend::PjrtFp16)).unwrap();
    pjrt_engine.start_seq(1, &ids).unwrap();
    let pjrt_toks: Vec<u32> =
        (0..4).map(|_| pjrt_engine.decode_one(1).unwrap()).collect();

    // same weights (same seed), same attention math — same greedy tokens
    assert_eq!(rust_toks, pjrt_toks);
}

#[test]
fn pjrt_lookat_backend_serves_and_matches_rust_lookat() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ids = ByteTokenizer::new().encode("lookat through pjrt");

    let mut rust_lk = Engine::build(&paper_cfg(AttentionBackend::Lookat {
        m: 4,
        k: 256,
    }))
    .unwrap();
    rust_lk.start_seq(1, &ids).unwrap();
    let rust_toks: Vec<u32> =
        (0..3).map(|_| rust_lk.decode_one(1).unwrap()).collect();

    let mut pjrt_lk =
        Engine::build(&paper_cfg(AttentionBackend::PjrtLookat { m: 4 }))
            .unwrap();
    pjrt_lk.start_seq(1, &ids).unwrap();
    let pjrt_toks: Vec<u32> =
        (0..3).map(|_| pjrt_lk.decode_one(1).unwrap()).collect();

    // identical codebooks (same seed/calibration) + identical ADC math
    assert_eq!(rust_toks, pjrt_toks);
}

// ---- batcher coverage (no artifacts needed: pure-rust fp16 engine) ----

fn tiny_batcher(max_batch: usize) -> Batcher {
    let engine = Engine::build(&EngineConfig {
        model: ModelConfig::test_tiny(),
        backend: AttentionBackend::Fp16Exact,
        value_backend: ValueBackend::Fp32,
        seed: 13,
        cache_blocks: 64,
        calib_tokens: 48,
        decode_threads: 2,
        prefill_chunk: 0,
        pipeline: true,
        prefix_cache: false,
        policy: CompressionPolicy::Uniform,
        faults: Default::default(),
    })
    .unwrap();
    Batcher::new(
        engine,
        BatcherConfig {
            max_batch,
            max_queue: 32,
            policy: lookat::coordinator::SchedulerPolicy::Fcfs,
            ..BatcherConfig::default()
        },
    )
}

fn req(id: u64, gen: usize) -> Request {
    Request {
        id,
        prompt: ByteTokenizer::new().encode("integration prompt"),
        max_new_tokens: gen,
        arrival_s: 0.0,
        timeout_ms: None,
    }
}

#[test]
fn full_batch_drains_fifo() {
    // submit 2x the batch width with staggered decode lengths so every
    // completion lands on its own tick; the queue must drain FCFS: ids
    // admitted in submission order and completed in submission order
    let mut b = tiny_batcher(3);
    for i in 0..6u64 {
        assert!(b.submit(req(i, 1 + i as usize)));
    }
    assert_eq!(b.queued(), 6);
    let mut now = 0.0;
    let mut iters = 0;
    while !b.idle() {
        b.admit(now);
        assert!(b.active() <= 3, "batch overflow");
        let produced = b.step(now).unwrap();
        assert!(produced <= 3, "one token per active per tick");
        now += 0.01;
        iters += 1;
        assert!(iters < 500, "batcher failed to drain");
    }
    assert_eq!(b.completed.len(), 6);
    let order: Vec<u64> = b.completed.iter().map(|c| c.id).collect();
    assert_eq!(order, vec![0, 1, 2, 3, 4, 5], "FIFO drain order");
    // admission times are monotone in submission order too
    for w in b.completed.windows(2) {
        assert!(
            w[1].admitted_s >= w[0].admitted_s - 1e-12,
            "admission must be FCFS"
        );
    }
    assert_eq!(b.rejected.len(), 0);
    assert_eq!(b.engine().cache_stats().tokens, 0, "cache fully released");
}

#[test]
fn empty_tick_does_not_spin() {
    // admit + step on an empty batcher must be cheap no-ops: no tokens,
    // no completions, no cache churn — the serving loop's idle path
    let mut b = tiny_batcher(2);
    assert!(b.idle());
    let t0 = std::time::Instant::now();
    for tick in 0..100 {
        b.admit(tick as f64);
        let produced = b.step(tick as f64).unwrap();
        assert_eq!(produced, 0, "empty tick produced tokens");
    }
    assert!(b.idle());
    assert_eq!(b.queued(), 0);
    assert_eq!(b.active(), 0);
    assert_eq!(b.completed.len(), 0);
    assert_eq!(b.engine().cache_stats().tokens, 0);
    // 100 empty ticks must be effectively instantaneous (no decode work,
    // no sleeping, no busy model calls)
    assert!(
        t0.elapsed() < std::time::Duration::from_millis(500),
        "empty ticks took {:?}",
        t0.elapsed()
    );
}

#[test]
fn pjrt_backend_handles_cache_growth_past_first_artifact() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // prompt + decode pushes past L=128 so the engine must switch to the
    // L=512 artifact mid-sequence
    let long_text = "x".repeat(140);
    let ids = ByteTokenizer::new().encode(&long_text);
    let mut e =
        Engine::build(&paper_cfg(AttentionBackend::PjrtFp16)).unwrap();
    e.start_seq(7, &ids).unwrap();
    for _ in 0..4 {
        e.decode_one(7).unwrap();
    }
    assert!(e.cache_stats().tokens > 128);
}
