//! End-to-end coordinator integration: serving through the PJRT-executed
//! AOT artifacts (python-authored, rust-served — the 3-layer contract in
//! the actual serving loop). Skips when artifacts aren't built.

use lookat::coordinator::{AttentionBackend, Engine, EngineConfig};
use lookat::model::{ByteTokenizer, ModelConfig};
use lookat::runtime::default_artifacts_dir;

fn artifacts_built() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn paper_cfg(backend: AttentionBackend) -> EngineConfig {
    EngineConfig {
        model: ModelConfig::gpt2_layer0(), // H=12, d_k=64: artifact geometry
        backend,
        seed: 21,
        cache_blocks: 64,
        calib_tokens: 128,
    }
}

#[test]
fn pjrt_fp16_backend_matches_rust_backend_tokens() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ids = ByteTokenizer::new().encode("compare the two backends");

    let mut rust_engine =
        Engine::build(&paper_cfg(AttentionBackend::Fp16Exact)).unwrap();
    rust_engine.start_seq(1, &ids).unwrap();
    let rust_toks: Vec<u32> =
        (0..4).map(|_| rust_engine.decode_one(1).unwrap()).collect();

    let mut pjrt_engine =
        Engine::build(&paper_cfg(AttentionBackend::PjrtFp16)).unwrap();
    pjrt_engine.start_seq(1, &ids).unwrap();
    let pjrt_toks: Vec<u32> =
        (0..4).map(|_| pjrt_engine.decode_one(1).unwrap()).collect();

    // same weights (same seed), same attention math — same greedy tokens
    assert_eq!(rust_toks, pjrt_toks);
}

#[test]
fn pjrt_lookat_backend_serves_and_matches_rust_lookat() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ids = ByteTokenizer::new().encode("lookat through pjrt");

    let mut rust_lk = Engine::build(&paper_cfg(AttentionBackend::Lookat {
        m: 4,
        k: 256,
    }))
    .unwrap();
    rust_lk.start_seq(1, &ids).unwrap();
    let rust_toks: Vec<u32> =
        (0..3).map(|_| rust_lk.decode_one(1).unwrap()).collect();

    let mut pjrt_lk =
        Engine::build(&paper_cfg(AttentionBackend::PjrtLookat { m: 4 }))
            .unwrap();
    pjrt_lk.start_seq(1, &ids).unwrap();
    let pjrt_toks: Vec<u32> =
        (0..3).map(|_| pjrt_lk.decode_one(1).unwrap()).collect();

    // identical codebooks (same seed/calibration) + identical ADC math
    assert_eq!(rust_toks, pjrt_toks);
}

#[test]
fn pjrt_backend_handles_cache_growth_past_first_artifact() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // prompt + decode pushes past L=128 so the engine must switch to the
    // L=512 artifact mid-sequence
    let long_text = "x".repeat(140);
    let ids = ByteTokenizer::new().encode(&long_text);
    let mut e =
        Engine::build(&paper_cfg(AttentionBackend::PjrtFp16)).unwrap();
    e.start_seq(7, &ids).unwrap();
    for _ in 0..4 {
        e.decode_one(7).unwrap();
    }
    assert!(e.cache_stats().tokens > 128);
}
