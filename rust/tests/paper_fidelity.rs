//! Paper-fidelity integration suite: the executable form of the paper's
//! headline claims, deterministic and self-contained.
//!
//! * rank correlation ρ > 0.95 between `lookat_attention` and
//!   `exact_attention` score vectors at the 64× (m=2), 32× (m=4) and
//!   16× (m=8) compression configurations, K = 256, across sequence
//!   lengths {128, 512, 1024} (paper abstract + Table 3);
//! * output-fidelity floors at m ∈ {4, 8} (paper Table 1's ≥ 0.95
//!   cosine band);
//! * bit-stability: two end-to-end runs of the full train→encode→attend
//!   pipeline produce identical f32 bits (the property every experiment
//!   table depends on for reproducibility).
//!
//! Keys are drawn from a tight Gaussian-mixture fixture
//! (`testkit::fixtures`): the low-intrinsic-dimension regime the paper
//! assumes of transformer keys (§1), with codebooks trained on a
//! *held-out* calibration set sharing the mixture (§5.1's deployment
//! setting). Values and queries are iid normal.

use lookat::attention::{exact_attention, lookat_attention};
use lookat::pq::{LookupTable, PqCodec, TrainOpts, NUM_CENTROIDS};
use lookat::testkit::{assertions, fixtures};

const D_K: usize = 64;
const N_CLUSTERS: usize = 64;
const SIGMA: f32 = 0.02;
const CALIB_N: usize = 1024;
const LENS: [usize; 3] = [128, 512, 1024];
const SEED: u64 = 0x1007AB;

/// One (m, L) evaluation: raw ADC/exact score vectors plus the attention
/// outputs for the last of three probe queries.
struct Eval {
    rho_min: f64,
    cosine_min: f64,
    /// concatenated ADC scores across probes (bit-stability payload)
    scores_apx: Vec<f32>,
    out_apx: Vec<f32>,
}

/// Train once on held-out calibration keys, then evaluate ADC vs exact
/// attention at every requested length. Pure function of (m, seed).
fn run_pipeline(m: usize, seed: u64) -> Vec<(usize, Eval)> {
    run_pipeline_with(m, NUM_CENTROIDS, N_CLUSTERS, seed)
}

/// [`run_pipeline`] generalized over the codebook width K and the
/// fixture's cluster count. The K = 256 harness runs 64 clusters (4×
/// centroid coverage per subspace); K = 16 runs scale the cluster
/// count with K so both sit in the same PQ-favorable coverage regime
/// the paper assumes of transformer keys (§1, §5.1).
fn run_pipeline_with(
    m: usize,
    k: usize,
    n_clusters: usize,
    seed: u64,
) -> Vec<(usize, Eval)> {
    let centers = fixtures::cluster_centers(n_clusters, D_K, seed);
    let calib = fixtures::keys_from_centers(
        &centers, n_clusters, CALIB_N, D_K, SIGMA, seed ^ 0xCA11B);
    let codec = PqCodec::train(
        &calib,
        D_K,
        m,
        k,
        &TrainOpts { iters: 10, seed: seed ^ 0xC0DE, tol: 1e-3 },
    );
    // byte codes hit the paper's Table 1 ratios; nibble-packed K = 16
    // doubles the ratio again at the same m
    let want_ratio = if codec.packed() {
        (D_K * 4 / m) as f64
    } else {
        (D_K * 2 / m) as f64
    };
    assert_eq!(
        codec.compression_ratio(),
        want_ratio,
        "m={m} K={k} must give a {want_ratio}x ratio"
    );

    LENS.iter()
        .map(|&len| {
            let keys = fixtures::keys_from_centers(
                &centers, n_clusters, len, D_K, SIGMA,
                seed ^ 0xE7A1 ^ ((len as u64) << 16));
            let values =
                fixtures::gaussian_keys(len, D_K, seed ^ len as u64);
            let codes = codec.encode_batch(&keys, len);
            assert_eq!(codes.len(), len * m);
            assert!(
                codes.iter().all(|&c| (c as usize) < k),
                "codes must stay below K"
            );

            let probes = fixtures::queries(3, D_K, seed ^ 0x9E_17);
            let mut rho_min = f64::INFINITY;
            let mut cosine_min = f64::INFINITY;
            let mut scores_apx = Vec::new();
            let mut out_apx = Vec::new();
            for p in 0..3 {
                let q = &probes[p * D_K..(p + 1) * D_K];
                let exact = exact_attention(q, &keys, &values, len);
                let approx =
                    lookat_attention(q, &codes, &codec, &values, len);

                // raw score vectors (pre-softmax rank structure): ADC
                // scores vs exact dot products
                let lut = LookupTable::build(q, &codec.codebook);
                let s_apx = lut.scores(&codes, len);
                let s_ref: Vec<f32> = (0..len)
                    .map(|l| {
                        lookat::tensor::dot(
                            q, &keys[l * D_K..(l + 1) * D_K])
                    })
                    .collect();
                let ctx = format!("m={m} L={len} probe={p}");
                let rho =
                    assertions::assert_spearman_at_least(
                        &s_ref, &s_apx, 0.95, &ctx);
                let cos = assertions::assert_cosine_at_least(
                    &exact.out, &approx.out, 0.90, &ctx);
                rho_min = rho_min.min(rho);
                cosine_min = cosine_min.min(cos);
                scores_apx.extend_from_slice(&s_apx);
                out_apx = approx.out;
            }
            (len, Eval { rho_min, cosine_min, scores_apx, out_apx })
        })
        .collect()
}

#[test]
fn rank_correlation_exceeds_0_95_at_paper_compressions() {
    // 64x (m=2), 32x (m=4), 16x (m=8) — acceptance floor is rho > 0.95
    // at every length and every probe query; the per-probe assertion
    // already enforces it, this test keeps the aggregate visible.
    for m in [2usize, 4, 8] {
        for (len, eval) in run_pipeline(m, SEED) {
            assert!(
                eval.rho_min > 0.95,
                "m={m} L={len}: min rho {:.4}",
                eval.rho_min
            );
        }
    }
}

#[test]
fn output_fidelity_floors_at_m4_and_m8() {
    // Table 1's band: LOOKAT-4/8 keep attention outputs within a ≥0.95
    // cosine of the FP16 oracle on PQ-favorable keys.
    for m in [4usize, 8] {
        for (len, eval) in run_pipeline(m, SEED) {
            assert!(
                eval.cosine_min > 0.95,
                "m={m} L={len}: min cosine {:.4}",
                eval.cosine_min
            );
        }
    }
}

#[test]
fn packed_k16_with_doubled_m_holds_the_rho_floor() {
    // The 4-bit fast-scan trade at matched bytes/token: (2m, K=16)
    // nibble codes spend exactly the (m, K=256) byte budget — m=4
    // packed is the 64x headline's equal-bit partner, m=8 packed the
    // 32x config's — and in the coverage-matched mixture regime they
    // keep the paper's rho > 0.95 floor at every length and probe
    // (each probe asserts it inside the pipeline; the aggregate stays
    // visible here).
    for (m, partner_m) in [(4usize, 2usize), (8, 4)] {
        for (len, eval) in run_pipeline_with(m, 16, 16, SEED) {
            assert!(
                eval.rho_min > 0.95,
                "K=16 m={m} (equal-bit partner of m={partner_m}, \
                 K=256) L={len}: min rho {:.4}",
                eval.rho_min
            );
        }
    }
}

#[test]
fn pipeline_is_bit_stable_across_runs() {
    // Run the full train -> encode -> attend pipeline twice at m=4 and
    // require *identical f32 bits* everywhere — this is what makes the
    // experiment tables regenerate bit-identically.
    let a = run_pipeline(4, SEED);
    let b = run_pipeline(4, SEED);
    assert_eq!(a.len(), b.len());
    for ((len_a, ea), (len_b, eb)) in a.iter().zip(&b) {
        assert_eq!(len_a, len_b);
        assert_eq!(ea.rho_min.to_bits(), eb.rho_min.to_bits());
        assert_eq!(ea.cosine_min.to_bits(), eb.cosine_min.to_bits());
        assert_eq!(ea.scores_apx.len(), eb.scores_apx.len());
        for (x, y) in ea.scores_apx.iter().zip(&eb.scores_apx) {
            assert_eq!(x.to_bits(), y.to_bits(), "ADC scores drifted");
        }
        for (x, y) in ea.out_apx.iter().zip(&eb.out_apx) {
            assert_eq!(x.to_bits(), y.to_bits(), "outputs drifted");
        }
    }
}

#[test]
fn golden_fixture_anchors_the_m4_scores() {
    // Golden-value regression: the first 32 ADC scores of the m=4,
    // L=128 configuration. On a checkout without the fixture the run
    // records it AND immediately re-opens the file to do a real
    // bit-exact comparison (so even the recording run verifies the
    // round trip); later runs compare against disk. Re-bless with
    // LOOKAT_BLESS=1 or by deleting the file.
    let evals = run_pipeline(4, SEED);
    let (len, eval) = &evals[0];
    assert_eq!(*len, 128);
    let head = &eval.scores_apx[..32];
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/paper_fidelity_golden.json");
    let mut golden = lookat::testkit::Golden::open(&path).unwrap();
    let compared = golden.check_or_record("m4_l128_scores", head, 0.0)
        .unwrap();
    golden.save().unwrap();
    if !compared {
        eprintln!("golden recorded at {path:?} (first run)");
        // recording run: reload from disk and compare for real — the
        // golden file must round-trip the exact bits it just captured
        let mut reread =
            lookat::testkit::Golden::open_with(&path, false).unwrap();
        assert!(
            reread.check_or_record("m4_l128_scores", head, 0.0).unwrap(),
            "re-opened golden must compare, not re-record"
        );
    }
}

#[test]
fn combined_64x_key_value_pq_serving_path() {
    // The §5.2 extension at the headline budget: PQ keys *and* PQ
    // values, combined (key+value) compression exactly 64× — served
    // through the real path (paged KvCache in ValueStorage::Pq mode,
    // LookatKernel's block-resident ADC scan + fused blocked weighted
    // decode). Asserts the paper's ρ > 0.95 rank-correlation floor on
    // raw scores, bit-parity of the fused kernel against the
    // lookat_kv_attention primitive, and an output-cosine floor vs the
    // FP16 oracle. Keys and values both follow the low-intrinsic-
    // dimension mixture regime (§1), codebooks train on held-out
    // calibration draws (§5.1).
    use lookat::attention::kernel::LookatKernel;
    use lookat::attention::{AttentionKernel, DecodePlan, WorkItem};
    use lookat::kvcache::{
        KeyStorage, KvCache, ValueStorage, BLOCK_TOKENS,
    };

    let m = 2; // 2 B keys + 2 B values vs 256 B FP16 K+V → 64×
    let key_centers = fixtures::cluster_centers(N_CLUSTERS, D_K, SEED);
    let val_centers =
        fixtures::cluster_centers(N_CLUSTERS, D_K, SEED ^ 0x55);
    let key_calib = fixtures::keys_from_centers(
        &key_centers, N_CLUSTERS, CALIB_N, D_K, SIGMA, SEED ^ 0xCA11B);
    let val_calib = fixtures::keys_from_centers(
        &val_centers, N_CLUSTERS, CALIB_N, D_K, SIGMA, SEED ^ 0xCA11C);
    let opts = |salt: u64| TrainOpts {
        iters: 10,
        seed: SEED ^ 0xC0DE ^ salt,
        tol: 1e-3,
    };
    let kc = PqCodec::train(&key_calib, D_K, m, NUM_CENTROIDS, &opts(0));
    let vc = PqCodec::train(&val_calib, D_K, m, NUM_CENTROIDS, &opts(1));
    let fp16_kv_bytes = (2 * D_K * 2) as f64;
    assert_eq!(
        fp16_kv_bytes
            / (kc.bytes_per_token() + vc.bytes_per_token()) as f64,
        64.0,
        "combined key+value budget must be the paper's 64x"
    );

    for len in [128usize, 512] {
        let keys = fixtures::keys_from_centers(
            &key_centers, N_CLUSTERS, len, D_K, SIGMA,
            SEED ^ 0xE7A1 ^ ((len as u64) << 16));
        let values = fixtures::keys_from_centers(
            &val_centers, N_CLUSTERS, len, D_K, SIGMA,
            SEED ^ 0xF00D ^ ((len as u64) << 16));

        // serving-path storage: both sides encoded at append, raw
        // vectors never stored
        let mut cache = KvCache::new(
            1,
            D_K,
            len.div_ceil(BLOCK_TOKENS),
            KeyStorage::pq(vec![kc.clone()]).unwrap(),
            ValueStorage::pq(vec![vc.clone()]).unwrap(),
        );
        cache.create_seq(0).unwrap();
        for t in 0..len {
            cache
                .append(
                    0,
                    &keys[t * D_K..(t + 1) * D_K],
                    &values[t * D_K..(t + 1) * D_K],
                )
                .unwrap();
        }
        let mut kcodes = Vec::new();
        let mut vcodes = Vec::new();
        cache.gather_codes_into(0, 0, &mut kcodes).unwrap();
        cache.gather_value_codes_into(0, 0, &mut vcodes).unwrap();

        let probes = fixtures::queries(3, D_K, SEED ^ 0x9E_17);
        for p in 0..3 {
            let q = &probes[p * D_K..(p + 1) * D_K];
            let ctx = format!("kv-64x L={len} probe={p}");

            // paper floor: raw-score rank correlation at combined 64×
            let lut = LookupTable::build(q, &kc.codebook);
            let s_apx = lut.scores(&kcodes, len);
            let s_ref: Vec<f32> = (0..len)
                .map(|l| {
                    lookat::tensor::dot(q, &keys[l * D_K..(l + 1) * D_K])
                })
                .collect();
            assertions::assert_spearman_at_least(
                &s_ref, &s_apx, 0.95, &ctx);

            // fused serving decode == §5.2 primitive, bit for bit —
            // and it never touched a raw value
            let items = vec![WorkItem {
                seq: 0,
                head: 0,
                q,
                rows: 1,
                prefixes: None,
            }];
            let plan = DecodePlan {
                cache: &cache,
                d_k: D_K,
                threads: 1,
                timers: None,
                items,
            };
            let outs = LookatKernel.decode_batch(&plan).unwrap();
            let want = lookat::attention::lookat_kv_attention(
                q, &kcodes, &kc, &vcodes, &vc, len);
            assert_eq!(outs[0].out, want.out, "{ctx}");
            assert_eq!(outs[0].weights, want.weights, "{ctx}");

            // end-to-end output fidelity vs the FP16 oracle
            let exact = exact_attention(q, &keys, &values, len);
            assertions::assert_cosine_at_least(
                &exact.out, &outs[0].out, 0.85, &ctx);
        }
    }
}

#[test]
fn calibrated_budget_meets_or_beats_uniform_rho_at_equal_bits() {
    // The CompressionPolicy acceptance claim on the paper fixture: four
    // heads of *heterogeneous* difficulty (per-head cluster noise from
    // tight to diffuse), candidate ladder m in {2, 4, 8} at K = 256,
    // and a total budget of exactly the uniform m=4 spend
    // (4 heads x 4 x 8 = 128 bits/token). The greedy allocator must
    // stay within budget, resolve deterministically, and achieve a
    // worst-head rank correlation at least as good as uniform m=4 at
    // the same total bits/token (the safety net in `allocate_budget`
    // guarantees it can never do worse on the error proxy; this checks
    // the claim holds through to the measured rho).
    use lookat::coordinator::policy::{
        allocate_budget, BudgetItem, Side,
    };

    let sigmas = [0.02f32, 0.05, 0.2, 0.6];
    let heads: Vec<(Vec<f32>, Vec<f32>)> = sigmas
        .iter()
        .enumerate()
        .map(|(h, &sigma)| {
            let centers = fixtures::cluster_centers(
                N_CLUSTERS, D_K, SEED ^ (h as u64));
            let calib = fixtures::keys_from_centers(
                &centers, N_CLUSTERS, CALIB_N, D_K, sigma,
                SEED ^ 0xCA11B ^ ((h as u64) << 8));
            let eval = fixtures::keys_from_centers(
                &centers, N_CLUSTERS, 256, D_K, sigma,
                SEED ^ 0xE7A1 ^ ((h as u64) << 8));
            (calib, eval)
        })
        .collect();

    // candidate codecs per head, errors = summed per-subspace k-means
    // MSE (the engine's calibration error proxy)
    let ms = [2usize, 4, 8];
    let codecs: Vec<Vec<PqCodec>> = heads
        .iter()
        .enumerate()
        .map(|(h, (calib, _))| {
            ms.iter()
                .map(|&m| {
                    PqCodec::train(calib, D_K, m, NUM_CENTROIDS, &TrainOpts {
                        iters: 10,
                        seed: SEED ^ 0xC0DE ^ (h as u64),
                        tol: 1e-3,
                    })
                })
                .collect()
        })
        .collect();
    let items: Vec<BudgetItem> = codecs
        .iter()
        .enumerate()
        .map(|(h, cands)| BudgetItem {
            layer: 0,
            head: h,
            side: Side::Key,
            code_bits: 8,
            candidates: cands
                .iter()
                .zip(&ms)
                .map(|(c, &m)| (m, c.train_mse.iter().sum::<f64>()))
                .collect(),
        })
        .collect();

    let budget = 4 * 4 * 8; // == uniform m=4 spend
    let choice = allocate_budget(&items, budget).unwrap();
    let spent: usize = items
        .iter()
        .zip(&choice)
        .map(|(it, &c)| it.candidates[c].0 * it.code_bits)
        .sum();
    assert!(spent <= budget, "allocation spent {spent} > {budget}");
    assert_eq!(
        allocate_budget(&items, budget).unwrap(),
        choice,
        "allocation must be deterministic"
    );

    // worst-head rho under an assignment (3 probes per head)
    let min_rho = |assign: &dyn Fn(usize) -> usize| -> f64 {
        let mut worst = f64::INFINITY;
        for (h, (_, eval)) in heads.iter().enumerate() {
            let codec = &codecs[h][assign(h)];
            let codes = codec.encode_batch(eval, 256);
            let probes =
                fixtures::queries(3, D_K, SEED ^ 0x9E17 ^ (h as u64));
            for p in 0..3 {
                let q = &probes[p * D_K..(p + 1) * D_K];
                let s_apx = LookupTable::build(q, &codec.codebook)
                    .scores(&codes, 256);
                let s_ref: Vec<f32> = (0..256)
                    .map(|l| {
                        lookat::tensor::dot(
                            q, &eval[l * D_K..(l + 1) * D_K])
                    })
                    .collect();
                worst = worst
                    .min(assertions::spearman(&s_ref, &s_apx));
            }
        }
        worst
    };
    let uniform_idx = ms.iter().position(|&m| m == 4).unwrap();
    let rho_uniform = min_rho(&|_| uniform_idx);
    let rho_calibrated = min_rho(&|h| choice[h]);
    assert!(
        rho_calibrated + 0.01 >= rho_uniform,
        "calibrated min-rho {rho_calibrated:.4} must meet or beat \
         uniform m=4 min-rho {rho_uniform:.4} at {budget} bits/token"
    );
}

#[test]
fn norm_pruning_keeps_attention_parity_within_the_mass_bound() {
    // The pruning-policy parity claim, in its deterministic form: drop
    // the frac-quantile lowest-L2-norm keys (exactly what the engine
    // does at append time) and attend over the survivors. The pruned
    // output o' differs from the full output o by at most
    // 2·w·max||v||, where w is the softmax mass the full attention put
    // on the pruned set — an algebraic bound, checked bit-level here,
    // plus generous sanity floors on the pruned fraction and on the
    // mass itself (low-norm keys must not be where attention lives).
    use lookat::coordinator::policy::prune_threshold;

    let frac = 0.1f64;
    for len in [128usize, 512] {
        let centers = fixtures::cluster_centers(N_CLUSTERS, D_K, SEED);
        let keys = fixtures::keys_from_centers(
            &centers, N_CLUSTERS, len, D_K, SIGMA,
            SEED ^ 0xE7A1 ^ ((len as u64) << 16));
        let values =
            fixtures::gaussian_keys(len, D_K, SEED ^ len as u64);
        let norms: Vec<f32> = (0..len)
            .map(|l| {
                keys[l * D_K..(l + 1) * D_K]
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    .sqrt()
            })
            .collect();
        let thresh = prune_threshold(&norms, frac);
        let survivors: Vec<usize> =
            (0..len).filter(|&l| norms[l] >= thresh).collect();
        let pruned = len - survivors.len();
        let expect = (frac * len as f64) as usize;
        assert!(
            pruned >= expect / 2 && pruned <= expect,
            "L={len}: pruned {pruned}, expected about {expect}"
        );

        let mut skeys = Vec::with_capacity(survivors.len() * D_K);
        let mut svals = Vec::with_capacity(survivors.len() * D_K);
        for &l in &survivors {
            skeys.extend_from_slice(&keys[l * D_K..(l + 1) * D_K]);
            svals.extend_from_slice(&values[l * D_K..(l + 1) * D_K]);
        }
        let vmax = (0..len)
            .map(|l| {
                values[l * D_K..(l + 1) * D_K]
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    .sqrt()
            })
            .fold(0.0f32, f32::max);

        let probes = fixtures::queries(3, D_K, SEED ^ 0x9E_17);
        let mut mass_sum = 0.0f64;
        for p in 0..3 {
            let q = &probes[p * D_K..(p + 1) * D_K];
            let full = exact_attention(q, &keys, &values, len);
            let kept = exact_attention(
                q, &skeys, &svals, survivors.len());
            let w_pruned: f32 = (0..len)
                .filter(|l| !survivors.contains(l))
                .map(|l| full.weights[l])
                .sum();
            mass_sum += w_pruned as f64;
            let dist = full
                .out
                .iter()
                .zip(&kept.out)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            assert!(
                dist <= 2.0 * w_pruned * vmax + 1e-3,
                "L={len} probe={p}: ||o' - o|| = {dist:.5} exceeds the \
                 mass bound 2·{w_pruned:.5}·{vmax:.3}"
            );
        }
        assert!(
            mass_sum / 3.0 < 0.8,
            "L={len}: mean pruned-set softmax mass {:.3} — low-norm \
             keys are carrying the attention",
            mass_sum / 3.0
        );
    }
}

#[test]
fn degradation_tracks_the_o_dk_over_mk_bound() {
    // Proposition 1 direction check on the fixture: the rank-correlation
    // deficit (1 - rho) must not grow as m·K grows. m=4 halves d_k/(mK)
    // vs m=2 (0.0625 vs 0.125 at K=256), so its worst-case deficit
    // should be no larger (small jitter tolerated).
    let rho_at = |m: usize| {
        run_pipeline(m, SEED)
            .iter()
            .map(|(_, e)| e.rho_min)
            .fold(f64::INFINITY, f64::min)
    };
    let d4 = 1.0 - rho_at(4);
    let d2 = 1.0 - rho_at(2);
    assert!(
        d4 <= d2 + 0.02,
        "deficit must shrink (or hold) as m grows: 1-rho m=4 {d4:.4} vs \
         m=2 {d2:.4}"
    );
}
