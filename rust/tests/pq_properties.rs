//! Property tests for the PQ invariants, driven by the crate's
//! `util::proptest` mini-framework (`lookat::prop_assert!`):
//!
//! 1. K-Means is deterministic for a fixed `Pcg32` seed;
//! 2. `PqCodec::encode_batch` codes are always `< K`;
//! 3. ADC lookup scores equal naive decode-then-dot-product within 1e-4;
//! 4. `pq::values::weighted_decode` (and its lane-resident sibling)
//!    equals the naive decode-then-weighted-sum within 1e-4;
//! 5. the subspace-major fast-scan (`LookupTable::scores_lanes`) and
//!    the grouped value weighted-decode
//!    (`pq::values::weighted_decode_lanes`) are *bit-identical* to the
//!    flat token-major references across uneven group sizes, partial
//!    tail groups and every unrolled `m` ∈ {2, 4, 8, 16} plus the
//!    generic path;
//! 6. the nibble-packed K ≤ 16 variants
//!    (`LookupTable::scores_lanes_packed` and
//!    `pq::values::weighted_decode_lanes_packed`) — dispatched *and*
//!    pinned-scalar — are bit-identical to the same flat references
//!    across odd token counts (partial low-nibble tails) and mid-stream
//!    causal truncation of the packed lanes.

use lookat::pq::kmeans::kmeans;
use lookat::pq::{LookupTable, PqCodec, TrainOpts};
use lookat::prop_assert;
use lookat::testkit::fixtures::{interleave_lanes, interleave_lanes_packed};
use lookat::util::proptest::Gen;
use lookat::util::rng::Pcg32;

/// Random but structurally valid (keys, d_k, m, k) tuple.
fn random_pq_case(g: &mut Gen) -> (Vec<f32>, usize, usize, usize) {
    let m = *g.choose(&[2usize, 4, 8]);
    let d_sub = *g.choose(&[4usize, 8]);
    let d_k = m * d_sub;
    let k = *g.choose(&[4usize, 8, 16, 32]);
    let n = g.usize_in(k.max(16), 96);
    // scaled-down values keep dot magnitudes small so the 1e-4 ADC
    // tolerance is a genuine relative bound
    let keys: Vec<f32> =
        g.normal_vec(n * d_k).iter().map(|v| v * 0.5).collect();
    (keys, d_k, m, k)
}

#[test]
fn kmeans_is_deterministic_for_fixed_seed() {
    prop_assert!("kmeans-deterministic", 25, |g: &mut Gen| {
        let dim = g.usize_in(2, 8);
        let k = g.usize_in(2, 12);
        let n = g.usize_in(k, 80);
        let pts = g.normal_vec(n * dim);
        let seed = g.rng.next_u64();
        let a = kmeans(&pts, dim, k, 15, 1e-6, &mut Pcg32::seed(seed));
        let b = kmeans(&pts, dim, k, 15, 1e-6, &mut Pcg32::seed(seed));
        if a.centroids != b.centroids {
            return Err(format!(
                "centroids diverged for seed {seed:#x}"
            ));
        }
        if a.inertia.to_bits() != b.inertia.to_bits() {
            return Err(format!(
                "inertia diverged for seed {seed:#x}: {} vs {}",
                a.inertia, b.inertia
            ));
        }
        if a.iters_run != b.iters_run {
            return Err("iteration count diverged".into());
        }
        Ok(())
    });
}

#[test]
fn encode_batch_codes_always_below_k() {
    prop_assert!("codes-below-k", 25, |g: &mut Gen| {
        let (keys, d_k, m, k) = random_pq_case(g);
        let n = keys.len() / d_k;
        let codec = PqCodec::train(
            &keys,
            d_k,
            m,
            k,
            &TrainOpts { iters: 6, seed: g.rng.next_u64(), tol: 1e-4 },
        );
        let codes = codec.encode_batch(&keys, n);
        if codes.len() != n * m {
            return Err(format!(
                "expected {} codes, got {}",
                n * m,
                codes.len()
            ));
        }
        match codes.iter().position(|&c| c as usize >= k) {
            Some(i) => Err(format!(
                "code {} at {i} >= K={k} (m={m}, d_k={d_k})",
                codes[i]
            )),
            None => Ok(()),
        }
    });
}

#[test]
fn adc_scores_equal_decode_then_dot_within_1e4() {
    prop_assert!("adc-equals-decode-dot", 25, |g: &mut Gen| {
        let (keys, d_k, m, k) = random_pq_case(g);
        let n = keys.len() / d_k;
        let codec = PqCodec::train(
            &keys,
            d_k,
            m,
            k,
            &TrainOpts { iters: 6, seed: g.rng.next_u64(), tol: 1e-4 },
        );
        let codes = codec.encode_batch(&keys, n);
        let q: Vec<f32> =
            g.normal_vec(d_k).iter().map(|v| v * 0.5).collect();
        let lut = LookupTable::build(&q, &codec.codebook);
        let batch = lut.scores(&codes, n);
        for l in 0..n {
            let code = &codes[l * m..(l + 1) * m];
            let naive = lookat::tensor::dot(&q, &codec.decode(code));
            let scalar = lut.score(code);
            if (scalar - naive).abs() > 1e-4 {
                return Err(format!(
                    "l={l}: lut.score {scalar} vs decode-dot {naive} \
                     (m={m}, k={k}, d_k={d_k})"
                ));
            }
            if (batch[l] - naive).abs() > 1e-4 {
                return Err(format!(
                    "l={l}: batched {} vs decode-dot {naive}",
                    batch[l]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn weighted_decode_equals_decode_then_weighted_sum_within_1e4() {
    // the §5.2 transposed aggregation: Σ_l α_l · decode(codes_l) must
    // match the scatter-accumulate + centroid-matvec path on arbitrary
    // (values, weights) draws — including zero weights, which the
    // scatter path skips outright — and the blocked variant must match
    // the flat one bit for bit
    prop_assert!("weighted-decode-equals-dense", 25, |g: &mut Gen| {
        let (values, d_k, m, k) = random_pq_case(g);
        let n = values.len() / d_k;
        let codec = PqCodec::train(
            &values,
            d_k,
            m,
            k,
            &TrainOpts { iters: 6, seed: g.rng.next_u64(), tol: 1e-4 },
        );
        let codes = codec.encode_batch(&values, n);
        // softmax-like weights with a sprinkle of exact zeros
        let mut weights: Vec<f32> = (0..n)
            .map(|_| if g.bool() { g.rng.next_f32() } else { 0.0 })
            .collect();
        let s: f32 = weights.iter().sum();
        if s > 0.0 {
            for w in weights.iter_mut() {
                *w /= s;
            }
        }
        let got =
            lookat::pq::values::weighted_decode(&weights, &codes, &codec);
        let mut want = vec![0.0f32; d_k];
        for (l, &w) in weights.iter().enumerate() {
            let v = codec.decode(&codes[l * m..(l + 1) * m]);
            for (o, x) in want.iter_mut().zip(&v) {
                *o += w * x;
            }
        }
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            if (a - b).abs() > 1e-4 {
                return Err(format!(
                    "dim {i}: weighted_decode {a} vs dense {b} \
                     (n={n}, m={m}, k={k})"
                ));
            }
        }
        let bt = g.usize_in(1, n);
        let lanes = interleave_lanes(&codes, m, bt);
        let blocked = lookat::pq::values::weighted_decode_lanes(
            &weights,
            lanes.iter().map(|(l, n)| (&l[..], *n)),
            &codec,
        );
        if got.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            != blocked.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        {
            return Err(format!(
                "lane decode diverged from flat (bt={bt})"
            ));
        }
        Ok(())
    });
}

/// Every subspace count the scan specializes on, plus one that takes
/// the generic path (m = 6: d_k = 6·d_sub, never unrolled).
const SCAN_MS: [usize; 5] = [2, 4, 8, 16, 6];

#[test]
fn lane_scan_bit_identical_to_flat_for_every_m() {
    // the fast-scan layout contract: subspace-major lanes with uneven
    // group sizes and a partial tail must score *bit-identically* to
    // the token-major reference, because each token still accumulates
    // its subspaces in order 0..m
    prop_assert!("lane-scan-bit-identical", 30, |g: &mut Gen| {
        let m = *g.choose(&SCAN_MS);
        let d_sub = *g.choose(&[2usize, 4, 8]);
        let d_k = m * d_sub;
        let k = *g.choose(&[8usize, 16, 64]);
        let n = g.usize_in(1, 150);
        let keys: Vec<f32> =
            g.normal_vec(n * d_k).iter().map(|v| v * 0.5).collect();
        let codec = PqCodec::train(
            &keys,
            d_k,
            m,
            k,
            &TrainOpts { iters: 4, seed: g.rng.next_u64(), tol: 1e-3 },
        );
        let codes = codec.encode_batch(&keys, n);
        let q: Vec<f32> =
            g.normal_vec(d_k).iter().map(|v| v * 0.5).collect();
        let lut = LookupTable::build(&q, &codec.codebook);
        let flat = lut.scores(&codes, n);
        // group size drawn to cover: 1 (degenerate), < n (partial
        // tail), >= n (single partial group)
        let group = g.usize_in(1, n + 8);
        let lanes = interleave_lanes(&codes, m, group);
        let mut out = Vec::new();
        lut.scores_lanes(
            lanes.iter().map(|(l, n)| (&l[..], *n)),
            &mut out,
        );
        if out.len() != n {
            return Err(format!(
                "lane scan returned {} scores for {n} tokens",
                out.len()
            ));
        }
        for (l, (a, b)) in flat.iter().zip(&out).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "score {l} diverged: flat {a} vs lanes {b} \
                     (m={m}, k={k}, group={group})"
                ));
            }
        }
        // the scalar reference agrees bit-for-bit too (order 0..m)
        let probe = g.usize_in(0, n - 1);
        let s = lut.score(&codes[probe * m..(probe + 1) * m]);
        if s.to_bits() != flat[probe].to_bits() {
            return Err(format!(
                "scalar score diverged at {probe} (m={m})"
            ));
        }
        Ok(())
    });
}

#[test]
fn grouped_value_decode_bit_identical_for_every_m() {
    // the value-side sibling: grouped scatter order per accumulator
    // cell is token order, exactly like the flat path, for every
    // unrolled m and the generic path
    prop_assert!("lane-value-decode-bit-identical", 30, |g: &mut Gen| {
        let m = *g.choose(&SCAN_MS);
        let d_sub = *g.choose(&[2usize, 4]);
        let d_k = m * d_sub;
        let k = *g.choose(&[8usize, 32]);
        let n = g.usize_in(1, 120);
        let values: Vec<f32> =
            g.normal_vec(n * d_k).iter().map(|v| v * 0.5).collect();
        let codec = PqCodec::train(
            &values,
            d_k,
            m,
            k,
            &TrainOpts { iters: 4, seed: g.rng.next_u64(), tol: 1e-3 },
        );
        let codes = codec.encode_batch(&values, n);
        let mut weights: Vec<f32> = (0..n)
            .map(|_| if g.bool() { g.rng.next_f32() } else { 0.0 })
            .collect();
        let s: f32 = weights.iter().sum();
        if s > 0.0 {
            for w in weights.iter_mut() {
                *w /= s;
            }
        }
        let flat = lookat::pq::values::weighted_decode(
            &weights, &codes, &codec);
        let group = g.usize_in(1, n + 8);
        let lanes = interleave_lanes(&codes, m, group);
        let grouped = lookat::pq::values::weighted_decode_lanes(
            &weights,
            lanes.iter().map(|(l, n)| (&l[..], *n)),
            &codec,
        );
        for (i, (a, b)) in flat.iter().zip(&grouped).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "dim {i} diverged: flat {a} vs grouped {b} \
                     (m={m}, k={k}, group={group})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn packed_lane_scan_bit_identical_to_flat_for_every_m() {
    // the 4-bit fast-scan contract: nibble-packed lanes (two codes per
    // byte, low nibble = even token slot) with odd token counts and a
    // mid-stream causal cut must score bit-identically to the flat
    // token-major reference, on both the dispatched path and the
    // pinned-scalar one
    prop_assert!("packed-scan-bit-identical", 30, |g: &mut Gen| {
        let m = *g.choose(&SCAN_MS);
        let d_sub = *g.choose(&[2usize, 4, 8]);
        let d_k = m * d_sub;
        let k = *g.choose(&[4usize, 8, 16]);
        let n = g.usize_in(1, 150);
        let keys: Vec<f32> =
            g.normal_vec(n * d_k).iter().map(|v| v * 0.5).collect();
        let codec = PqCodec::train(
            &keys,
            d_k,
            m,
            k,
            &TrainOpts { iters: 4, seed: g.rng.next_u64(), tol: 1e-3 },
        );
        if !codec.packed() {
            return Err(format!("k={k} codec should nibble-pack"));
        }
        let codes = codec.encode_batch(&keys, n);
        let q: Vec<f32> =
            g.normal_vec(d_k).iter().map(|v| v * 0.5).collect();
        let lut = LookupTable::build(&q, &codec.codebook);
        // score only a causal prefix: lanes past the cut are dropped,
        // the cut group is taken partially — mid-stream truncation
        let t = g.usize_in(1, n);
        let flat = lut.scores(&codes[..t * m], t);
        // even group per the packed-lane layout; may overshoot n so a
        // single partial group is also drawn
        let group = 2 * g.usize_in(1, n.div_ceil(2) + 4);
        let lanes = interleave_lanes_packed(&codes, m, group);
        let truncate = |mut left: usize| {
            lanes.iter().filter_map(move |(l, len)| {
                if left == 0 {
                    return None;
                }
                let take = (*len).min(left);
                left -= take;
                Some((&l[..], take))
            })
        };
        let mut out = Vec::new();
        lut.scores_lanes_packed(truncate(t), &mut out);
        if out.len() != t {
            return Err(format!(
                "packed scan returned {} scores for {t} tokens",
                out.len()
            ));
        }
        for (l, (a, b)) in flat.iter().zip(&out).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "score {l} diverged: flat {a} vs packed {b} \
                     (m={m}, k={k}, group={group}, t={t}, n={n})"
                ));
            }
        }
        let mut scalar = Vec::new();
        lut.scores_lanes_packed_scalar(truncate(t), &mut scalar);
        for (l, (a, b)) in out.iter().zip(&scalar).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "score {l}: dispatched {a} vs pinned-scalar {b} \
                     (m={m}, group={group})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn packed_value_decode_bit_identical_to_flat_for_every_m() {
    // value-side sibling of the packed scan property: the fused
    // weighted decode over nibble-packed lanes must match the flat
    // byte-code reference bit for bit, dispatched and pinned-scalar
    prop_assert!("packed-value-decode-bit-identical", 30, |g: &mut Gen| {
        let m = *g.choose(&SCAN_MS);
        let d_sub = *g.choose(&[2usize, 4]);
        let d_k = m * d_sub;
        let k = *g.choose(&[4usize, 16]);
        let n = g.usize_in(1, 120);
        let values: Vec<f32> =
            g.normal_vec(n * d_k).iter().map(|v| v * 0.5).collect();
        let codec = PqCodec::train(
            &values,
            d_k,
            m,
            k,
            &TrainOpts { iters: 4, seed: g.rng.next_u64(), tol: 1e-3 },
        );
        if !codec.packed() {
            return Err(format!("k={k} codec should nibble-pack"));
        }
        let codes = codec.encode_batch(&values, n);
        let t = g.usize_in(1, n);
        let mut weights: Vec<f32> = (0..t)
            .map(|_| if g.bool() { g.rng.next_f32() } else { 0.0 })
            .collect();
        let s: f32 = weights.iter().sum();
        if s > 0.0 {
            for w in weights.iter_mut() {
                *w /= s;
            }
        }
        let flat = lookat::pq::values::weighted_decode(
            &weights, &codes[..t * m], &codec);
        let group = 2 * g.usize_in(1, n.div_ceil(2) + 4);
        let lanes = interleave_lanes_packed(&codes, m, group);
        let truncate = |mut left: usize| {
            lanes.iter().filter_map(move |(l, len)| {
                if left == 0 {
                    return None;
                }
                let take = (*len).min(left);
                left -= take;
                Some((&l[..], take))
            })
        };
        let packed = lookat::pq::values::weighted_decode_lanes_packed(
            &weights, truncate(t), &codec);
        for (i, (a, b)) in flat.iter().zip(&packed).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "dim {i} diverged: flat {a} vs packed {b} \
                     (m={m}, k={k}, group={group}, t={t}, n={n})"
                ));
            }
        }
        let scalar =
            lookat::pq::values::weighted_decode_lanes_packed_scalar(
                &weights, truncate(t), &codec);
        for (i, (a, b)) in packed.iter().zip(&scalar).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "dim {i}: dispatched {a} vs pinned-scalar {b} \
                     (m={m}, group={group})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn train_then_encode_is_deterministic_end_to_end() {
    // codec-level counterpart of the kmeans property: same opts -> same
    // codebook bits -> same codes
    prop_assert!("codec-deterministic", 10, |g: &mut Gen| {
        let (keys, d_k, m, k) = random_pq_case(g);
        let n = keys.len() / d_k;
        let opts =
            TrainOpts { iters: 5, seed: g.rng.next_u64(), tol: 1e-4 };
        let a = PqCodec::train(&keys, d_k, m, k, &opts);
        let b = PqCodec::train(&keys, d_k, m, k, &opts);
        if a.codebook != b.codebook {
            return Err("codebooks diverged".into());
        }
        if a.encode_batch(&keys, n) != b.encode_batch(&keys, n) {
            return Err("codes diverged".into());
        }
        Ok(())
    });
}
