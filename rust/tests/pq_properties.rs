//! Property tests for the PQ invariants, driven by the crate's
//! `util::proptest` mini-framework (`lookat::prop_assert!`):
//!
//! 1. K-Means is deterministic for a fixed `Pcg32` seed;
//! 2. `PqCodec::encode_batch` codes are always `< K`;
//! 3. ADC lookup scores equal naive decode-then-dot-product within 1e-4;
//! 4. `pq::values::weighted_decode` (and its block-resident sibling)
//!    equals the naive decode-then-weighted-sum within 1e-4.

use lookat::pq::kmeans::kmeans;
use lookat::pq::{LookupTable, PqCodec, TrainOpts};
use lookat::prop_assert;
use lookat::util::proptest::Gen;
use lookat::util::rng::Pcg32;

/// Random but structurally valid (keys, d_k, m, k) tuple.
fn random_pq_case(g: &mut Gen) -> (Vec<f32>, usize, usize, usize) {
    let m = *g.choose(&[2usize, 4, 8]);
    let d_sub = *g.choose(&[4usize, 8]);
    let d_k = m * d_sub;
    let k = *g.choose(&[4usize, 8, 16, 32]);
    let n = g.usize_in(k.max(16), 96);
    // scaled-down values keep dot magnitudes small so the 1e-4 ADC
    // tolerance is a genuine relative bound
    let keys: Vec<f32> =
        g.normal_vec(n * d_k).iter().map(|v| v * 0.5).collect();
    (keys, d_k, m, k)
}

#[test]
fn kmeans_is_deterministic_for_fixed_seed() {
    prop_assert!("kmeans-deterministic", 25, |g: &mut Gen| {
        let dim = g.usize_in(2, 8);
        let k = g.usize_in(2, 12);
        let n = g.usize_in(k, 80);
        let pts = g.normal_vec(n * dim);
        let seed = g.rng.next_u64();
        let a = kmeans(&pts, dim, k, 15, 1e-6, &mut Pcg32::seed(seed));
        let b = kmeans(&pts, dim, k, 15, 1e-6, &mut Pcg32::seed(seed));
        if a.centroids != b.centroids {
            return Err(format!(
                "centroids diverged for seed {seed:#x}"
            ));
        }
        if a.inertia.to_bits() != b.inertia.to_bits() {
            return Err(format!(
                "inertia diverged for seed {seed:#x}: {} vs {}",
                a.inertia, b.inertia
            ));
        }
        if a.iters_run != b.iters_run {
            return Err("iteration count diverged".into());
        }
        Ok(())
    });
}

#[test]
fn encode_batch_codes_always_below_k() {
    prop_assert!("codes-below-k", 25, |g: &mut Gen| {
        let (keys, d_k, m, k) = random_pq_case(g);
        let n = keys.len() / d_k;
        let codec = PqCodec::train(
            &keys,
            d_k,
            m,
            k,
            &TrainOpts { iters: 6, seed: g.rng.next_u64(), tol: 1e-4 },
        );
        let codes = codec.encode_batch(&keys, n);
        if codes.len() != n * m {
            return Err(format!(
                "expected {} codes, got {}",
                n * m,
                codes.len()
            ));
        }
        match codes.iter().position(|&c| c as usize >= k) {
            Some(i) => Err(format!(
                "code {} at {i} >= K={k} (m={m}, d_k={d_k})",
                codes[i]
            )),
            None => Ok(()),
        }
    });
}

#[test]
fn adc_scores_equal_decode_then_dot_within_1e4() {
    prop_assert!("adc-equals-decode-dot", 25, |g: &mut Gen| {
        let (keys, d_k, m, k) = random_pq_case(g);
        let n = keys.len() / d_k;
        let codec = PqCodec::train(
            &keys,
            d_k,
            m,
            k,
            &TrainOpts { iters: 6, seed: g.rng.next_u64(), tol: 1e-4 },
        );
        let codes = codec.encode_batch(&keys, n);
        let q: Vec<f32> =
            g.normal_vec(d_k).iter().map(|v| v * 0.5).collect();
        let lut = LookupTable::build(&q, &codec.codebook);
        let batch = lut.scores(&codes, n);
        for l in 0..n {
            let code = &codes[l * m..(l + 1) * m];
            let naive = lookat::tensor::dot(&q, &codec.decode(code));
            let scalar = lut.score(code);
            if (scalar - naive).abs() > 1e-4 {
                return Err(format!(
                    "l={l}: lut.score {scalar} vs decode-dot {naive} \
                     (m={m}, k={k}, d_k={d_k})"
                ));
            }
            if (batch[l] - naive).abs() > 1e-4 {
                return Err(format!(
                    "l={l}: batched {} vs decode-dot {naive}",
                    batch[l]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn weighted_decode_equals_decode_then_weighted_sum_within_1e4() {
    // the §5.2 transposed aggregation: Σ_l α_l · decode(codes_l) must
    // match the scatter-accumulate + centroid-matvec path on arbitrary
    // (values, weights) draws — including zero weights, which the
    // scatter path skips outright — and the blocked variant must match
    // the flat one bit for bit
    prop_assert!("weighted-decode-equals-dense", 25, |g: &mut Gen| {
        let (values, d_k, m, k) = random_pq_case(g);
        let n = values.len() / d_k;
        let codec = PqCodec::train(
            &values,
            d_k,
            m,
            k,
            &TrainOpts { iters: 6, seed: g.rng.next_u64(), tol: 1e-4 },
        );
        let codes = codec.encode_batch(&values, n);
        // softmax-like weights with a sprinkle of exact zeros
        let mut weights: Vec<f32> = (0..n)
            .map(|_| if g.bool() { g.rng.next_f32() } else { 0.0 })
            .collect();
        let s: f32 = weights.iter().sum();
        if s > 0.0 {
            for w in weights.iter_mut() {
                *w /= s;
            }
        }
        let got =
            lookat::pq::values::weighted_decode(&weights, &codes, &codec);
        let mut want = vec![0.0f32; d_k];
        for (l, &w) in weights.iter().enumerate() {
            let v = codec.decode(&codes[l * m..(l + 1) * m]);
            for (o, x) in want.iter_mut().zip(&v) {
                *o += w * x;
            }
        }
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            if (a - b).abs() > 1e-4 {
                return Err(format!(
                    "dim {i}: weighted_decode {a} vs dense {b} \
                     (n={n}, m={m}, k={k})"
                ));
            }
        }
        let bt = g.usize_in(1, n);
        let blocked = lookat::pq::values::weighted_decode_blocks(
            &weights,
            codes.chunks(bt * m),
            &codec,
        );
        if got.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            != blocked.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        {
            return Err(format!(
                "blocked decode diverged from flat (bt={bt})"
            ));
        }
        Ok(())
    });
}

#[test]
fn train_then_encode_is_deterministic_end_to_end() {
    // codec-level counterpart of the kmeans property: same opts -> same
    // codebook bits -> same codes
    prop_assert!("codec-deterministic", 10, |g: &mut Gen| {
        let (keys, d_k, m, k) = random_pq_case(g);
        let n = keys.len() / d_k;
        let opts =
            TrainOpts { iters: 5, seed: g.rng.next_u64(), tol: 1e-4 };
        let a = PqCodec::train(&keys, d_k, m, k, &opts);
        let b = PqCodec::train(&keys, d_k, m, k, &opts);
        if a.codebook != b.codebook {
            return Err("codebooks diverged".into());
        }
        if a.encode_batch(&keys, n) != b.encode_batch(&keys, n) {
            return Err("codes diverged".into());
        }
        Ok(())
    });
}
