//! Integration tests: the three-layer AOT contract.
//!
//! Loads the real `artifacts/*.hlo.txt` (jax/pallas-lowered) through the
//! PJRT CPU client and checks numerics against the pure-rust
//! implementations. Skips gracefully when `make artifacts` hasn't run.

use lookat::attention;
use lookat::pq::{LookupTable, PqCodec, TrainOpts};
use lookat::runtime::{default_artifacts_dir, InputArg, Runtime};
use lookat::util::rng::Pcg32;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(&dir).expect("runtime open"))
}

fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32_std()).collect()
}

const H: usize = 12;
const DK: usize = 64;
const K: usize = 256;

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in [
        "attn_fp16_L128",
        "attn_fp16_L512",
        "attn_lookat_m4_L512",
        "attn_lookat_m2_L512",
        "lut_build_m4",
        "adc_scores_m4_L512",
        "block_fp16_L512",
        "block_lookat_m4_L512",
    ] {
        assert!(rt.manifest.get(name).is_some(), "missing artifact {name}");
    }
}

#[test]
fn lut_build_artifact_matches_rust() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let m = 4;
    let d_sub = DK / m;
    let mut rng = Pcg32::seed(100);
    // train a real codec so the codebook layout is authentic
    let calib = randv(&mut rng, 256 * DK);
    let codec = PqCodec::train(&calib, DK, m, K, &TrainOpts::default());
    let q = randv(&mut rng, DK);
    let lut_rust = LookupTable::build(&q, &codec.codebook);

    let cb_flat = codec.codebook.to_flat();
    let out = rt
        .execute(
            "lut_build_m4",
            &[InputArg::F32(&q), InputArg::F32(&cb_flat)],
        )
        .expect("execute lut_build");
    assert_eq!(out[0].len(), m * K);
    for (a, b) in out[0].iter().zip(lut_rust.as_slice()) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
    let _ = d_sub;
}

#[test]
fn adc_scores_artifact_matches_rust() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let m = 4;
    let l = 512;
    let mut rng = Pcg32::seed(101);
    let calib = randv(&mut rng, 256 * DK);
    let codec = PqCodec::train(&calib, DK, m, K, &TrainOpts::default());
    let keys = randv(&mut rng, l * DK);
    let codes = codec.encode_batch(&keys, l);
    let q = randv(&mut rng, DK);
    let lut = LookupTable::build(&q, &codec.codebook);
    let want = lut.scores(&codes, l);

    let codes_i32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
    let out = rt
        .execute(
            "adc_scores_m4_L512",
            &[InputArg::I32(&codes_i32), InputArg::F32(lut.as_slice())],
        )
        .expect("execute adc_scores");
    for (a, b) in out[0].iter().zip(&want) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn attn_fp16_artifact_matches_rust_attention() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let l = 128;
    let valid = 100usize;
    let mut rng = Pcg32::seed(102);
    let q: Vec<f32> = randv(&mut rng, H * DK);
    let k: Vec<f32> = randv(&mut rng, H * l * DK);
    let v: Vec<f32> = randv(&mut rng, H * l * DK);
    let mask: Vec<f32> =
        (0..l).map(|i| if i < valid { 1.0 } else { 0.0 }).collect();

    let out = rt
        .execute(
            "attn_fp16_L128",
            &[
                InputArg::F32(&q),
                InputArg::F32(&k),
                InputArg::F32(&v),
                InputArg::F32(&mask),
            ],
        )
        .expect("execute attn_fp16");
    assert_eq!(out[0].len(), H * DK);

    // reference: per-head rust exact attention over the valid prefix
    for h in 0..H {
        let qh = &q[h * DK..(h + 1) * DK];
        let kh: Vec<f32> = (0..valid)
            .flat_map(|t| {
                k[(h * l + t) * DK..(h * l + t + 1) * DK].to_vec()
            })
            .collect();
        let vh: Vec<f32> = (0..valid)
            .flat_map(|t| {
                v[(h * l + t) * DK..(h * l + t + 1) * DK].to_vec()
            })
            .collect();
        let want = attention::exact_attention(qh, &kh, &vh, valid);
        for (a, b) in out[0][h * DK..(h + 1) * DK].iter().zip(&want.out) {
            assert!(
                (a - b).abs() < 1e-3,
                "head {h}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn attn_lookat_artifact_matches_rust_lookat() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (l, m) = (512, 4);
    let valid = 300usize;
    let mut rng = Pcg32::seed(103);

    // per-head codecs trained on the head's own keys (authentic pipeline)
    let mut q = Vec::new();
    let mut v = Vec::new();
    let mut codes_i32 = Vec::new();
    let mut cb_flat = Vec::new();
    let mut rust_out = Vec::new();
    let mut codes_all: Vec<Vec<u8>> = Vec::new();
    let mut codecs = Vec::new();
    for _h in 0..H {
        let keys = randv(&mut rng, l * DK);
        let codec = PqCodec::train(&keys, DK, m, K, &TrainOpts::default());
        let codes = codec.encode_batch(&keys, l);
        cb_flat.extend(codec.codebook.to_flat());
        codes_i32.extend(codes.iter().map(|&c| c as i32));
        codes_all.push(codes);
        codecs.push(codec);
        q.extend(randv(&mut rng, DK));
        v.extend(randv(&mut rng, l * DK));
    }
    let mask: Vec<f32> =
        (0..l).map(|i| if i < valid { 1.0 } else { 0.0 }).collect();
    for h in 0..H {
        let qh = &q[h * DK..(h + 1) * DK];
        let vh = &v[h * l * DK..(h * l + valid) * DK];
        let codes_valid = &codes_all[h][..valid * m];
        let got = attention::lookat_attention(
            qh, codes_valid, &codecs[h], vh, valid);
        rust_out.extend(got.out);
    }

    let out = rt
        .execute(
            "attn_lookat_m4_L512",
            &[
                InputArg::F32(&q),
                InputArg::I32(&codes_i32),
                InputArg::F32(&cb_flat),
                InputArg::F32(&v),
                InputArg::F32(&mask),
            ],
        )
        .expect("execute attn_lookat");
    for (i, (a, b)) in out[0].iter().zip(&rust_out).enumerate() {
        assert!((a - b).abs() < 1e-3, "elem {i}: {a} vs {b}");
    }
}

#[test]
fn execute_validates_shapes_and_dtypes() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let q = vec![0.0f32; 3]; // wrong size
    let err = rt
        .execute("attn_fp16_L128", &[InputArg::F32(&q)])
        .unwrap_err()
        .to_string();
    assert!(err.contains("inputs"), "{err}");

    // right count, wrong element count
    let k = vec![0.0f32; 10];
    let v = vec![0.0f32; 10];
    let mask = vec![0.0f32; 10];
    let err2 = rt
        .execute(
            "attn_fp16_L128",
            &[
                InputArg::F32(&q),
                InputArg::F32(&k),
                InputArg::F32(&v),
                InputArg::F32(&mask),
            ],
        )
        .unwrap_err()
        .to_string();
    assert!(err2.contains("elements"), "{err2}");
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert!(rt.execute("no_such_artifact", &[]).is_err());
}
