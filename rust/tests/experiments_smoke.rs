//! Smoke: every experiment runs end-to-end in --quick mode and writes
//! its reports. This is the "can a user regenerate the paper" check.

use lookat::experiments;

#[test]
fn all_experiments_run_quick() {
    experiments::run("all", true).expect("quick experiment run");
    let dir = experiments::report::reports_dir();
    for id in [
        "table1", "table2", "table3", "table4", "figure3", "figure4",
        "efficiency", "ablation_values", "ablation_centroids",
        "ablation_calibration",
    ] {
        assert!(
            dir.join(format!("{id}.md")).exists(),
            "{id}.md not written"
        );
        assert!(
            dir.join(format!("{id}.json")).exists(),
            "{id}.json not written"
        );
    }
}

#[test]
fn unknown_experiment_id_errors() {
    assert!(experiments::run("table9", true).is_err());
}
