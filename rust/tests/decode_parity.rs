//! Batched-decode parity + KV-cache block lifecycle (the PR-2
//! acceptance suite, extended by the chunked-prefill/preemption PR):
//! dropping a sequence returns its blocks, the allocator budget is
//! re-admittable to exhaustion, a decode batch of N is bit-identical to
//! N serial batch-of-one decodes on every backend, chunked prefill is
//! bit-identical to monolithic prefill on every key × value backend
//! combination, and a preempt → re-admit round trip reproduces the
//! uninterrupted run's tokens exactly — via re-prefill and via the
//! tiered swap store — with copy-on-write prefix sharing holding under
//! preemption churn (PJRT backends run when artifacts are built).

use lookat::coordinator::{
    AttentionBackend, Batcher, BatcherConfig, CompressionPolicy, Engine,
    EngineConfig, Request, SchedulerPolicy, TickEntry, ValueBackend,
};
use lookat::kvcache::{
    CacheError, KeyStorage, KvCache, ValueStorage, BLOCK_TOKENS,
};
use lookat::model::{ByteTokenizer, ModelConfig};
use lookat::runtime::default_artifacts_dir;

fn artifacts_built() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn tiny_cfg(backend: AttentionBackend, threads: usize) -> EngineConfig {
    tiny_cfg_kv(backend, ValueBackend::Fp32, threads)
}

fn tiny_cfg_kv(
    backend: AttentionBackend,
    value_backend: ValueBackend,
    threads: usize,
) -> EngineConfig {
    EngineConfig {
        model: ModelConfig::test_tiny(),
        backend,
        value_backend,
        seed: 42,
        cache_blocks: 48,
        calib_tokens: 96,
        decode_threads: threads,
        prefill_chunk: 0,
        pipeline: true,
        prefix_cache: false,
        policy: CompressionPolicy::Uniform,
        faults: Default::default(),
    }
}

fn paper_cfg(backend: AttentionBackend, threads: usize) -> EngineConfig {
    EngineConfig {
        model: ModelConfig::gpt2_layer0(), // artifact geometry
        backend,
        value_backend: ValueBackend::Fp32,
        seed: 21,
        cache_blocks: 64,
        calib_tokens: 128,
        decode_threads: threads,
        prefill_chunk: 0,
        pipeline: true,
        prefix_cache: false,
        policy: CompressionPolicy::Uniform,
        faults: Default::default(),
    }
}

/// Feed a prompt to a fresh sequence in chunks of `chunk` tokens
/// through the mixed-tick path (what the scheduler does).
fn prefill_chunked(e: &mut Engine, id: u64, prompt: &[u32], chunk: usize) {
    e.begin_seq(id).unwrap();
    let mut off = 0;
    while off < prompt.len() {
        let end = (off + chunk).min(prompt.len());
        e.step_batch(&[TickEntry::Prefill {
            seq: id,
            tokens: &prompt[off..end],
        }])
        .unwrap();
        off = end;
    }
}

// ---- block lifecycle ---------------------------------------------------

#[test]
fn freed_blocks_return_to_the_allocator_and_readmit() {
    let mut c = KvCache::new(2, 16, 4, KeyStorage::Fp16, ValueStorage::Fp32);
    let k = vec![0.5f32; 2 * 16];
    let v = vec![0.25f32; 2 * 16];

    // fill the whole budget with one sequence
    c.create_seq(1).unwrap();
    for _ in 0..4 * BLOCK_TOKENS {
        c.append(1, &k, &v).unwrap();
    }
    assert_eq!(c.append(1, &k, &v), Err(CacheError::OutOfBlocks));
    let s = c.stats();
    assert_eq!(s.blocks_allocated, 4);
    assert_eq!(s.blocks_total, 4);

    // drop it: every block must come back
    c.free_seq(1).unwrap();
    let s = c.stats();
    assert_eq!(s.blocks_allocated, 0);
    assert_eq!(s.tokens, 0);

    // re-admit new sequences until exhaustion — the full budget is
    // usable again, and the failure mode is an error, not a panic
    c.create_seq(2).unwrap();
    c.create_seq(3).unwrap();
    let mut appended = 0usize;
    loop {
        let id = 2 + (appended / BLOCK_TOKENS) as u64 % 2;
        match c.append(id, &k, &v) {
            Ok(_) => appended += 1,
            Err(CacheError::OutOfBlocks) => break,
            Err(e) => panic!("unexpected error: {e}"),
        }
        assert!(appended <= 4 * BLOCK_TOKENS, "over-admitted");
    }
    assert_eq!(appended, 4 * BLOCK_TOKENS);
    assert_eq!(c.stats().blocks_allocated, 4);
}

#[test]
fn engine_release_makes_room_for_new_sequences() {
    // cache_blocks = 2 per layer: one ~40-token sequence fills it
    let mut cfg = tiny_cfg(AttentionBackend::Fp16Exact, 1);
    cfg.cache_blocks = 2;
    let mut e = Engine::build(&cfg).unwrap();
    let ids = ByteTokenizer::new()
        .encode("a prompt long enough to span one cache block easily..");
    e.start_seq(1, &ids).unwrap();
    assert!(!e.can_admit(ids.len()), "cache should be near-full");
    e.release(1).unwrap();
    assert!(e.can_admit(ids.len()), "release must free the blocks");
    e.start_seq(2, &ids).unwrap();
    e.decode_one(2).unwrap();
}

// ---- batched vs serial parity ------------------------------------------

fn assert_batched_matches_serial(
    serial: &mut Engine,
    batched: &mut Engine,
    n_seqs: u64,
    steps: usize,
) {
    let tok = ByteTokenizer::new();
    let prompts = [
        "first parity prompt",
        "a different second prompt",
        "third, rather longer, parity prompt for block spill",
        "and a fourth",
    ];
    for i in 0..n_seqs {
        let ids = tok.encode(prompts[i as usize % prompts.len()]);
        serial.start_seq(i, &ids).unwrap();
        batched.start_seq(i, &ids).unwrap();
    }
    let ids: Vec<u64> = (0..n_seqs).collect();
    for step in 0..steps {
        let s: Vec<u32> = ids
            .iter()
            .map(|&i| serial.decode_one(i).unwrap())
            .collect();
        let b = batched.decode_batch(&ids).unwrap();
        assert_eq!(
            s, b,
            "backend {:?} diverged at step {step}",
            batched.backend
        );
    }
}

#[test]
fn batched_decode_bit_identical_all_rust_backends() {
    for backend in [
        AttentionBackend::Fp16Exact,
        AttentionBackend::Lookat { m: 4, k: 64 },
        AttentionBackend::Lookat { m: 2, k: 64 },
        AttentionBackend::Lookat { m: 4, k: 16 },
        AttentionBackend::ScalarQuant { bits: 8 },
        AttentionBackend::ScalarQuant { bits: 4 },
    ] {
        let mut serial =
            Engine::build(&tiny_cfg(backend.clone(), 1)).unwrap();
        let mut batched =
            Engine::build(&tiny_cfg(backend, 4)).unwrap();
        assert_batched_matches_serial(&mut serial, &mut batched, 4, 6);
    }
}

#[test]
fn batched_decode_bit_identical_every_key_value_backend_combo() {
    // the value-storage axis: every rust key backend × {fp32, pq}
    // values must stay bit-identical between batched and serial decode
    // (the fused blocked weighted decode is per-item deterministic)
    let key_backends = [
        AttentionBackend::Fp16Exact,
        AttentionBackend::Lookat { m: 4, k: 64 },
        AttentionBackend::Lookat { m: 2, k: 64 },
        // nibble-packed 4-bit key lanes (the SIMD fast-scan mode)
        AttentionBackend::Lookat { m: 4, k: 16 },
        AttentionBackend::ScalarQuant { bits: 8 },
        AttentionBackend::ScalarQuant { bits: 4 },
    ];
    let value_backends = [
        ValueBackend::Fp32,
        ValueBackend::Pq { m: 4, k: 64 },
        // nibble-packed 4-bit value lanes
        ValueBackend::Pq { m: 4, k: 16 },
    ];
    for backend in key_backends {
        for vb in &value_backends {
            let mut serial = Engine::build(&tiny_cfg_kv(
                backend.clone(), vb.clone(), 1)).unwrap();
            let mut batched = Engine::build(&tiny_cfg_kv(
                backend.clone(), vb.clone(), 4)).unwrap();
            assert_batched_matches_serial(
                &mut serial, &mut batched, 4, 6);
        }
    }
}

#[test]
fn uniform_policy_bit_identical_every_key_value_backend_combo() {
    // `--policy uniform` must be a no-op: codec training uses the exact
    // historical calibration calls (same salts, same subspace geometry),
    // so an engine with the policy spelled out decodes the same tokens
    // as one built from the default-policy config on every backend combo
    let key_backends = [
        AttentionBackend::Fp16Exact,
        AttentionBackend::Lookat { m: 4, k: 64 },
        AttentionBackend::Lookat { m: 2, k: 64 },
        AttentionBackend::Lookat { m: 4, k: 16 },
        AttentionBackend::ScalarQuant { bits: 8 },
        AttentionBackend::ScalarQuant { bits: 4 },
    ];
    let value_backends = [
        ValueBackend::Fp32,
        ValueBackend::Pq { m: 4, k: 64 },
        ValueBackend::Pq { m: 4, k: 16 },
    ];
    let tok = ByteTokenizer::new();
    let ids = tok.encode("uniform policy parity prompt, long enough to spill");
    for backend in key_backends {
        for vb in &value_backends {
            let mut explicit =
                tiny_cfg_kv(backend.clone(), vb.clone(), 2);
            explicit.policy = CompressionPolicy::Uniform;
            let mut default_cfg =
                tiny_cfg_kv(backend.clone(), vb.clone(), 2);
            default_cfg.policy = CompressionPolicy::default();
            let mut a = Engine::build(&explicit).unwrap();
            let mut b = Engine::build(&default_cfg).unwrap();

            // uniform record mirrors the backend geometry exactly
            let rec = a.policy_record();
            assert_eq!(rec.policy, "uniform");
            if let AttentionBackend::Lookat { m, .. } = backend {
                assert!(
                    rec.heads.iter().all(|h| h.key_m == m),
                    "{backend:?}: uniform key_m must equal backend m"
                );
            }

            a.start_seq(1, &ids).unwrap();
            b.start_seq(1, &ids).unwrap();
            for step in 0..6 {
                let ta = a.decode_one(1).unwrap();
                let tb = b.decode_one(1).unwrap();
                assert_eq!(
                    ta, tb,
                    "{backend:?}/{vb:?} diverged at step {step}"
                );
            }
        }
    }
}

#[test]
fn value_pq_cache_frees_like_fp32() {
    // block lifecycle holds with the value-codes lane active
    let mut e = Engine::build(&tiny_cfg_kv(
        AttentionBackend::Lookat { m: 4, k: 64 },
        ValueBackend::Pq { m: 4, k: 64 },
        2,
    ))
    .unwrap();
    let ids = ByteTokenizer::new().encode("value lane lifecycle");
    e.start_seq(1, &ids).unwrap();
    for _ in 0..3 {
        e.decode_one(1).unwrap();
    }
    assert!(e.cache_stats().blocks_allocated > 0);
    e.release(1).unwrap();
    assert_eq!(e.cache_stats().blocks_allocated, 0);
    e.start_seq(2, &ids).unwrap();
    e.decode_one(2).unwrap();
}

// ---- chunked prefill vs monolithic -------------------------------------

#[test]
fn chunked_prefill_bit_identical_every_key_value_backend_combo() {
    // prefill rides the backend kernel as causal spans, so a span row's
    // result depends only on (query row, cache prefix) — any chunking
    // of the same prompt must produce bit-identical decode trajectories
    let tok = ByteTokenizer::new();
    let ids = tok.encode(
        "chunked prefill parity prompt, long enough to spill across \
         cache blocks and then some more",
    );
    assert!(ids.len() > BLOCK_TOKENS, "prompt must span blocks");
    let key_backends = [
        AttentionBackend::Fp16Exact,
        AttentionBackend::Lookat { m: 4, k: 64 },
        AttentionBackend::Lookat { m: 2, k: 64 },
        // nibble-packed 4-bit key lanes (the SIMD fast-scan mode)
        AttentionBackend::Lookat { m: 4, k: 16 },
        AttentionBackend::ScalarQuant { bits: 8 },
        AttentionBackend::ScalarQuant { bits: 4 },
    ];
    let value_backends = [
        ValueBackend::Fp32,
        ValueBackend::Pq { m: 4, k: 64 },
        // nibble-packed 4-bit value lanes
        ValueBackend::Pq { m: 4, k: 16 },
    ];
    for backend in key_backends {
        for vb in &value_backends {
            let cfg = tiny_cfg_kv(backend.clone(), vb.clone(), 2);
            let mut mono = Engine::build(&cfg).unwrap();
            mono.start_seq(1, &ids).unwrap();
            let mono_toks: Vec<u32> =
                (0..4).map(|_| mono.decode_one(1).unwrap()).collect();
            for chunk in [1usize, 7] {
                let mut ch = Engine::build(&cfg).unwrap();
                prefill_chunked(&mut ch, 1, &ids, chunk);
                let ch_toks: Vec<u32> = (0..4)
                    .map(|_| ch.decode_one(1).unwrap())
                    .collect();
                assert_eq!(
                    mono_toks, ch_toks,
                    "{backend:?} + {vb:?} diverged at chunk={chunk}"
                );
            }
        }
    }
}

// ---- preemption round trip ---------------------------------------------

fn preempt_requests(n: u64, gen: usize) -> Vec<Request> {
    let tok = ByteTokenizer::new();
    let prompts = [
        "preemption parity prompt number one",
        "a different second preemption prompt",
        "third prompt, somewhat longer, to vary block usage a bit",
        "and the fourth one",
    ];
    (0..n)
        .map(|i| Request {
            id: i,
            prompt: tok.encode(prompts[i as usize % prompts.len()]),
            max_new_tokens: gen,
            // staggered arrivals: preemption victims are well-defined
            arrival_s: i as f64 * 0.001,
            timeout_ms: None,
        })
        .collect()
}

fn drain_batcher(b: &mut Batcher) {
    let mut now = 0.0;
    let mut iters = 0;
    while !b.idle() {
        b.admit(now);
        b.step(now).unwrap();
        let s = b.engine().cache_stats();
        assert!(
            s.blocks_allocated <= s.blocks_total,
            "block budget exceeded"
        );
        now += 0.01;
        iters += 1;
        assert!(iters < 4000, "batcher failed to drain");
    }
}

#[test]
fn preempt_readmit_roundtrip_produces_identical_tokens() {
    // an oversubscribed preemptive run must emit exactly the tokens of
    // a roomy no-preemption run: re-prefill from codes reproduces the
    // evicted sequence's decode states bit for bit (swap disabled here
    // on purpose — the swap tier has its own parity test below)
    let mk = |blocks: usize, policy: SchedulerPolicy| {
        let mut cfg =
            tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 }, 2);
        cfg.cache_blocks = blocks;
        cfg.prefill_chunk = 8;
        let engine = Engine::build(&cfg).unwrap();
        Batcher::new(
            engine,
            BatcherConfig {
                max_batch: 4,
                max_queue: 32,
                policy,
                swap: false,
                ..BatcherConfig::default()
            },
        )
    };

    let mut roomy = mk(64, SchedulerPolicy::Fcfs);
    for r in preempt_requests(4, 40) {
        assert!(roomy.submit(r));
    }
    drain_batcher(&mut roomy);

    // 5 blocks: four ~(36 prompt + 40 gen)-token sequences need 3
    // blocks each at peak — far over budget, so eviction must kick in
    let mut tight = mk(5, SchedulerPolicy::Preempt);
    for r in preempt_requests(4, 40) {
        assert!(tight.submit(r));
    }
    drain_batcher(&mut tight);

    assert!(
        tight.preemptions > 0,
        "scenario must actually exercise preemption"
    );
    assert_eq!(tight.completed.len(), 4);
    assert!(tight.rejected.is_empty(), "no admitted request dropped");

    let by_id = |b: &Batcher| {
        let mut v: Vec<(u64, Vec<u32>)> = b
            .completed
            .iter()
            .map(|c| (c.id, c.generated.clone()))
            .collect();
        v.sort();
        v
    };
    assert_eq!(by_id(&roomy), by_id(&tight));
}

#[test]
fn oversubscription_no_longer_rejects_admitted_requests() {
    // under the preemptive policy, admission charges only the chunk in
    // flight: demand far beyond the block budget queues and cycles
    // instead of erroring with OutOfBlocks
    let mut cfg = tiny_cfg(AttentionBackend::Fp16Exact, 2);
    cfg.cache_blocks = 4;
    cfg.prefill_chunk = 8;
    let engine = Engine::build(&cfg).unwrap();
    let mut b = Batcher::new(
        engine,
        BatcherConfig {
            max_batch: 6,
            max_queue: 64,
            policy: SchedulerPolicy::Preempt,
            ..BatcherConfig::default()
        },
    );
    for r in preempt_requests(8, 30) {
        assert!(b.submit(r));
    }
    drain_batcher(&mut b);
    assert_eq!(b.completed.len(), 8, "every request completes");
    assert!(b.rejected.is_empty());
    assert_eq!(b.engine().cache_stats().tokens, 0, "cache drained");
}

// ---- swap tier + prefix cache ------------------------------------------

#[test]
fn swap_restore_bit_identical_every_key_value_backend_combo() {
    // the swap tier copies whole code/tensor slabs to a host-side
    // spill store and back, so a preempted-then-restored sequence must
    // continue with exactly the tokens of an uninterrupted roomy run —
    // on every key × value backend combination
    let key_backends = [
        AttentionBackend::Fp16Exact,
        AttentionBackend::Lookat { m: 4, k: 64 },
        AttentionBackend::Lookat { m: 2, k: 64 },
        // nibble-packed 4-bit key lanes (the SIMD fast-scan mode)
        AttentionBackend::Lookat { m: 4, k: 16 },
        AttentionBackend::ScalarQuant { bits: 8 },
        AttentionBackend::ScalarQuant { bits: 4 },
    ];
    let value_backends = [
        ValueBackend::Fp32,
        ValueBackend::Pq { m: 4, k: 64 },
        // nibble-packed 4-bit value lanes
        ValueBackend::Pq { m: 4, k: 16 },
    ];
    let by_id = |b: &Batcher| {
        let mut v: Vec<(u64, Vec<u32>)> = b
            .completed
            .iter()
            .map(|c| (c.id, c.generated.clone()))
            .collect();
        v.sort();
        v
    };
    for backend in key_backends {
        for vb in &value_backends {
            let mk = |blocks: usize, policy: SchedulerPolicy| {
                let mut cfg =
                    tiny_cfg_kv(backend.clone(), vb.clone(), 2);
                cfg.cache_blocks = blocks;
                cfg.prefill_chunk = 8;
                let engine = Engine::build(&cfg).unwrap();
                Batcher::new(
                    engine,
                    BatcherConfig {
                        max_batch: 4,
                        max_queue: 32,
                        policy,
                        ..BatcherConfig::default()
                    },
                )
            };

            let mut roomy = mk(64, SchedulerPolicy::Fcfs);
            for r in preempt_requests(4, 40) {
                assert!(roomy.submit(r));
            }
            drain_batcher(&mut roomy);

            let mut tight = mk(5, SchedulerPolicy::Preempt);
            for r in preempt_requests(4, 40) {
                assert!(tight.submit(r));
            }
            drain_batcher(&mut tight);

            assert!(
                tight.swap_outs > 0,
                "{backend:?} + {vb:?}: swap tier never exercised"
            );
            assert_eq!(
                tight.swap_ins, tight.swap_outs,
                "{backend:?} + {vb:?}: a swapped sequence never resumed"
            );
            assert_eq!(tight.completed.len(), 4);
            assert!(tight.rejected.is_empty());
            assert_eq!(
                by_id(&roomy),
                by_id(&tight),
                "{backend:?} + {vb:?}: swap restore diverged"
            );
        }
    }
}

#[test]
fn prefix_cache_cow_holds_under_preemption_churn() {
    // copy-on-write prefix sharing under an oversubscribed preemptive
    // batcher: token parity against a roomy prefix-off run proves no
    // shared block is ever freed (and recycled) while a holder is
    // still live, and after the full drain no refcount, spill, or
    // prefix-index leaks remain
    let tok = ByteTokenizer::new();
    // 84 chars ≈ 84 tokens: two full shared blocks plus a private tail
    let system = "shared system preamble text ".repeat(3);
    let requests = || -> Vec<Request> {
        (0..6u64)
            .map(|i| Request {
                id: i,
                prompt: tok.encode(&format!("{system}tail {i}")),
                max_new_tokens: 10 + (i as usize % 4),
                arrival_s: i as f64 * 0.001,
                timeout_ms: None,
            })
            .collect()
    };
    let mk = |blocks: usize, policy: SchedulerPolicy, prefix: bool| {
        let mut cfg =
            tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 }, 2);
        cfg.cache_blocks = blocks;
        cfg.prefill_chunk = 8;
        cfg.prefix_cache = prefix;
        let engine = Engine::build(&cfg).unwrap();
        Batcher::new(
            engine,
            BatcherConfig {
                max_batch: 3,
                max_queue: 32,
                policy,
                ..BatcherConfig::default()
            },
        )
    };

    let mut plain = mk(64, SchedulerPolicy::Fcfs, false);
    for r in requests() {
        assert!(plain.submit(r));
    }
    drain_batcher(&mut plain);

    // 7 blocks against three ~4-block sequences at a time: constant
    // eviction pressure while prefix blocks are shared and re-attached
    let mut shared = mk(7, SchedulerPolicy::Preempt, true);
    for r in requests() {
        assert!(shared.submit(r));
    }
    drain_batcher(&mut shared);

    assert!(shared.preemptions > 0, "churn scenario must preempt");
    assert_eq!(shared.completed.len(), 6);
    assert!(shared.rejected.is_empty());

    let by_id = |b: &Batcher| {
        let mut v: Vec<(u64, Vec<u32>)> = b
            .completed
            .iter()
            .map(|c| (c.id, c.generated.clone()))
            .collect();
        v.sort();
        v
    };
    assert_eq!(
        by_id(&plain),
        by_id(&shared),
        "a survivor read a block freed while shared"
    );

    let s = shared.engine().cache_stats();
    assert_eq!(s.blocks_allocated, 0, "refcount leak: blocks held");
    assert_eq!(s.shared_blocks, 0, "dangling shared refs");
    assert_eq!(s.tokens, 0);
    assert_eq!(
        shared.engine().prefix_entries(),
        0,
        "prefix index kept entries past their last holder"
    );
}

#[test]
fn batched_decode_bit_identical_pjrt_backends() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    for backend in [
        AttentionBackend::PjrtFp16,
        AttentionBackend::PjrtLookat { m: 4 },
    ] {
        let mut serial =
            Engine::build(&paper_cfg(backend.clone(), 1)).unwrap();
        let mut batched =
            Engine::build(&paper_cfg(backend, 2)).unwrap();
        assert_batched_matches_serial(&mut serial, &mut batched, 2, 3);
    }
}

#[test]
fn chunked_prefill_bit_identical_pjrt_backends() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ids = ByteTokenizer::new().encode("pjrt chunked prefill parity");
    for backend in [
        AttentionBackend::PjrtFp16,
        AttentionBackend::PjrtLookat { m: 4 },
    ] {
        let cfg = paper_cfg(backend.clone(), 1);
        let mut mono = Engine::build(&cfg).unwrap();
        mono.start_seq(1, &ids).unwrap();
        let mono_toks: Vec<u32> =
            (0..2).map(|_| mono.decode_one(1).unwrap()).collect();
        let mut ch = Engine::build(&cfg).unwrap();
        prefill_chunked(&mut ch, 1, &ids, 7);
        let ch_toks: Vec<u32> =
            (0..2).map(|_| ch.decode_one(1).unwrap()).collect();
        assert_eq!(mono_toks, ch_toks, "{backend:?}");
    }
}

// ---- software-pipelined layer executor ---------------------------------

#[test]
fn pipeline_bit_identical_on_mixed_ticks_and_deeper_models() {
    // the pipelined executor must be invisible in outputs on the
    // hardest tick shape: mixed decode + prefill-chunk entries, a
    // deeper layer stack (more skewed iterations), and both the
    // compressed and dense key backends with PQ values
    let tok = ByteTokenizer::new();
    let long = tok.encode(
        "a long prompt that arrives in chunks while other sequences \
         keep decoding through the pipelined executor",
    );
    assert!(long.len() > BLOCK_TOKENS);
    for backend in [
        AttentionBackend::Lookat { m: 4, k: 64 },
        AttentionBackend::Fp16Exact,
    ] {
        let mk = |pipeline: bool| {
            let mut cfg = tiny_cfg_kv(
                backend.clone(),
                ValueBackend::Pq { m: 4, k: 64 },
                3,
            );
            cfg.model.n_layer = 4;
            cfg.pipeline = pipeline;
            cfg.prefill_chunk = 8;
            Engine::build(&cfg).unwrap()
        };
        let run = |e: &mut Engine| -> Vec<u32> {
            // two decoding sequences...
            e.start_seq(1, &tok.encode("steady decoder one")).unwrap();
            e.start_seq(2, &tok.encode("steady decoder two")).unwrap();
            // ...plus a prompt fed in chunks through mixed ticks
            e.begin_seq(3).unwrap();
            let mut toks = Vec::new();
            let mut off = 0usize;
            while off < long.len() {
                let end = (off + 8).min(long.len());
                let entries = vec![
                    TickEntry::Decode(1),
                    TickEntry::Decode(2),
                    TickEntry::Prefill {
                        seq: 3,
                        tokens: &long[off..end],
                    },
                ];
                let outs = e.step_batch(&entries).unwrap();
                toks.push(outs[0].token.unwrap());
                toks.push(outs[1].token.unwrap());
                off = end;
            }
            // all three decode together once the prefill lands
            for _ in 0..4 {
                let outs = e
                    .step_batch(&[
                        TickEntry::Decode(1),
                        TickEntry::Decode(2),
                        TickEntry::Decode(3),
                    ])
                    .unwrap();
                for o in outs {
                    toks.push(o.token.unwrap());
                }
            }
            toks
        };
        let mut on = mk(true);
        let mut off_e = mk(false);
        assert_eq!(
            run(&mut on),
            run(&mut off_e),
            "{backend:?}: pipeline on/off diverged"
        );
    }
}

#[test]
fn batch_composition_does_not_change_a_sequence() {
    // seq 0 decoded alongside 3 peers must equal seq 0 decoded alone —
    // the plan's items never interact
    let backend = AttentionBackend::Lookat { m: 4, k: 64 };
    let tok = ByteTokenizer::new();
    let ids = tok.encode("isolation check prompt");

    let mut alone = Engine::build(&tiny_cfg(backend.clone(), 2)).unwrap();
    alone.start_seq(0, &ids).unwrap();
    let alone_toks: Vec<u32> =
        (0..5).map(|_| alone.decode_one(0).unwrap()).collect();

    let mut crowd = Engine::build(&tiny_cfg(backend, 2)).unwrap();
    crowd.start_seq(0, &ids).unwrap();
    for i in 1..4u64 {
        crowd.start_seq(i, &tok.encode("peer sequence filler")).unwrap();
    }
    let mut crowd_toks = Vec::new();
    for _ in 0..5 {
        let t = crowd.decode_batch(&[0, 1, 2, 3]).unwrap();
        crowd_toks.push(t[0]);
    }
    assert_eq!(alone_toks, crowd_toks);
}
