//! Chaos suite: mixed workloads under deterministic seeded fault
//! plans. The invariants under test are the serving stack's failure
//! contracts, not its happy path:
//!
//!   * every submitted request reaches exactly one terminal state
//!     (completed / rejected / expired / quarantined) — nothing hangs,
//!     nothing is answered twice
//!   * after the drain, no cache state leaks: zero live tokens, zero
//!     allocated blocks, zero shared prefix refs, zero spill entries
//!   * faults degrade, never corrupt: requests that survive a faulty
//!     run produce bit-identical tokens to a fault-free run of the
//!     same workload (uniform policy, deterministic engine)
//!
//! Every plan is seeded, so failures replay exactly.

use lookat::coordinator::{
    AttentionBackend, Batcher, BatcherConfig, CompressionPolicy, Engine,
    EngineConfig, Request, SchedulerPolicy, ValueBackend,
};
use lookat::kvcache::CacheError;
use lookat::model::{ByteTokenizer, ModelConfig};
use lookat::util::fault::FaultPlan;

fn chaos_engine_cfg(blocks: usize, prefix: bool) -> EngineConfig {
    EngineConfig {
        model: ModelConfig::test_tiny(),
        backend: AttentionBackend::Lookat { m: 4, k: 64 },
        value_backend: ValueBackend::Fp32,
        seed: 1234,
        cache_blocks: blocks,
        calib_tokens: 64,
        decode_threads: 2,
        prefill_chunk: 32,
        pipeline: true,
        prefix_cache: prefix,
        policy: CompressionPolicy::Uniform,
        faults: Default::default(),
    }
}

fn chaos_batcher(
    blocks: usize,
    prefix: bool,
    engine_faults: &str,
    batcher_faults: &str,
) -> Batcher {
    let mut ecfg = chaos_engine_cfg(blocks, prefix);
    ecfg.faults = FaultPlan::parse(engine_faults).unwrap();
    let engine = Engine::build(&ecfg).unwrap();
    Batcher::new(
        engine,
        BatcherConfig {
            max_batch: 3,
            max_queue: 32,
            policy: SchedulerPolicy::Preempt,
            faults: FaultPlan::parse(batcher_faults).unwrap(),
            ..BatcherConfig::default()
        },
    )
}

fn workload(n: u64) -> Vec<Request> {
    let tok = ByteTokenizer::new();
    let prompts = [
        "chaos prompt one, short",
        "a second chaos prompt that runs a little longer than the first",
        "third — different length again to vary block usage",
        "fourth prompt",
    ];
    (0..n)
        .map(|i| Request {
            id: i,
            prompt: tok.encode(prompts[i as usize % prompts.len()]),
            max_new_tokens: 6 + (i as usize % 7),
            arrival_s: i as f64 * 0.002,
            timeout_ms: None,
        })
        .collect()
}

/// Seed override for CI's chaos matrix: `LOOKAT_FAULTS=seed:N` re-runs
/// every probabilistic plan in this suite under seed N — the contracts
/// (conservation, leak-freedom, survivor bit-parity) must hold for any
/// seed. `@N` nth-trigger clauses are deterministic and unaffected.
/// Locally, with the env unset, the baked-in seed is used.
fn seeded(spec: &str, default_seed: u64) -> String {
    let seed = std::env::var("LOOKAT_FAULTS")
        .ok()
        .and_then(|env| {
            env.split(',').find_map(|clause| {
                clause
                    .trim()
                    .strip_prefix("seed:")
                    .and_then(|v| v.parse::<u64>().ok())
            })
        })
        .unwrap_or(default_seed);
    format!("seed:{seed},{spec}")
}

/// Drive the batcher the way the serving loop does: tick errors are
/// logged-and-retried, tick panics quarantine the active set, and the
/// loop only exits when the scheduler is empty. Returns the number of
/// ticks that failed (err or panic).
fn drive_to_drain(b: &mut Batcher, reqs: Vec<Request>) -> usize {
    let mut pending: std::collections::VecDeque<Request> = reqs.into();
    let mut now = 0.0f64;
    let mut faults_seen = 0usize;
    let mut iters = 0usize;
    while !(pending.is_empty() && b.idle()) {
        while pending
            .front()
            .is_some_and(|r| r.arrival_s <= now)
        {
            let mut r = pending.pop_front().unwrap();
            r.arrival_s = now;
            b.submit(r);
        }
        b.admit(now);
        if b.active() > 0 {
            let step = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| b.step(now)),
            );
            match step {
                Ok(Ok(_)) => {}
                Ok(Err(_)) => faults_seen += 1, // retried next tick
                Err(_) => {
                    faults_seen += 1;
                    b.quarantine_active(now);
                }
            }
        }
        now += 0.005;
        iters += 1;
        assert!(iters < 20_000, "chaos run failed to drain");
    }
    faults_seen
}

/// One terminal reply per request, never two — the conservation law the
/// TCP server relies on to answer every connection exactly once.
fn assert_conservation(b: &Batcher, submitted: u64) {
    let mut terminal: Vec<u64> = b
        .completed
        .iter()
        .map(|c| c.id)
        .chain(b.rejected.iter().copied())
        .chain(b.expired.iter().copied())
        .chain(b.quarantined.iter().copied())
        .collect();
    terminal.sort_unstable();
    let before = terminal.len();
    terminal.dedup();
    assert_eq!(terminal.len(), before, "a request got two terminal states");
    assert_eq!(
        terminal,
        (0..submitted).collect::<Vec<u64>>(),
        "every request must reach exactly one terminal state"
    );
}

fn assert_no_leaks(b: &Batcher) {
    let stats = b.engine().cache_stats();
    assert_eq!(stats.tokens, 0, "live tokens leaked past drain");
    assert_eq!(stats.blocks_allocated, 0, "blocks leaked past drain");
    assert_eq!(stats.shared_blocks, 0, "shared prefix refs leaked");
    assert_eq!(b.engine().prefix_entries(), 0, "prefix entries leaked");
}

/// Baseline sanity: the chaos harness itself, with no plan armed.
#[test]
fn fault_free_chaos_workload_completes_everything() {
    let mut b = chaos_batcher(64, false, "", "");
    let n = 12;
    let faults = drive_to_drain(&mut b, workload(n));
    assert_eq!(faults, 0);
    assert_eq!(b.completed.len(), n as usize);
    assert_conservation(&b, n);
    assert_no_leaks(&b);
}

#[test]
fn mixed_workload_under_alloc_faults_conserves_requests() {
    // ~15% of engine block-demand checks fail; the Preempt scheduler
    // retries / evicts around them and every request still terminates
    let mut b = chaos_batcher(64, false, &seeded("alloc:0.15", 5), "");
    let n = 12;
    drive_to_drain(&mut b, workload(n));
    assert_conservation(&b, n);
    assert_no_leaks(&b);
    // alloc faults are retryable: nothing should have been lost to
    // quarantine, and the plan must actually have fired
    assert!(b.quarantined.is_empty());
    assert!(
        b.engine()
            .metrics()
            .counter(lookat::telemetry::Ctr::FaultsInjected)
            > 0,
        "plan never fired — the test is vacuous"
    );
}

#[test]
fn tick_errors_and_panics_still_conserve_requests() {
    // tick 4 errors (retried), tick 9 panics (active set quarantined);
    // later requests are served by the surviving loop
    let mut b = chaos_batcher(64, false, "", "tick:err@4,tick:panic@9");
    let n = 10;
    let faults = drive_to_drain(&mut b, workload(n));
    assert!(faults >= 2, "both planned faults must fire, saw {faults}");
    assert_conservation(&b, n);
    assert_no_leaks(&b);
    assert!(!b.quarantined.is_empty(), "the panic must quarantine");
    assert!(!b.completed.is_empty(), "serving must continue after it");
}

#[test]
fn deadline_storm_conserves_requests_and_blocks() {
    // alternating impossible (1ms) and unlimited deadlines over a
    // cache under alloc faults: expiries must free their blocks even
    // while the allocator is misbehaving
    let mut b = chaos_batcher(64, false, &seeded("alloc:0.1", 11), "");
    let n = 12;
    let mut reqs = workload(n);
    for (i, r) in reqs.iter_mut().enumerate() {
        if i % 2 == 0 {
            r.timeout_ms = Some(1);
        }
    }
    drive_to_drain(&mut b, reqs);
    assert_conservation(&b, n);
    assert_no_leaks(&b);
    assert!(!b.expired.is_empty(), "1ms deadlines must expire");
    // unlimited-deadline requests are never expired by mistake
    for id in &b.expired {
        assert_eq!(id % 2, 0, "only even ids carried the 1ms deadline");
    }
}

/// The headline degradation contract: a faulty run's *survivors* are
/// bit-identical to the fault-free run. Faults may change *which*
/// requests finish, never *what* they say.
#[test]
fn surviving_outputs_match_fault_free_run_bit_for_bit() {
    let n = 12;
    let run = |engine_faults: &str, batcher_faults: &str| {
        let mut b =
            chaos_batcher(24, false, engine_faults, batcher_faults);
        drive_to_drain(&mut b, workload(n));
        assert_conservation(&b, n);
        assert_no_leaks(&b);
        let mut out: Vec<(u64, Vec<u32>)> = b
            .completed
            .iter()
            .map(|c| (c.id, c.generated.clone()))
            .collect();
        out.sort();
        out
    };
    let clean = run("", "");
    assert_eq!(clean.len(), n as usize, "fault-free run must complete all");
    // 24-block cache under preemption + alloc/swap faults + tick churn
    let faulty = run(
        &seeded("alloc:0.1,swap_in:err@2", 3),
        "tick:err@5,tick:panic@11",
    );
    assert!(!faulty.is_empty(), "some requests must survive the storm");
    let reference: std::collections::HashMap<u64, &Vec<u32>> =
        clean.iter().map(|(id, toks)| (*id, toks)).collect();
    for (id, toks) in &faulty {
        assert_eq!(
            Some(toks),
            reference.get(id).copied(),
            "request {id}'s tokens drifted under faults"
        );
    }
}

#[test]
fn prefix_attach_fault_degrades_to_a_miss_with_identical_tokens() {
    let tok = ByteTokenizer::new();
    let system = "shared chaos system preamble ".repeat(3);
    let reqs = || -> Vec<Request> {
        (0..4u64)
            .map(|i| Request {
                id: i,
                prompt: tok.encode(&format!("{system}tail {i}")),
                max_new_tokens: 8,
                arrival_s: i as f64 * 0.002,
                timeout_ms: None,
            })
            .collect()
    };
    let run = |faults: &str| {
        let mut ecfg = chaos_engine_cfg(96, true);
        ecfg.faults = FaultPlan::parse(faults).unwrap();
        let mut b = Batcher::new(
            ecfg_build(ecfg),
            BatcherConfig {
                max_batch: 2,
                max_queue: 16,
                policy: SchedulerPolicy::Fcfs,
                ..BatcherConfig::default()
            },
        );
        drive_to_drain(&mut b, reqs());
        assert_eq!(b.completed.len(), 4);
        assert_no_leaks(&b);
        let mut out: Vec<(u64, Vec<u32>)> = b
            .completed
            .iter()
            .map(|c| (c.id, c.generated.clone()))
            .collect();
        out.sort();
        (out, b.prefix_hits)
    };
    let (clean, hits_clean) = run("");
    // every prefix attach is refused: the lookup degrades to a miss
    // (full re-prefill), and the tokens don't move a bit
    let (faulty, hits_faulty) = run("prefix:err");
    assert_eq!(clean, faulty, "prefix-miss fallback changed tokens");
    assert!(hits_clean > 0, "clean run must actually share the prefix");
    assert_eq!(hits_faulty, 0, "every attach was fault-refused");
}

fn ecfg_build(cfg: EngineConfig) -> Engine {
    Engine::build(&cfg).unwrap()
}

// ---- engine-level integrity checks (swap checksums) ----

#[test]
fn corrupted_swap_slab_is_never_restored_and_reprefill_matches() {
    let tok = ByteTokenizer::new();
    let ids = tok.encode("checksummed swap victim prompt");
    // reference: uninterrupted run
    let mut reference =
        Engine::build(&chaos_engine_cfg(32, false)).unwrap();
    reference.start_seq(1, &ids).unwrap();
    let want: Vec<u32> =
        (0..5).map(|_| reference.decode_one(1).unwrap()).collect();

    let mut e = Engine::build(&chaos_engine_cfg(32, false)).unwrap();
    e.start_seq(1, &ids).unwrap();
    e.swap_out(1).unwrap();
    assert!(e.corrupt_swapped(1), "no spill entry to corrupt");
    match e.swap_in(1) {
        Err(CacheError::Corrupt(seq)) => assert_eq!(seq, 1),
        other => panic!("corrupt swap-in must fail, got {other:?}"),
    }
    assert!(
        !e.is_swapped(1),
        "poisoned spill entries must be discarded, not retried"
    );
    assert_eq!(e.cache_stats().blocks_allocated, 0, "restore leaked");
    assert_eq!(
        e.metrics()
            .counter(lookat::telemetry::Ctr::ChecksumFailures),
        1
    );
    // the fallback path: re-prefill from tokens, bit-identical tokens
    e.start_seq(1, &ids).unwrap();
    let got: Vec<u32> =
        (0..5).map(|_| e.decode_one(1).unwrap()).collect();
    assert_eq!(got, want);
}

#[test]
fn injected_swap_in_fault_purges_the_spill_entry() {
    let tok = ByteTokenizer::new();
    let ids = tok.encode("swap-in fault victim");
    let mut cfg = chaos_engine_cfg(32, false);
    cfg.faults = FaultPlan::parse("swap_in:err@1").unwrap();
    let mut e = Engine::build(&cfg).unwrap();
    e.start_seq(1, &ids).unwrap();
    e.swap_out(1).unwrap();
    match e.swap_in(1) {
        Err(CacheError::Injected(site)) => assert_eq!(site, "swap_in"),
        other => panic!("expected the injected fault, got {other:?}"),
    }
    assert!(!e.is_swapped(1), "fault fallback must purge the entry");
    assert_eq!(e.cache_stats().blocks_allocated, 0);
    // the engine is healthy afterwards: same id can re-prefill
    e.start_seq(1, &ids).unwrap();
    e.decode_one(1).unwrap();
}
