//! Bench T4: regenerates paper Table 4 (equal-memory head-to-head).
//!
//!   cargo bench --bench table4_memory_budget

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let rows = lookat::experiments::table4::run(false)?;
    println!(
        "\n[bench] table4 regenerated in {:.1}s ({} budgets)",
        t0.elapsed().as_secs_f64(),
        rows.len()
    );
    Ok(())
}
