//! Microbenchmarks of the hot paths (the §Perf targets in DESIGN.md):
//! ADC scan throughput, LUT build, PQ encode, K-Means, exact-attention
//! matvec baseline, KV-cache append/gather, and the fused decode step.
//!
//!   cargo bench --bench micro_hotpaths

use lookat::attention;
use lookat::kvcache::{KeyStorage, KvCache, ValueStorage};
use lookat::pq::{kmeans::kmeans, LookupTable, PqCodec, TrainOpts};
use lookat::util::bench::{black_box, Bench};
use lookat::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new();
    let d_k = 64;
    let l = 512;
    let mut rng = Pcg32::seed(0xBE7C);
    let keys: Vec<f32> = (0..l * d_k).map(|_| rng.next_f32_std()).collect();
    let values: Vec<f32> =
        (0..l * d_k).map(|_| rng.next_f32_std()).collect();
    let q: Vec<f32> = (0..d_k).map(|_| rng.next_f32_std()).collect();

    // --- exact score scan (the baseline LOOKAT replaces) --------------
    let mut scores = vec![0.0f32; l];
    b.run_throughput(
        "exact_scores/L512_d64",
        l as f64,
        (l * d_k * 4) as f64,
        || {
            for i in 0..l {
                scores[i] = lookat::tensor::dot(
                    &q, &keys[i * d_k..(i + 1) * d_k]);
            }
            black_box(&scores);
        },
    );

    // --- ADC scan for each paper m -------------------------------------
    for m in [2usize, 4, 8, 16] {
        let codec = PqCodec::train(
            &keys, d_k, m, 256,
            &TrainOpts { iters: 5, ..Default::default() });
        let codes = codec.encode_batch(&keys, l);
        let lut = LookupTable::build(&q, &codec.codebook);
        b.run_throughput(
            &format!("adc_scan/m{m}_L512"),
            l as f64,
            (l * m) as f64,
            || {
                lut.scores_into(&codes, l, &mut scores);
                black_box(&scores);
            },
        );
        b.run_items(&format!("lut_build/m{m}_K256"), (m * 256) as f64, || {
            black_box(LookupTable::build(&q, &codec.codebook));
        });
        b.run_items(&format!("pq_encode/m{m}"), 1.0, || {
            black_box(codec.encode(&q));
        });
    }

    // --- full attention steps ------------------------------------------
    let codec4 = PqCodec::train(
        &keys, d_k, 4, 256, &TrainOpts { iters: 5, ..Default::default() });
    let codes4 = codec4.encode_batch(&keys, l);
    b.run_items("attention/exact_L512", l as f64, || {
        black_box(attention::exact_attention(&q, &keys, &values, l));
    });
    b.run_items("attention/lookat4_L512", l as f64, || {
        black_box(attention::lookat_attention(
            &q, &codes4, &codec4, &values, l));
    });
    b.run_items("attention/int4_L512", l as f64, || {
        black_box(attention::scalar_quant_attention(
            &q, &keys, &values, l, 4));
    });

    // --- K-Means training (codebook build cost) -------------------------
    let sub: Vec<f32> = keys[..l * 16].to_vec();
    b.run("kmeans/K64_d16_n512_it5", || {
        let mut r = Pcg32::seed(3);
        black_box(kmeans(&sub, 16, 64, 5, 1e-4, &mut r));
    });

    // --- KV-cache ops ----------------------------------------------------
    let h = 12;
    let kv: Vec<f32> = (0..h * d_k).map(|_| rng.next_f32_std()).collect();
    b.run_items("kvcache/append_fp16_12h", 1.0, || {
        let mut c = KvCache::new(
            h, d_k, 24, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        for _ in 0..256 {
            c.append(1, &kv, &kv).unwrap();
        }
        black_box(c.stats());
    });
    let codecs: Vec<PqCodec> = (0..h)
        .map(|_| {
            PqCodec::train(&keys, d_k, 4, 256,
                           &TrainOpts { iters: 3, ..Default::default() })
        })
        .collect();
    let storage = KeyStorage::pq(codecs)?;
    b.run_items("kvcache/append_pq4_12h", 1.0, || {
        let mut c = KvCache::new(
            h, d_k, 24, storage.clone(), ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        for _ in 0..256 {
            c.append(1, &kv, &kv).unwrap();
        }
        black_box(c.stats());
    });
    {
        let mut c = KvCache::new(
            h, d_k, 24, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        for _ in 0..512 {
            c.append(1, &kv, &kv).unwrap();
        }
        let mut out = Vec::new();
        b.run_throughput(
            "kvcache/gather_keys_L512",
            512.0,
            (512 * d_k * 4) as f64,
            || {
                c.gather_keys_into(1, 3, &mut out).unwrap();
                black_box(&out);
            },
        );
        // the zero-copy path the LOOKAT kernel uses instead of gathering
        // (reads every value lane so the byte count matches the work)
        b.run_throughput(
            "kvcache/block_scan_values_L512",
            512.0,
            (512 * d_k * 4) as f64,
            || {
                let mut acc = 0.0f32;
                for blk in c.blocks(1, 3).unwrap() {
                    for v in blk.values {
                        acc += *v;
                    }
                }
                black_box(acc);
            },
        );
    }

    b.write_report("micro_hotpaths")?;
    println!("\n[bench] micro_hotpaths written to artifacts/reports/");
    Ok(())
}
