//! Serving throughput bench: the coordinator end-to-end on the same
//! trace under every backend — decode tok/s, TTFT, peak key-cache bytes.
//!
//!   cargo bench --bench serving_throughput

use lookat::coordinator::{
    AttentionBackend, BatcherConfig, EngineConfig, Router, RouterConfig,
};
use lookat::model::ModelConfig;
use lookat::util::json::Json;
use lookat::workload::{TraceConfig, TraceGenerator};

fn bench_backend(backend: AttentionBackend)
    -> anyhow::Result<lookat::coordinator::ServingReport>
{
    let mut model = ModelConfig::gpt2_layer0();
    model.n_layer = 2;
    let mut router = Router::build(RouterConfig {
        engine: EngineConfig {
            model,
            backend,
            seed: 77,
            cache_blocks: 512,
            calib_tokens: 192,
        },
        batcher: BatcherConfig { max_batch: 4, max_queue: 256 },
        max_prompt_tokens: 96,
    })?;
    let trace = TraceGenerator::new(TraceConfig {
        rate: 50.0, // saturating: throughput-bound measurement
        num_requests: 16,
        prompt_chars: (150, 350),
        gen_tokens: (8, 16),
        seed: 5150,
    })
    .generate();
    let reqs = router.tokenize_trace(&trace);
    let report = router.serve_trace(reqs)?;
    println!("{}", report.pretty());
    Ok(report)
}

fn main() -> anyhow::Result<()> {
    let backends = [
        AttentionBackend::Fp16Exact,
        AttentionBackend::ScalarQuant { bits: 8 },
        AttentionBackend::ScalarQuant { bits: 4 },
        AttentionBackend::Lookat { m: 4, k: 256 },
        AttentionBackend::Lookat { m: 2, k: 256 },
    ];
    let mut arr = Vec::new();
    for b in backends {
        let report = bench_backend(b)?;
        arr.push(report.to_json());
    }
    let dir = lookat::experiments::report::reports_dir();
    std::fs::create_dir_all(&dir)?;
    std::fs::write(
        dir.join("serving_throughput.json"),
        Json::Arr(arr).to_string_pretty(),
    )?;
    println!("\n[bench] serving_throughput written to artifacts/reports/");
    Ok(())
}
