//! Serving throughput bench: the coordinator end-to-end on the same
//! trace under every (key backend × value backend) × decode batch
//! width — decode tok/s, TTFT, peak key- and value-cache bytes.
//!
//!   cargo bench --bench serving_throughput
//!
//! Each backend builds one engine (so codebook training and weight init
//! stay out of the comparison) and serves a fresh copy of the same
//! 16-request trace at batch widths 1, 4 and 16. Batch 1 is the serial
//! baseline; wider batches exercise the batched decode pipeline's
//! (seq, head) fan-out. Two artifacts are written:
//!
//! * `artifacts/reports/serving_throughput.json` — full per-run reports
//! * `<repo root>/BENCH_serving.json` — the machine-readable perf
//!   trajectory CI uploads (tokens/s per backend per batch width, plus
//!   the batch-16-vs-1 speedup)

use lookat::coordinator::{
    AttentionBackend, BatcherConfig, EngineConfig, Router, RouterConfig,
    ValueBackend,
};
use lookat::model::ModelConfig;
use lookat::util::json::Json;
use lookat::workload::{TraceConfig, TraceGenerator};

const BATCH_SIZES: [usize; 3] = [1, 4, 16];

/// Short prompts, long generations: decode throughput (the batched
/// pipeline) is the quantity under test, so generation dominates.
fn trace() -> Vec<lookat::workload::RequestSpec> {
    TraceGenerator::new(TraceConfig {
        rate: 1000.0, // saturating: throughput-bound measurement
        num_requests: 16,
        prompt_chars: (10, 30),
        gen_tokens: (48, 64),
        seed: 5150,
    })
    .generate()
}

fn bench_backend(
    backend: AttentionBackend,
    value_backend: ValueBackend,
) -> anyhow::Result<Json> {
    let mut model = ModelConfig::gpt2_layer0();
    model.n_layer = 2;
    let mut router = Router::build(RouterConfig {
        engine: EngineConfig {
            model,
            backend,
            value_backend,
            seed: 77,
            cache_blocks: 512,
            calib_tokens: 192,
            decode_threads: 0,
        },
        batcher: BatcherConfig { max_batch: 1, max_queue: 256 },
        max_prompt_tokens: 96,
    })?;

    // the entry's name is the report's own label (Engine::label):
    // fp32-value combos keep the bare key-backend name, so the CI
    // regression gate matches them against pre-value-sweep baselines
    let mut o = Json::obj();
    let mut runs = Vec::new();
    let mut tok_s_by_batch = Vec::new();
    for &bs in &BATCH_SIZES {
        router.set_max_batch(bs);
        let reqs = router.tokenize_trace(&trace());
        let report = router.serve_trace(reqs)?;
        println!("batch={bs:<3} {}", report.pretty());
        if runs.is_empty() {
            o.set("backend", Json::Str(report.backend.clone()));
        }
        tok_s_by_batch.push(report.throughput_tok_s());
        o.set(
            &format!("batch_{bs}_tok_s"),
            Json::Num(report.throughput_tok_s()),
        );
        let mut run = report.to_json();
        run.set("batch", Json::Num(bs as f64));
        runs.push(run);
    }
    o.set(
        "speedup_b16_vs_b1",
        Json::Num(tok_s_by_batch[2] / tok_s_by_batch[0].max(1e-12)),
    );
    o.set("runs", Json::Arr(runs));
    Ok(o)
}

fn main() -> anyhow::Result<()> {
    let combos = [
        // the pre-existing key-backend sweep (fp32 values)
        (AttentionBackend::Fp16Exact, ValueBackend::Fp32),
        (AttentionBackend::ScalarQuant { bits: 8 }, ValueBackend::Fp32),
        (AttentionBackend::ScalarQuant { bits: 4 }, ValueBackend::Fp32),
        (AttentionBackend::Lookat { m: 4, k: 256 }, ValueBackend::Fp32),
        (AttentionBackend::Lookat { m: 2, k: 256 }, ValueBackend::Fp32),
        // value-backend sweep: lookat-kv (fully compressed, fused
        // blocked weighted decode) at the paper's 32x and combined-64x
        // configurations, plus the int-key x pq-value combination
        (
            AttentionBackend::Lookat { m: 4, k: 256 },
            ValueBackend::Pq { m: 8, k: 256 },
        ),
        (
            AttentionBackend::Lookat { m: 2, k: 256 },
            ValueBackend::Pq { m: 2, k: 256 },
        ),
        (
            AttentionBackend::ScalarQuant { bits: 8 },
            ValueBackend::Pq { m: 8, k: 256 },
        ),
    ];
    let mut results = Vec::new();
    for (b, vb) in combos {
        results.push(bench_backend(b, vb)?);
    }

    let mut top = Json::obj();
    top.set("bench", Json::Str("serving_throughput".into()));
    top.set(
        "batch_sizes",
        Json::Arr(BATCH_SIZES.iter().map(|&b| Json::Num(b as f64)).collect()),
    );
    top.set(
        "threads",
        Json::Num(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1) as f64,
        ),
    );
    top.set("results", Json::Arr(results));

    // full per-run reports next to the other experiment artifacts
    let dir = lookat::experiments::report::reports_dir();
    std::fs::create_dir_all(&dir)?;
    std::fs::write(
        dir.join("serving_throughput.json"),
        top.to_string_pretty(),
    )?;

    // machine-readable perf trajectory at the repo root for CI upload
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("BENCH_serving.json");
    std::fs::write(&root, top.to_string_pretty())?;
    println!(
        "\n[bench] serving_throughput written to artifacts/reports/ and {}",
        root.display()
    );
    Ok(())
}
