//! Serving throughput bench: the coordinator end-to-end on the same
//! trace under every (key backend × value backend) × decode batch
//! width — decode tok/s, TTFT, peak key- and value-cache bytes.
//!
//!   cargo bench --bench serving_throughput
//!
//! Each backend builds one engine (so codebook training and weight init
//! stay out of the comparison) and serves a fresh copy of the same
//! 16-request trace at batch widths 1, 4 and 16. Batch 1 is the serial
//! baseline; wider batches exercise the batched decode pipeline's
//! (seq, head) fan-out. Two artifacts are written:
//!
//! * `artifacts/reports/serving_throughput.json` — full per-run reports
//! * `<repo root>/BENCH_serving.json` — the machine-readable perf
//!   trajectory CI uploads (tokens/s per backend per batch width with
//!   per-phase breakdowns, the batch-16-vs-1 speedup, and the
//!   scenarios: the oversubscribed long-prompt interference run under
//!   fcfs-monolithic vs preempt + chunked prefill, the 12-layer
//!   `--pipeline on|off` A/B of the software-pipelined layer executor,
//!   the preempt-heavy swap-tier A/B recording swap-vs-reprefill
//!   speedup, the shared-system-prompt prefix-cache A/B recording
//!   blocks shared, and the compression-policy sweep (uniform vs
//!   calibrated-at-equal-bits vs norm-pruning, with the calibrated
//!   run's worst per-(layer,head) rho) — `lookat bench-check` gates
//!   every scenario's
//!   `*_tok_s` metric alongside the backend sweep, and each backend's
//!   batch-16 `ttft_p99_s` / `tick_p99_s` tail latencies from the
//!   telemetry histograms, lower-is-better)

use lookat::coordinator::{
    AttentionBackend, BatcherConfig, CompressionPolicy, EngineConfig,
    Router, RouterConfig, SchedulerPolicy, ValueBackend,
};
use lookat::model::ModelConfig;
use lookat::util::json::Json;
use lookat::workload::{
    Genre, RequestSpec, TraceConfig, TraceGenerator,
};

const BATCH_SIZES: [usize; 3] = [1, 4, 16];

/// Short prompts, long generations: decode throughput (the batched
/// pipeline) is the quantity under test, so generation dominates.
fn trace() -> Vec<lookat::workload::RequestSpec> {
    TraceGenerator::new(TraceConfig {
        rate: 1000.0, // saturating: throughput-bound measurement
        num_requests: 16,
        prompt_chars: (10, 30),
        gen_tokens: (48, 64),
        seed: 5150,
    })
    .generate()
}

fn bench_backend(
    backend: AttentionBackend,
    value_backend: ValueBackend,
) -> anyhow::Result<Json> {
    let mut model = ModelConfig::gpt2_layer0();
    model.n_layer = 2;
    let mut router = Router::build(RouterConfig {
        engine: EngineConfig {
            model,
            backend,
            value_backend,
            seed: 77,
            cache_blocks: 512,
            calib_tokens: 192,
            decode_threads: 0,
            prefill_chunk: 0,
            pipeline: true,
            prefix_cache: false,
            policy: CompressionPolicy::Uniform,
            faults: Default::default(),
        },
        batcher: BatcherConfig {
            max_batch: 1,
            max_queue: 256,
            policy: SchedulerPolicy::Fcfs,
            ..BatcherConfig::default()
        },
        max_prompt_tokens: 96,
    })?;

    // the entry's name is the report's own label (Engine::label):
    // fp32-value combos keep the bare key-backend name, so the CI
    // regression gate matches them against pre-value-sweep baselines
    let mut o = Json::obj();
    let mut runs = Vec::new();
    let mut tok_s_by_batch = Vec::new();
    for &bs in &BATCH_SIZES {
        router.set_max_batch(bs);
        let reqs = router.tokenize_trace(&trace());
        let report = router.serve_trace(reqs)?;
        println!("batch={bs:<3} {}", report.pretty());
        if runs.is_empty() {
            o.set("backend", Json::Str(report.backend.clone()));
        }
        tok_s_by_batch.push(report.throughput_tok_s());
        o.set(
            &format!("batch_{bs}_tok_s"),
            Json::Num(report.throughput_tok_s()),
        );
        // tail-latency series from the batch-16 run's telemetry
        // histograms: *_p99_s keys are gated lower-is-better by
        // `lookat bench-check` (with one-bucket slack for the
        // sqrt(2)-spaced histogram quantization)
        if bs == 16 {
            if let Some(p) = report.ttft_hist.p99() {
                o.set("ttft_p99_s", Json::Num(p));
            }
            if let Some(p) = report.tick_hist.p99() {
                o.set("tick_p99_s", Json::Num(p));
            }
        }
        let mut run = report.to_json();
        run.set("batch", Json::Num(bs as f64));
        runs.push(run);
    }
    o.set(
        "speedup_b16_vs_b1",
        Json::Num(tok_s_by_batch[2] / tok_s_by_batch[0].max(1e-12)),
    );
    o.set("runs", Json::Arr(runs));
    Ok(o)
}

/// The scheduler scenarios: decode throughput under long-prompt
/// interference and oversubscription.
///
/// 16 decode-heavy short requests arrive at a steady rate (batch width
/// 16); one 1024-token prompt lands mid-stream. Three runs:
///
/// * `baseline` — the short trace alone (preempt + chunked config, so
///   the comparison isolates the long prompt, not the scheduler)
/// * `fcfs_monolithic` — long prompt included, FCFS admission and
///   one-shot prefill (the head-of-line stall this PR removes)
/// * `preempt_chunked` — long prompt included, `--prefill-chunk 128
///   --scheduler preempt`: the prefill rides mixed ticks and decode
///   keeps flowing
///
/// The headline figure is `preempt_chunked_vs_baseline` — decode
/// tokens/s retained under interference (target: ≥ 0.8).
fn scheduler_scenarios() -> anyhow::Result<Json> {
    const LONG_PROMPT_TOKENS: usize = 1024;

    let build = |policy: SchedulerPolicy, chunk: usize| {
        let mut model = ModelConfig::gpt2_layer0();
        model.n_layer = 2;
        // room for the 1024-token prompt plus its generation
        model.max_pos = 1280;
        Router::build(RouterConfig {
            engine: EngineConfig {
                model,
                backend: AttentionBackend::Lookat { m: 4, k: 256 },
                value_backend: ValueBackend::Fp32,
                seed: 77,
                cache_blocks: 128,
                calib_tokens: 192,
                decode_threads: 0,
                prefill_chunk: chunk,
                pipeline: true,
                prefix_cache: false,
                policy: CompressionPolicy::Uniform,
                faults: Default::default(),
            },
            batcher: BatcherConfig {
                max_batch: 16,
                max_queue: 256,
                policy,
                ..BatcherConfig::default()
            },
            max_prompt_tokens: LONG_PROMPT_TOKENS,
        })
    };

    let shorts = || {
        TraceGenerator::new(TraceConfig {
            rate: 6.0,
            num_requests: 16,
            prompt_chars: (20, 60),
            gen_tokens: (48, 64),
            seed: 4242,
        })
        .generate()
    };
    let with_long = || {
        let mut specs = shorts();
        specs.push(RequestSpec {
            id: 1000,
            arrival_s: 1.0, // mid-stream: shorts are already decoding
            genre: Genre::Prose,
            prompt: lookat::workload::Corpus::new(Genre::Prose, 99)
                .generate(LONG_PROMPT_TOKENS),
            gen_tokens: 8,
        });
        // keep arrival order for the router's delivery loop
        specs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        specs
    };

    let run = |router: &mut Router, specs: Vec<RequestSpec>| {
        let reqs = router.tokenize_trace(&specs);
        router.serve_trace(reqs)
    };

    let mut baseline_router =
        build(SchedulerPolicy::Preempt, 128)?;
    let baseline = run(&mut baseline_router, shorts())?;
    println!("scenario baseline        {}", baseline.pretty());

    let mut fcfs_router = build(SchedulerPolicy::Fcfs, 0)?;
    let fcfs = run(&mut fcfs_router, with_long())?;
    println!("scenario fcfs-monolithic {}", fcfs.pretty());

    let mut pre_router = build(SchedulerPolicy::Preempt, 128)?;
    let pre = run(&mut pre_router, with_long())?;
    println!("scenario preempt-chunked {}", pre.pretty());

    let ratio = pre.throughput_tok_s()
        / baseline.throughput_tok_s().max(1e-12);
    println!(
        "scenario long_prompt: preempt+chunked retains {:.0}% of the \
         no-long-prompt decode tok/s (fcfs-monolithic: {:.0}%)",
        ratio * 100.0,
        fcfs.throughput_tok_s()
            / baseline.throughput_tok_s().max(1e-12)
            * 100.0
    );

    let mut o = Json::obj();
    o.set("scenario", Json::Str("long_prompt_oversubscribed".into()));
    o.set("batch", Json::Num(16.0));
    o.set("long_prompt_tokens", Json::Num(LONG_PROMPT_TOKENS as f64));
    o.set("baseline_tok_s", Json::Num(baseline.throughput_tok_s()));
    o.set("fcfs_monolithic_tok_s", Json::Num(fcfs.throughput_tok_s()));
    o.set("preempt_chunked_tok_s", Json::Num(pre.throughput_tok_s()));
    o.set("preempt_chunked_vs_baseline", Json::Num(ratio));
    o.set("preemptions", Json::Num(pre.preemptions as f64));
    o.set(
        "completed",
        Json::Num((baseline.completed.len()
            + fcfs.completed.len()
            + pre.completed.len()) as f64),
    );
    Ok(o)
}

/// The layer-pipeline scenario: gpt2_small depth (12 layers) decoding
/// a steady batch, `--pipeline on` vs `--pipeline off`. Deep models
/// are where the software-pipelined executor earns its keep — each
/// tick crosses the layer loop 12 times, so overlapping one group's
/// attention/MLP with the other group's QKV and appends compounds.
/// Outputs are bit-identical between the two runs (asserted in
/// tests/decode_parity.rs); this records the throughput delta.
fn pipeline_scenario() -> anyhow::Result<Json> {
    let build = |pipeline: bool| {
        let mut model = ModelConfig::gpt2_layer0();
        model.n_layer = 12; // gpt2_small depth
        Router::build(RouterConfig {
            engine: EngineConfig {
                model,
                backend: AttentionBackend::Lookat { m: 4, k: 256 },
                value_backend: ValueBackend::Fp32,
                seed: 77,
                cache_blocks: 256,
                calib_tokens: 128,
                decode_threads: 0,
                prefill_chunk: 0,
                pipeline,
                prefix_cache: false,
                policy: CompressionPolicy::Uniform,
                faults: Default::default(),
            },
            batcher: BatcherConfig {
                max_batch: 16,
                max_queue: 64,
                policy: SchedulerPolicy::Fcfs,
                ..BatcherConfig::default()
            },
            max_prompt_tokens: 48,
        })
    };
    let trace = || {
        TraceGenerator::new(TraceConfig {
            rate: 1000.0,
            num_requests: 16,
            prompt_chars: (10, 30),
            gen_tokens: (12, 16),
            seed: 6160,
        })
        .generate()
    };

    let mut off_router = build(false)?;
    let reqs = off_router.tokenize_trace(&trace());
    let off = off_router.serve_trace(reqs)?;
    println!("scenario pipeline-off    {}", off.pretty());
    drop(off_router);

    let mut on_router = build(true)?;
    let reqs = on_router.tokenize_trace(&trace());
    let on = on_router.serve_trace(reqs)?;
    println!("scenario pipeline-on     {}", on.pretty());

    let speedup =
        on.throughput_tok_s() / off.throughput_tok_s().max(1e-12);
    println!(
        "scenario layer_pipeline: 12-layer decode tok/s {:.1} -> {:.1} \
         ({speedup:.2}x with --pipeline on)",
        off.throughput_tok_s(),
        on.throughput_tok_s()
    );

    let mut o = Json::obj();
    o.set("scenario", Json::Str("layer_pipeline_12l".into()));
    o.set("batch", Json::Num(16.0));
    o.set("layers", Json::Num(12.0));
    o.set("pipeline_off_tok_s", Json::Num(off.throughput_tok_s()));
    o.set("pipeline_on_tok_s", Json::Num(on.throughput_tok_s()));
    o.set("pipeline_speedup", Json::Num(speedup));
    o.set("pipeline_off_phases", off.phases.to_json());
    o.set("pipeline_on_phases", on.phases.to_json());
    Ok(o)
}

/// The swap-tier scenario: an oversubscribed preempt-heavy trace
/// (12 medium-context requests over a 10-block cache at batch width 8)
/// served twice — `--swap off` re-prefills every preemption victim,
/// `--swap on` spills its blocks to the host-side store and restores
/// them with a copy. The headline figure is `swap_vs_reprefill`:
/// decode tokens/s with the swap tier relative to the recompute path
/// (outputs are bit-identical either way; tests/decode_parity.rs
/// asserts it).
fn swap_scenario() -> anyhow::Result<Json> {
    let build = |swap: bool| {
        let mut model = ModelConfig::gpt2_layer0();
        model.n_layer = 2;
        Router::build(RouterConfig {
            engine: EngineConfig {
                model,
                backend: AttentionBackend::Lookat { m: 4, k: 256 },
                value_backend: ValueBackend::Fp32,
                seed: 77,
                cache_blocks: 10,
                calib_tokens: 128,
                decode_threads: 0,
                prefill_chunk: 32,
                pipeline: true,
                prefix_cache: false,
                policy: CompressionPolicy::Uniform,
                faults: Default::default(),
            },
            batcher: BatcherConfig {
                max_batch: 8,
                max_queue: 64,
                policy: SchedulerPolicy::Preempt,
                swap,
                ..BatcherConfig::default()
            },
            max_prompt_tokens: 96,
        })
    };
    let trace = || {
        TraceGenerator::new(TraceConfig {
            rate: 1000.0,
            num_requests: 12,
            prompt_chars: (100, 200),
            gen_tokens: (24, 48),
            seed: 7411,
        })
        .generate()
    };

    let mut off_router = build(false)?;
    let reqs = off_router.tokenize_trace(&trace());
    let off = off_router.serve_trace(reqs)?;
    println!("scenario swap-off        {}", off.pretty());
    drop(off_router);

    let mut on_router = build(true)?;
    let reqs = on_router.tokenize_trace(&trace());
    let on = on_router.serve_trace(reqs)?;
    println!("scenario swap-on         {}", on.pretty());

    let speedup =
        on.throughput_tok_s() / off.throughput_tok_s().max(1e-12);
    println!(
        "scenario swap_preempt_heavy: decode tok/s {:.1} -> {:.1} \
         ({speedup:.2}x with --swap on; {} spills, {} restores)",
        off.throughput_tok_s(),
        on.throughput_tok_s(),
        on.swap_outs,
        on.swap_ins
    );

    let mut o = Json::obj();
    o.set("scenario", Json::Str("swap_preempt_heavy".into()));
    o.set("batch", Json::Num(8.0));
    o.set("swap_off_tok_s", Json::Num(off.throughput_tok_s()));
    o.set("swap_on_tok_s", Json::Num(on.throughput_tok_s()));
    o.set("swap_vs_reprefill", Json::Num(speedup));
    o.set("preemptions", Json::Num(on.preemptions as f64));
    o.set("swap_outs", Json::Num(on.swap_outs as f64));
    o.set("swap_ins", Json::Num(on.swap_ins as f64));
    Ok(o)
}

/// The prefix-cache scenario: twelve sessions opening with the same
/// 160-char system prompt (5 full blocks at 32 tokens/block) and
/// distinct tails, served at batch width 4 with `--prefix-cache off`
/// vs `on`. Generation lengths are staggered so completions free
/// slots one at a time and every later admission overlaps live prefix
/// holders. Records the shared-prefill speedup plus how many physical
/// blocks sharing saved at peak.
fn prefix_scenario() -> anyhow::Result<Json> {
    let build = |prefix_cache: bool| {
        let mut model = ModelConfig::gpt2_layer0();
        model.n_layer = 2;
        Router::build(RouterConfig {
            engine: EngineConfig {
                model,
                backend: AttentionBackend::Lookat { m: 4, k: 256 },
                value_backend: ValueBackend::Fp32,
                seed: 77,
                cache_blocks: 128,
                calib_tokens: 128,
                decode_threads: 0,
                prefill_chunk: 0,
                pipeline: true,
                prefix_cache,
                policy: CompressionPolicy::Uniform,
                faults: Default::default(),
            },
            batcher: BatcherConfig {
                max_batch: 4,
                max_queue: 64,
                policy: SchedulerPolicy::Fcfs,
                ..BatcherConfig::default()
            },
            max_prompt_tokens: 256,
        })
    };
    let specs = || -> Vec<RequestSpec> {
        let system = lookat::workload::Corpus::new(Genre::Technical, 31)
            .generate(160);
        (0..12u64)
            .map(|i| RequestSpec {
                id: i,
                arrival_s: 0.0,
                genre: Genre::Technical,
                prompt: format!(
                    "{system} session {i}: {}",
                    lookat::workload::Corpus::new(Genre::Prose, 100 + i)
                        .generate(30)
                ),
                gen_tokens: 12 + (i as usize % 5),
            })
            .collect()
    };

    let mut off_router = build(false)?;
    let reqs = off_router.tokenize_trace(&specs());
    let off = off_router.serve_trace(reqs)?;
    println!("scenario prefix-off      {}", off.pretty());
    drop(off_router);

    let mut on_router = build(true)?;
    let reqs = on_router.tokenize_trace(&specs());
    let on = on_router.serve_trace(reqs)?;
    println!("scenario prefix-on       {}", on.pretty());

    let speedup =
        on.throughput_tok_s() / off.throughput_tok_s().max(1e-12);
    println!(
        "scenario shared_prefix: decode tok/s {:.1} -> {:.1} \
         ({speedup:.2}x with --prefix-cache on; {} hits, \
         {} blocks shared at peak)",
        off.throughput_tok_s(),
        on.throughput_tok_s(),
        on.prefix_hits,
        on.shared_blocks_peak
    );

    let mut o = Json::obj();
    o.set("scenario", Json::Str("shared_prefix".into()));
    o.set("batch", Json::Num(4.0));
    o.set("prefix_off_tok_s", Json::Num(off.throughput_tok_s()));
    o.set("prefix_on_tok_s", Json::Num(on.throughput_tok_s()));
    o.set("prefix_speedup", Json::Num(speedup));
    o.set("prefix_hits", Json::Num(on.prefix_hits as f64));
    o.set(
        "shared_blocks_peak",
        Json::Num(on.shared_blocks_peak as f64),
    );
    Ok(o)
}

/// The compression-policy ablation: the same decode-heavy trace served
/// under `--policy uniform`, `--policy calibrated-<bits>` at *exactly*
/// the uniform spend (2 layers × 12 heads × m=4 × 8 bits = 768
/// bits/token, so the comparison is heterogeneous-vs-flat allocation
/// at equal budget, not more-bits-vs-fewer), and `--policy prune-0.1`.
/// Records tok/s per policy (gated by `lookat bench-check` like every
/// other scenario `*_tok_s`), the calibrated run's worst
/// per-(layer,head) rank correlation and realized bits/token, and the
/// pruned-token count.
fn policy_scenario() -> anyhow::Result<Json> {
    let build = |policy: CompressionPolicy| {
        let mut model = ModelConfig::gpt2_layer0();
        model.n_layer = 2;
        Router::build(RouterConfig {
            engine: EngineConfig {
                model,
                backend: AttentionBackend::Lookat { m: 4, k: 256 },
                value_backend: ValueBackend::Fp32,
                seed: 77,
                cache_blocks: 512,
                calib_tokens: 192,
                decode_threads: 0,
                prefill_chunk: 0,
                pipeline: true,
                prefix_cache: false,
                policy,
                faults: Default::default(),
            },
            batcher: BatcherConfig {
                max_batch: 16,
                max_queue: 256,
                policy: SchedulerPolicy::Fcfs,
                ..BatcherConfig::default()
            },
            max_prompt_tokens: 96,
        })
    };
    const UNIFORM_BITS: usize = 2 * 12 * 4 * 8;

    let mut reports = Vec::new();
    for policy in [
        CompressionPolicy::Uniform,
        CompressionPolicy::Calibrated { bits: UNIFORM_BITS },
        CompressionPolicy::Prune { frac: 0.1 },
    ] {
        let mut router = build(policy)?;
        let reqs = router.tokenize_trace(&trace());
        let report = router.serve_trace(reqs)?;
        println!("scenario policy          {}", report.pretty());
        reports.push(report);
    }
    let (uni, cal, pru) = (&reports[0], &reports[1], &reports[2]);
    println!(
        "scenario policy_sweep: tok/s uniform {:.1} / calibrated {:.1} \
         / prune {:.1}; calibrated min-rho {:.4} at {} bits/token \
         (uniform spends {UNIFORM_BITS}); {} tokens pruned",
        uni.throughput_tok_s(),
        cal.throughput_tok_s(),
        pru.throughput_tok_s(),
        cal.min_rho(),
        cal.policy_bits_per_token,
        pru.pruned_tokens
    );

    let mut o = Json::obj();
    o.set("scenario", Json::Str("compression_policy_sweep".into()));
    o.set("batch", Json::Num(16.0));
    o.set("policy_uniform_tok_s", Json::Num(uni.throughput_tok_s()));
    o.set("policy_calibrated_tok_s", Json::Num(cal.throughput_tok_s()));
    o.set("policy_prune_tok_s", Json::Num(pru.throughput_tok_s()));
    o.set("calibrated_min_rho", Json::Num(cal.min_rho()));
    o.set(
        "calibrated_bits_per_token",
        Json::Num(cal.policy_bits_per_token as f64),
    );
    o.set(
        "uniform_bits_per_token",
        Json::Num(uni.policy_bits_per_token as f64),
    );
    o.set("pruned_tokens", Json::Num(pru.pruned_tokens as f64));
    Ok(o)
}

fn main() -> anyhow::Result<()> {
    let combos = [
        // the pre-existing key-backend sweep (fp32 values)
        (AttentionBackend::Fp16Exact, ValueBackend::Fp32),
        (AttentionBackend::ScalarQuant { bits: 8 }, ValueBackend::Fp32),
        (AttentionBackend::ScalarQuant { bits: 4 }, ValueBackend::Fp32),
        (AttentionBackend::Lookat { m: 4, k: 256 }, ValueBackend::Fp32),
        (AttentionBackend::Lookat { m: 2, k: 256 }, ValueBackend::Fp32),
        // value-backend sweep: lookat-kv (fully compressed, fused
        // blocked weighted decode) at the paper's 32x and combined-64x
        // configurations, plus the int-key x pq-value combination
        (
            AttentionBackend::Lookat { m: 4, k: 256 },
            ValueBackend::Pq { m: 8, k: 256 },
        ),
        (
            AttentionBackend::Lookat { m: 2, k: 256 },
            ValueBackend::Pq { m: 2, k: 256 },
        ),
        (
            AttentionBackend::ScalarQuant { bits: 8 },
            ValueBackend::Pq { m: 8, k: 256 },
        ),
        // combined-compression 4-bit mode: K=16 keys and values at 2m
        // subspaces — same bytes/token as the (m, K=256) rows above,
        // served by the nibble-packed SIMD shuffle scan. New label
        // ("lookat-8+k16+vpq-8+k16/<isa>"), so the baseline gate picks
        // it up as a fresh series
        (
            AttentionBackend::Lookat { m: 8, k: 16 },
            ValueBackend::Pq { m: 8, k: 16 },
        ),
    ];
    let mut results = Vec::new();
    for (b, vb) in combos {
        results.push(bench_backend(b, vb)?);
    }
    let scenarios = scheduler_scenarios()?;
    let pipeline = pipeline_scenario()?;
    let swap = swap_scenario()?;
    let prefix = prefix_scenario()?;
    let policy = policy_scenario()?;

    let mut top = Json::obj();
    top.set("bench", Json::Str("serving_throughput".into()));
    top.set(
        "scenarios",
        Json::Arr(vec![scenarios, pipeline, swap, prefix, policy]),
    );
    top.set(
        "batch_sizes",
        Json::Arr(BATCH_SIZES.iter().map(|&b| Json::Num(b as f64)).collect()),
    );
    top.set(
        "threads",
        Json::Num(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1) as f64,
        ),
    );
    top.set("results", Json::Arr(results));

    // full per-run reports next to the other experiment artifacts
    let dir = lookat::experiments::report::reports_dir();
    std::fs::create_dir_all(&dir)?;
    std::fs::write(
        dir.join("serving_throughput.json"),
        top.to_string_pretty(),
    )?;

    // machine-readable perf trajectory at the repo root for CI upload
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("BENCH_serving.json");
    std::fs::write(&root, top.to_string_pretty())?;
    println!(
        "\n[bench] serving_throughput written to artifacts/reports/ and {}",
        root.display()
    );
    Ok(())
}
