//! ADC scan micro-bench: the fast-scan (subspace-major lane) kernel vs
//! the token-major flat kernel, per subspace count.
//!
//!   cargo bench --bench adc_scan
//!
//! Measures raw scan throughput (GB/s of code bytes streamed, and
//! scored tokens/s) for every unrolled `m` specialization plus the
//! generic path, in both layouts over the same codes — plus, per `m`,
//! the pinned-scalar lane scan and the nibble-packed K=16 shuffle scan
//! at matched code bits (2m subspaces of 4 bits = m bytes/token, same
//! stream size, directly comparable GB/s). Lanes are built
//! at [`BLOCK_TOKENS`]-token groups — exactly the paged cache's block
//! shape — so the figures are the serving hot path's, not a synthetic
//! best case. Two artifacts are written:
//!
//! * `artifacts/reports/adc_scan.json` — full measurements
//! * `<repo root>/BENCH_adc.json` — the machine-readable perf
//!   trajectory CI uploads next to `BENCH_serving.json`; its `results`
//!   entries carry `scan_gb_s` / `scan_tok_s` metrics, which `lookat
//!   bench-check` discovers and gates alongside the serving figures

use lookat::kvcache::BLOCK_TOKENS;
use lookat::pq::{simd, Codebook, LookupTable};
use lookat::testkit::fixtures::{interleave_lanes, interleave_lanes_packed};
use lookat::util::bench::{black_box, Bench};
use lookat::util::json::Json;
use lookat::util::rng::Pcg32;

/// Tokens scanned per iteration (128 cache blocks' worth).
const N_TOKENS: usize = 128 * BLOCK_TOKENS;
const D_K: usize = 64;
const K: usize = 256;

/// Random codebook + codes: scan cost does not depend on centroid
/// values, so no k-means training is needed for a scan bench.
fn setup_k(m: usize, k: usize) -> (LookupTable, Vec<u8>) {
    let mut rng = Pcg32::seed(0xADC + (m * k) as u64);
    let d_sub = D_K / m;
    let centroids: Vec<Vec<f32>> = (0..m)
        .map(|_| (0..k * d_sub).map(|_| rng.next_f32_std()).collect())
        .collect();
    let cb = Codebook::new(m, k, d_sub, centroids);
    let query: Vec<f32> = (0..D_K).map(|_| rng.next_f32_std()).collect();
    let lut = LookupTable::build(&query, &cb);
    let codes: Vec<u8> =
        (0..N_TOKENS * m).map(|_| rng.next_bounded(k as u32) as u8).collect();
    (lut, codes)
}

fn setup(m: usize) -> (LookupTable, Vec<u8>) {
    setup_k(m, K)
}

fn result_entry(
    label: String,
    m: usize,
    layout: &str,
    path: &str,
    meas: &lookat::util::bench::Measurement,
) -> Json {
    let mut o = Json::obj();
    o.set("backend", Json::Str(label));
    o.set("m", Json::Num(m as f64));
    o.set("layout", Json::Str(layout.to_string()));
    o.set("path", Json::Str(path.to_string()));
    o.set(
        "scan_tok_s",
        Json::Num(meas.throughput_items_per_s().unwrap_or(0.0)),
    );
    o.set(
        "scan_gb_s",
        Json::Num(meas.throughput_gb_per_s().unwrap_or(0.0)),
    );
    o.set("median_s", Json::Num(meas.median_s));
    o
}

fn main() -> anyhow::Result<()> {
    let mut results = Vec::new();
    let mut bench = Bench::new();
    // 32 exercises the generic (non-unrolled) kernel
    for m in [2usize, 4, 8, 16, 32] {
        let (lut, codes) = setup(m);
        let lanes = interleave_lanes(&codes, m, BLOCK_TOKENS);
        let bytes = (N_TOKENS * m) as f64;

        let mut out = vec![0.0f32; N_TOKENS];
        let flat = bench
            .run_throughput(
                &format!("adc_scan/flat/m{m}"),
                N_TOKENS as f64,
                bytes,
                || {
                    lut.scores_into(&codes, N_TOKENS, &mut out);
                    black_box(out[N_TOKENS - 1]);
                },
            )
            .clone();

        let mut lane_out = Vec::with_capacity(N_TOKENS);
        let grouped = bench
            .run_throughput(
                &format!("adc_scan/lanes/m{m}"),
                N_TOKENS as f64,
                bytes,
                || {
                    lane_out.clear();
                    lut.scores_lanes(
                        lanes.iter().map(|(l, n)| (&l[..], *n)),
                        &mut lane_out,
                    );
                    black_box(lane_out[N_TOKENS - 1]);
                },
            )
            .clone();

        for (layout, meas) in [("flat", &flat), ("lanes", &grouped)] {
            // historical labels: no path suffix, so the perf trajectory
            // stays one series per (m, layout) across machines
            results.push(result_entry(
                format!("adc-m{m}-{layout}"),
                m,
                layout,
                simd::scan_path(),
                meas,
            ));
        }

        // pinned-scalar K=256 lane scan — the dispatch's reference
        // series, and the packed comparison's "before" number
        let mut scal_out = Vec::with_capacity(N_TOKENS);
        let lanes_scalar = bench
            .run_throughput(
                &format!("adc_scan/lanes-scalar/m{m}"),
                N_TOKENS as f64,
                bytes,
                || {
                    scal_out.clear();
                    lut.scores_lanes_scalar(
                        lanes.iter().map(|(l, n)| (&l[..], *n)),
                        &mut scal_out,
                    );
                    black_box(scal_out[N_TOKENS - 1]);
                },
            )
            .clone();
        results.push(result_entry(
            format!("adc-m{m}-lanes-scalar"),
            m,
            "lanes",
            "scalar",
            &lanes_scalar,
        ));

        // 4-bit fast-scan at matched code bits: K=16 with 2m subspaces
        // streams the same m bytes/token as K=256 with m, so the GB/s
        // columns are directly comparable
        let mm = 2 * m;
        let (lut16, codes16) = setup_k(mm, 16);
        let packed = interleave_lanes_packed(&codes16, mm, BLOCK_TOKENS);
        let mut p_out = Vec::with_capacity(N_TOKENS);
        let packed_simd = bench
            .run_throughput(
                &format!("adc_scan/packed16/m{mm}"),
                N_TOKENS as f64,
                bytes,
                || {
                    p_out.clear();
                    lut16.scores_lanes_packed(
                        packed.iter().map(|(l, n)| (&l[..], *n)),
                        &mut p_out,
                    );
                    black_box(p_out[N_TOKENS - 1]);
                },
            )
            .clone();
        results.push(result_entry(
            format!("adc-m{mm}-packed16/{}", simd::scan_path()),
            mm,
            "packed16",
            simd::scan_path(),
            &packed_simd,
        ));
        let packed_scalar = bench
            .run_throughput(
                &format!("adc_scan/packed16-scalar/m{mm}"),
                N_TOKENS as f64,
                bytes,
                || {
                    p_out.clear();
                    lut16.scores_lanes_packed_scalar(
                        packed.iter().map(|(l, n)| (&l[..], *n)),
                        &mut p_out,
                    );
                    black_box(p_out[N_TOKENS - 1]);
                },
            )
            .clone();
        results.push(result_entry(
            format!("adc-m{mm}-packed16-scalar"),
            mm,
            "packed16",
            "scalar",
            &packed_scalar,
        ));

        println!(
            "m={m:<3} lanes/flat speedup: {:.2}x  \
             packed16(2m,{})/scalar-lanes: {:.2}x  \
             packed16 simd/scalar: {:.2}x",
            flat.median_s / grouped.median_s.max(1e-12),
            simd::scan_path(),
            lanes_scalar.median_s / packed_simd.median_s.max(1e-12),
            packed_scalar.median_s / packed_simd.median_s.max(1e-12),
        );
    }

    let mut top = Json::obj();
    top.set("bench", Json::Str("adc_scan".into()));
    top.set("tokens_per_iter", Json::Num(N_TOKENS as f64));
    top.set("group_tokens", Json::Num(BLOCK_TOKENS as f64));
    top.set("scan_path", Json::Str(simd::scan_path().to_string()));
    top.set("results", Json::Arr(results));

    let dir = lookat::experiments::report::reports_dir();
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("adc_scan.json"), top.to_string_pretty())?;

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("BENCH_adc.json");
    std::fs::write(&root, top.to_string_pretty())?;
    println!(
        "\n[bench] adc_scan written to artifacts/reports/ and {}",
        root.display()
    );
    Ok(())
}
