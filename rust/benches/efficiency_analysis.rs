//! Bench E1: paper §4.7 efficiency analysis — analytic FLOP/bandwidth
//! model plus measured exact-vs-ADC score-phase timings on this host.
//!
//!   cargo bench --bench efficiency_analysis

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    lookat::experiments::efficiency::run(false)?;
    println!(
        "\n[bench] efficiency analysis done in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
