//! Ablation bench A1: value-compression extension (paper §5.2).
//!
//!   cargo bench --bench ablation_values

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let rows = lookat::experiments::ablation_values::run(false)?;
    println!(
        "\n[bench] ablation_values regenerated in {:.1}s ({} configs)",
        t0.elapsed().as_secs_f64(),
        rows.len()
    );
    Ok(())
}
