//! Bench F3: regenerates paper Figure 3 (four panels + Pareto frontier),
//! emitting the CSV series for external plotting.
//!
//!   cargo bench --bench figure3_pareto

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let fig = lookat::experiments::figure3::run(false)?;
    println!(
        "\n[bench] figure3 regenerated in {:.1}s (frontier: {})",
        t0.elapsed().as_secs_f64(),
        fig.pareto.join(", ")
    );
    Ok(())
}
