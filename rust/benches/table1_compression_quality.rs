//! Bench T1: regenerates paper Table 1 (compression vs quality) at full
//! size and times the per-method evaluation cost.
//!
//!   cargo bench --bench table1_compression_quality

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let rows = lookat::experiments::table1::run(false)?;
    println!(
        "\n[bench] table1 regenerated in {:.1}s ({} methods × 3 samples)",
        t0.elapsed().as_secs_f64(),
        rows.len()
    );
    Ok(())
}
