//! Bench F4: regenerates paper Figure 4 (attention-pattern
//! reconstruction across the three genres).
//!
//!   cargo bench --bench figure4_attention_maps

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let maps = lookat::experiments::figure4::run(false)?;
    let (lo, hi) = maps.iter().fold((f64::MAX, 0.0f64), |(lo, hi), m| {
        (lo.min(m.kl), hi.max(m.kl))
    });
    println!(
        "\n[bench] figure4 regenerated in {:.1}s — per-genre KL range \
         {lo:.2}–{hi:.2} nats (paper caption: 2.17–5.16)",
        t0.elapsed().as_secs_f64(),
    );
    Ok(())
}
