//! Bench T3: regenerates paper Table 3 (quality vs sequence length,
//! LOOKAT-4, L up to 1024).
//!
//!   cargo bench --bench table3_long_context

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let rows = lookat::experiments::table3::run(false)?;
    println!(
        "\n[bench] table3 regenerated in {:.1}s ({} lengths)",
        t0.elapsed().as_secs_f64(),
        rows.len()
    );
    Ok(())
}
