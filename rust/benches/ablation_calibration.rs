//! Ablation bench A3: calibration-transfer matrix (paper §5.1).
//!
//!   cargo bench --bench ablation_calibration

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let m = lookat::experiments::ablation_calibration::run(false)?;
    let gap =
        lookat::experiments::ablation_calibration::transfer_gap(&m.cosine);
    println!(
        "\n[bench] ablation_calibration regenerated in {:.1}s \
         (in-domain − cross-domain cosine gap: {gap:.4})",
        t0.elapsed().as_secs_f64(),
    );
    Ok(())
}
