//! Bench T2: regenerates paper Table 2 (subspace granularity ablation).
//!
//!   cargo bench --bench table2_subspace_ablation

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let rows = lookat::experiments::table2::run(false)?;
    println!(
        "\n[bench] table2 regenerated in {:.1}s ({} granularities)",
        t0.elapsed().as_secs_f64(),
        rows.len()
    );
    Ok(())
}
