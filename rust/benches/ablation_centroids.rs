//! Ablation bench A2: centroid-count sweep validating Proposition 1.
//!
//!   cargo bench --bench ablation_centroids

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let rows = lookat::experiments::ablation_centroids::run(false)?;
    let c = lookat::experiments::ablation_centroids::fit_constant(&rows);
    println!(
        "\n[bench] ablation_centroids regenerated in {:.1}s \
         (fitted 1-rho ≈ {c:.3}·d_k/(mK))",
        t0.elapsed().as_secs_f64(),
    );
    Ok(())
}
