//! TCP front-end: newline-delimited JSON over a socket, backed by the
//! batcher/engine. This is the "launcher" face of the coordinator — a
//! client connects, sends one request per line, and receives one JSON
//! response per line when its generation completes.
//!
//! Protocol (UTF-8, one JSON object per line):
//!   → {"prompt": "text...", "max_new_tokens": 16}
//!   ← {"id": 3, "text": "...", "prompt_tokens": 12, "ttft_ms": 41.2,
//!      "e2e_ms": 180.5, "tokens": 16}
//!   ← {"error": "...", "id": 3}   (overload / never-schedulable — the
//!      id lets clients correlate; always sent on the rejected
//!      request's own connection)
//!   ← {"error": "..."}            (malformed request: no id assigned)
//!
//! Requests may carry `"timeout_ms"`: past that deadline (or the
//! server-wide `--timeout-ms` default) the request is expired — blocks
//! reclaimed, `{"error": "deadline", "id"}` answered.
//!
//! Control verbs share the wire (answered out of band by the serving
//! loop, so the numbers come from the thread that owns the engine):
//!   → {"cmd": "stats"}       ← telemetry snapshot (counters / gauges /
//!                              histogram percentiles) + "uptime_s"
//!   → {"cmd": "trace-dump"}  ← {"trace": "<chrome trace_event json>"}
//!                              when started with a trace sink, else
//!                              {"error": ...}
//!   → {"cmd": "drain"}       ← {"ok": "draining", ...}; stops
//!                              admissions (later requests get
//!                              {"error": "draining", "id"}), finishes
//!                              or deadline-expires everything in
//!                              flight, then shuts the server down
//!
//! With `metrics_addr` set, a sidecar thread additionally serves the
//! registry in Prometheus text exposition format over plain HTTP GET.
//!
//! tokio is not vendored offline; the server uses one acceptor thread,
//! one serving thread driving the batcher, and per-connection reader
//! threads feeding a shared queue (see util::threadpool for the pool
//! primitive this reuses).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::request::Request;
use crate::model::ByteTokenizer;
use crate::telemetry::{Gauge, MetricsRegistry, TraceRing};
use crate::util::json::Json;

/// Events the per-request trace ring retains before overwriting the
/// oldest — ~6 per request-lifecycle plus one per tick, so this covers
/// tens of thousands of requests of lookback.
const TRACE_RING_EVENTS: usize = 65536;

/// Per-connection socket write timeout: a client that stops reading
/// stalls only its own replies, never the serving loop's other
/// connections (writes happen under that connection's own lock).
const WRITE_TIMEOUT: std::time::Duration =
    std::time::Duration::from_secs(5);

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub engine: EngineConfig,
    pub batcher: BatcherConfig,
    pub max_prompt_tokens: usize,
    /// bind address, e.g. "127.0.0.1:7070" (port 0 = ephemeral)
    pub addr: String,
    /// optional Prometheus text-exposition endpoint, e.g.
    /// "127.0.0.1:9091" (port 0 = ephemeral; `None` = disabled)
    pub metrics_addr: Option<String>,
    /// optional Chrome trace_event sink: enables the in-memory trace
    /// ring and writes its contents to this path on shutdown
    pub trace_out: Option<String>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            engine: EngineConfig::default(),
            batcher: BatcherConfig::default(),
            max_prompt_tokens: 120,
            addr: "127.0.0.1:0".into(),
            metrics_addr: None,
            trace_out: None,
        }
    }
}

/// Control verbs answered by the serving loop itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Control {
    Stats,
    TraceDump,
    Drain,
}

enum Inbound {
    Request {
        req: Request,
        conn: Arc<Mutex<TcpStream>>,
    },
    Control {
        verb: Control,
        conn: Arc<Mutex<TcpStream>>,
    },
}

/// A running server; `shutdown()` joins all threads immediately,
/// `drain()` answers everything in flight first.
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    /// where the Prometheus sidecar bound, when enabled
    pub metrics_addr: Option<std::net::SocketAddr>,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving in background threads.
    ///
    /// The engine is constructed *inside* the serving thread: the PJRT
    /// client (used by the `Pjrt*` backends) holds non-`Send` handles,
    /// so it must live and die on the thread that drives it.
    pub fn start(cfg: ServerConfig) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));
        let queue: Arc<Mutex<Vec<Inbound>>> = Arc::new(Mutex::new(Vec::new()));
        let next_id = Arc::new(AtomicU64::new(0));

        // acceptor thread: accepts connections, spawns reader threads
        let acc_stop = stop.clone();
        let acc_queue = queue.clone();
        let max_prompt = cfg.max_prompt_tokens;
        let acceptor = std::thread::Builder::new()
            .name("lookat-acceptor".into())
            .spawn(move || {
                let mut readers = Vec::new();
                while !acc_stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // a client that stops reading must stall
                            // only its own replies (shared with the
                            // clone: SO_SNDTIMEO is per-socket)
                            stream.set_write_timeout(Some(WRITE_TIMEOUT))
                                .ok();
                            let writer = match stream.try_clone() {
                                Ok(w) => w,
                                Err(e) => {
                                    crate::log_error!(
                                        "accept: stream clone failed, \
                                         dropping connection: {e}"
                                    );
                                    continue;
                                }
                            };
                            let conn = Arc::new(Mutex::new(writer));
                            let q = acc_queue.clone();
                            let ids = next_id.clone();
                            let rstop = acc_stop.clone();
                            readers.push(std::thread::spawn(move || {
                                reader_loop(stream, conn, q, ids, rstop,
                                            max_prompt);
                            }));
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            std::thread::sleep(
                                std::time::Duration::from_millis(5),
                            );
                        }
                        Err(_) => break,
                    }
                }
                for r in readers {
                    let _ = r.join();
                }
            })?;

        // the engine is born on the serving thread, so the metrics
        // sidecar learns about its registry through this slot
        let registry: Arc<OnceLock<Arc<MetricsRegistry>>> =
            Arc::new(OnceLock::new());
        let tracer: Option<Arc<TraceRing>> = cfg
            .trace_out
            .as_ref()
            .map(|_| Arc::new(TraceRing::new(TRACE_RING_EVENTS)));

        // serving thread: builds the engine, drains the queue into the
        // batcher, steps it, writes completions back to their connections
        let srv_stop = stop.clone();
        let srv_draining = draining.clone();
        let srv_queue = queue.clone();
        let engine_cfg = cfg.engine.clone();
        let batcher_cfg = cfg.batcher.clone();
        let srv_registry = registry.clone();
        let srv_tracer = tracer.clone();
        let trace_out = cfg.trace_out.clone();
        let server_thread = std::thread::Builder::new()
            .name("lookat-server".into())
            .spawn(move || {
                let engine = match Engine::build(&engine_cfg) {
                    Ok(e) => e,
                    Err(e) => {
                        crate::log_error!("engine build failed: {e:#}");
                        srv_stop.store(true, Ordering::SeqCst);
                        return;
                    }
                };
                let _ = srv_registry.set(engine.metrics());
                let mut batcher = Batcher::new(engine, batcher_cfg);
                if let Some(t) = &srv_tracer {
                    batcher.set_tracer(t.clone());
                }
                serve_loop(batcher, srv_queue, srv_stop, srv_draining);
                if let (Some(t), Some(path)) = (&srv_tracer, &trace_out) {
                    match std::fs::write(path, t.dump_chrome_json()) {
                        Ok(()) => crate::log_info!(
                            "wrote request trace to {path}"
                        ),
                        Err(e) => crate::log_error!(
                            "trace write to {path} failed: {e}"
                        ),
                    }
                }
            })?;

        let mut threads = vec![acceptor, server_thread];

        // optional Prometheus sidecar: plain HTTP, text exposition
        let mut metrics_addr = None;
        if let Some(addr) = &cfg.metrics_addr {
            let ml = TcpListener::bind(addr)?;
            ml.set_nonblocking(true)?;
            metrics_addr = Some(ml.local_addr()?);
            let m_stop = stop.clone();
            let m_registry = registry.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("lookat-metrics".into())
                    .spawn(move || {
                        metrics_loop(ml, m_registry, m_stop);
                    })?,
            );
        }

        Ok(Server {
            local_addr,
            metrics_addr,
            stop,
            draining,
            threads,
        })
    }

    /// Signal shutdown and join all threads. In-flight work is
    /// abandoned; use [`Server::drain`] to answer it first.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Graceful drain: stop admitting new requests (they are answered
    /// `{"error": "draining"}`), finish or deadline-expire everything
    /// already in flight, answer it all, then shut down and join. The
    /// serving loop records the tail time in the `drain_duration_ms`
    /// gauge.
    pub fn drain(mut self) {
        self.draining.store(true, Ordering::SeqCst);
        // the serving thread flips `stop` once the batcher is empty
        // and every queued line has been answered
        while !self.stop.load(Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Block until the server stops on its own — a wire-initiated
    /// `{"cmd": "drain"}` ran dry, or the engine failed to build —
    /// then join all threads. This is the CLI's foreground wait: it
    /// never returns while the server is healthy and undrained.
    pub fn wait(mut self) {
        while !self.stop.load(Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn reader_loop(
    stream: TcpStream,
    conn: Arc<Mutex<TcpStream>>,
    queue: Arc<Mutex<Vec<Inbound>>>,
    next_id: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    max_prompt: usize,
) {
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(100)))
        .ok();
    let mut reader = BufReader::new(stream);
    let tok = ByteTokenizer::new();
    let mut line = String::new();
    while !stop.load(Ordering::SeqCst) {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF
            Ok(_) => {
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                match parse_inbound(
                    trimmed, &tok, &next_id, max_prompt, &conn,
                ) {
                    Ok(inbound) => {
                        queue.lock().unwrap().push(inbound);
                    }
                    Err(msg) => {
                        let mut err = Json::obj();
                        err.set("error", Json::Str(msg));
                        write_line(&conn, &err);
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
    }
}

fn parse_inbound(
    line: &str,
    tok: &ByteTokenizer,
    next_id: &AtomicU64,
    max_prompt: usize,
    conn: &Arc<Mutex<TcpStream>>,
) -> Result<Inbound, String> {
    let j = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
        let verb = match cmd {
            "stats" => Control::Stats,
            "trace-dump" => Control::TraceDump,
            "drain" => Control::Drain,
            other => return Err(format!("unknown cmd '{other}'")),
        };
        return Ok(Inbound::Control {
            verb,
            conn: conn.clone(),
        });
    }
    let prompt = j
        .get("prompt")
        .and_then(|p| p.as_str())
        .ok_or("missing 'prompt'")?;
    if prompt.is_empty() {
        return Err("empty prompt".into());
    }
    let max_new = j
        .get("max_new_tokens")
        .and_then(|n| n.as_usize())
        .unwrap_or(16)
        .clamp(1, 256);
    let timeout_ms = j
        .get("timeout_ms")
        .and_then(|n| n.as_usize())
        .map(|ms| ms as u64);
    Ok(Inbound::Request {
        req: Request {
            id: next_id.fetch_add(1, Ordering::SeqCst),
            prompt: tok.encode_clamped(prompt, max_prompt),
            max_new_tokens: max_new,
            arrival_s: 0.0, // stamped by the serving loop
            timeout_ms,
        },
        conn: conn.clone(),
    })
}

fn serve_loop(
    mut batcher: Batcher,
    queue: Arc<Mutex<Vec<Inbound>>>,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
) {
    let t0 = std::time::Instant::now();
    let tok = ByteTokenizer::new();
    // request id -> connection to answer on
    let mut conns: std::collections::HashMap<u64, Arc<Mutex<TcpStream>>> =
        std::collections::HashMap::new();
    // when the drain began, for the drain_duration_ms gauge
    let mut drain_started: Option<f64> = None;
    loop {
        let now = t0.elapsed().as_secs_f64();
        if draining.load(Ordering::SeqCst) && drain_started.is_none() {
            drain_started = Some(now);
        }
        // ingest — a full queue pushes the id onto `batcher.rejected`,
        // answered with every other rejection in the drain below.
        // Control verbs are answered here, from the engine-owning
        // thread, so stats reads never race a tick. Collected first:
        // answering a slow client must not hold the reader queue lock.
        let drained: Vec<Inbound> =
            std::mem::take(&mut *queue.lock().unwrap());
        for inbound in drained {
            match inbound {
                Inbound::Request { mut req, conn } => {
                    if drain_started.is_some() {
                        // admissions are closed; answer immediately so
                        // the client never waits on a draining server
                        let mut err = Json::obj();
                        err.set("error", Json::Str("draining".into()));
                        err.set("id", Json::Num(req.id as f64));
                        write_line(&conn, &err);
                        continue;
                    }
                    req.arrival_s = now;
                    conns.insert(req.id, conn);
                    let _ = batcher.submit(req);
                }
                Inbound::Control { verb: Control::Stats, conn } => {
                    let mut o = batcher
                        .engine()
                        .metrics()
                        .snapshot()
                        .to_json();
                    o.set("uptime_s", Json::Num(now));
                    write_line(&conn, &o);
                }
                Inbound::Control { verb: Control::TraceDump, conn } => {
                    let mut o = Json::obj();
                    match batcher.tracer() {
                        Some(t) => o.set(
                            "trace",
                            Json::Str(t.dump_chrome_json()),
                        ),
                        None => o.set(
                            "error",
                            Json::Str(
                                "tracing disabled (start the server \
                                 with --trace-out)"
                                    .into(),
                            ),
                        ),
                    }
                    write_line(&conn, &o);
                }
                Inbound::Control { verb: Control::Drain, conn } => {
                    draining.store(true, Ordering::SeqCst);
                    if drain_started.is_none() {
                        drain_started = Some(now);
                    }
                    let mut o = Json::obj();
                    o.set("ok", Json::Str("draining".into()));
                    o.set("queued", Json::Num(batcher.queued() as f64));
                    o.set("active", Json::Num(batcher.active() as f64));
                    write_line(&conn, &o);
                }
            }
        }
        // work — the tick runs under catch_unwind so one poisoned
        // sequence (or an injected panic) never kills the server: the
        // active set is quarantined, answered below, and the loop goes
        // on serving
        batcher.admit(now);
        let idle = batcher.active() == 0;
        if !idle {
            let step_now = t0.elapsed().as_secs_f64();
            match std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| batcher.step(step_now)),
            ) {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => {
                    crate::log_error!("batcher step failed: {e:#}");
                }
                Err(payload) => {
                    let msg = panic_message(payload.as_ref());
                    let ids = batcher.quarantine_active(step_now);
                    crate::log_error!(
                        "batcher tick panicked ({msg}); quarantined \
                         {} active sequence(s), serving continues",
                        ids.len()
                    );
                }
            }
        }
        // respond — completions first, then every terminal error
        // (rejected, deadline-expired, quarantined), each on the
        // request's own connection so no client hangs
        for done in batcher.completed.drain(..) {
            if let Some(conn) = conns.remove(&done.id) {
                let mut o = Json::obj();
                o.set("id", Json::Num(done.id as f64));
                o.set("text", Json::Str(tok.decode(&done.generated)));
                o.set("prompt_tokens",
                      Json::Num(done.prompt_tokens as f64));
                o.set("tokens", Json::Num(done.generated.len() as f64));
                o.set("ttft_ms", Json::Num(done.ttft() * 1e3));
                o.set("e2e_ms", Json::Num(done.e2e() * 1e3));
                write_line(&conn, &o);
            }
        }
        for id in batcher.rejected.drain(..) {
            if let Some(conn) = conns.remove(&id) {
                let mut err = Json::obj();
                err.set(
                    "error",
                    Json::Str(
                        "request rejected (overload or does not fit)"
                            .into(),
                    ),
                );
                err.set("id", Json::Num(id as f64));
                write_line(&conn, &err);
            }
        }
        for id in batcher.expired.drain(..) {
            if let Some(conn) = conns.remove(&id) {
                let mut err = Json::obj();
                err.set("error", Json::Str("deadline".into()));
                err.set("id", Json::Num(id as f64));
                write_line(&conn, &err);
            }
        }
        for id in batcher.quarantined.drain(..) {
            if let Some(conn) = conns.remove(&id) {
                let mut err = Json::obj();
                err.set(
                    "error",
                    Json::Str("quarantined: internal fault".into()),
                );
                err.set("id", Json::Num(id as f64));
                write_line(&conn, &err);
            }
        }
        if idle {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            if drain_started.is_some() && batcher.idle() {
                // drained dry: answer any straggler lines, publish the
                // tail time, and let `Server::drain` join us
                for inbound in
                    std::mem::take(&mut *queue.lock().unwrap())
                {
                    if let Inbound::Request { req, conn } = inbound {
                        let mut err = Json::obj();
                        err.set("error", Json::Str("draining".into()));
                        err.set("id", Json::Num(req.id as f64));
                        write_line(&conn, &err);
                    }
                }
                let ms = (t0.elapsed().as_secs_f64()
                    - drain_started.unwrap_or(now))
                    * 1e3;
                batcher
                    .engine()
                    .metrics()
                    .set(Gauge::DrainDurationMs, ms.max(0.0) as u64);
                stop.store(true, Ordering::SeqCst);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Minimal HTTP responder for Prometheus scrapes: every request gets
/// the full text exposition regardless of path, then the connection
/// closes. No HTTP library is vendored; scrapers only need 200 + body.
fn metrics_loop(
    listener: TcpListener,
    registry: Arc<OnceLock<Arc<MetricsRegistry>>>,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut stream, _)) => {
                stream
                    .set_read_timeout(Some(
                        std::time::Duration::from_millis(200),
                    ))
                    .ok();
                // drain the request head up to the blank line; the
                // verb and path don't change the answer
                if let Ok(peer) = stream.try_clone() {
                    let mut reader = BufReader::new(peer);
                    let mut line = String::new();
                    while let Ok(n) = reader.read_line(&mut line) {
                        if n == 0 || line.trim().is_empty() {
                            break;
                        }
                        line.clear();
                    }
                }
                let body = match registry.get() {
                    Some(r) => r.snapshot().to_prometheus(),
                    None => "# engine still starting\n".to_string(),
                };
                let _ = write!(
                    stream,
                    "HTTP/1.1 200 OK\r\n\
                     Content-Type: text/plain; version=0.0.4\r\n\
                     Content-Length: {}\r\n\
                     Connection: close\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = stream.flush();
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

fn write_line(conn: &Arc<Mutex<TcpStream>>, j: &Json) {
    if let Ok(mut s) = conn.lock() {
        let _ = writeln!(s, "{j}");
        let _ = s.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::AttentionBackend;
    use crate::model::ModelConfig;
    use std::io::{BufRead, BufReader, Write};

    fn test_config() -> ServerConfig {
        ServerConfig {
            engine: EngineConfig {
                model: ModelConfig::test_tiny(),
                backend: AttentionBackend::Lookat { m: 4, k: 64 },
                value_backend:
                    crate::coordinator::engine::ValueBackend::Fp32,
                seed: 2,
                cache_blocks: 64,
                calib_tokens: 64,
                decode_threads: 2,
                prefill_chunk: 16,
                pipeline: true,
                prefix_cache: false,
                policy: crate::coordinator::CompressionPolicy::Uniform,
                faults: Default::default(),
            },
            batcher: BatcherConfig {
                max_batch: 2,
                max_queue: 16,
                policy: crate::coordinator::SchedulerPolicy::Preempt,
                ..BatcherConfig::default()
            },
            max_prompt_tokens: 48,
            addr: "127.0.0.1:0".into(),
            metrics_addr: None,
            trace_out: None,
        }
    }

    fn test_server() -> Server {
        Server::start(test_config()).expect("server start")
    }

    fn roundtrip(addr: std::net::SocketAddr, line: &str) -> Json {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{line}").unwrap();
        s.flush().unwrap();
        let mut reader = BufReader::new(s);
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    }

    #[test]
    fn serves_a_request_over_tcp() {
        let server = test_server();
        let resp = roundtrip(
            server.local_addr,
            r#"{"prompt": "hello over the wire", "max_new_tokens": 3}"#,
        );
        assert!(resp.get("error").is_none(), "{resp}");
        assert_eq!(resp.get("tokens").unwrap().as_usize(), Some(3));
        assert!(resp.get("ttft_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert!(resp.get("text").is_some());
        server.shutdown();
    }

    #[test]
    fn malformed_request_gets_error() {
        let server = test_server();
        let resp = roundtrip(server.local_addr, "{not json");
        assert!(resp.get("error").is_some());
        let resp2 = roundtrip(server.local_addr, r#"{"nope": 1}"#);
        assert!(resp2
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("prompt"));
        server.shutdown();
    }

    #[test]
    fn rejected_request_gets_error_with_id_on_own_connection() {
        // 2 blocks = 64 tokens of cache: a clamped-48-token prompt
        // asking for 256 generated tokens can never fit and is
        // rejected inside `admit` — the client must still get an
        // {"error", "id"} line on its own connection instead of
        // hanging, while a small concurrent request is served
        let server = Server::start(ServerConfig {
            engine: EngineConfig {
                model: ModelConfig::test_tiny(),
                backend: AttentionBackend::Lookat { m: 4, k: 64 },
                value_backend:
                    crate::coordinator::engine::ValueBackend::Fp32,
                seed: 2,
                cache_blocks: 2,
                calib_tokens: 64,
                decode_threads: 2,
                prefill_chunk: 16,
                pipeline: true,
                prefix_cache: false,
                policy: crate::coordinator::CompressionPolicy::Uniform,
                faults: Default::default(),
            },
            batcher: BatcherConfig {
                max_batch: 2,
                max_queue: 16,
                policy: crate::coordinator::SchedulerPolicy::Preempt,
                ..BatcherConfig::default()
            },
            max_prompt_tokens: 48,
            addr: "127.0.0.1:0".into(),
            metrics_addr: None,
            trace_out: None,
        })
        .expect("server start");
        let addr = server.local_addr;
        let huge = std::thread::spawn(move || {
            roundtrip(
                addr,
                &format!(
                    r#"{{"prompt": "{}", "max_new_tokens": 256}}"#,
                    "x".repeat(200)
                ),
            )
        });
        let ok = roundtrip(addr, r#"{"prompt": "hi", "max_new_tokens": 2}"#);
        assert!(ok.get("error").is_none(), "{ok}");
        assert_eq!(ok.get("tokens").unwrap().as_usize(), Some(2));
        let rej = huge.join().unwrap();
        assert!(rej.get("error").is_some(), "{rej}");
        assert!(rej.get("id").is_some(),
                "rejection must carry the request id: {rej}");
        server.shutdown();
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let server = test_server();
        let addr = server.local_addr;
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    roundtrip(
                        addr,
                        &format!(
                            r#"{{"prompt": "client {i} text", "max_new_tokens": 2}}"#
                        ),
                    )
                })
            })
            .collect();
        let mut ids = Vec::new();
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.get("error").is_none(), "{resp}");
            ids.push(resp.get("id").unwrap().as_usize().unwrap());
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 4, "each client got a distinct request id");
        server.shutdown();
    }

    #[test]
    fn stats_verb_reports_live_metrics() {
        let server = test_server();
        let resp = roundtrip(
            server.local_addr,
            r#"{"prompt": "warm the counters", "max_new_tokens": 3}"#,
        );
        assert!(resp.get("error").is_none(), "{resp}");
        let stats = roundtrip(server.local_addr, r#"{"cmd": "stats"}"#);
        let counters = stats.get("counters").expect("counters block");
        assert!(
            counters
                .get("requests_completed")
                .and_then(Json::as_f64)
                .unwrap()
                >= 1.0,
            "{stats}"
        );
        assert!(
            counters.get("decode_tokens").and_then(Json::as_f64).unwrap()
                >= 3.0
        );
        assert!(
            counters.get("scan_bytes").and_then(Json::as_f64).unwrap()
                > 0.0
        );
        let gauges = stats.get("gauges").expect("gauges block");
        assert!(gauges.get("blocks_total").is_some());
        assert!(gauges.get("scratch_leases").is_some());
        let hists = stats.get("histograms").expect("histograms block");
        let ttft = hists.get("ttft_s").expect("ttft_s histogram");
        assert!(
            ttft.get("count").and_then(Json::as_f64).unwrap() >= 1.0
        );
        assert!(ttft.get("p50").is_some());
        assert!(stats.get("uptime_s").is_some());

        let bogus = roundtrip(server.local_addr, r#"{"cmd": "bogus"}"#);
        assert!(bogus
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("unknown cmd"));
        server.shutdown();
    }

    #[test]
    fn prometheus_endpoint_serves_text_exposition() {
        let mut cfg = test_config();
        cfg.metrics_addr = Some("127.0.0.1:0".into());
        let server = Server::start(cfg).expect("server start");
        let maddr = server.metrics_addr.expect("metrics sidecar bound");
        let resp = roundtrip(
            server.local_addr,
            r#"{"prompt": "scrape me", "max_new_tokens": 2}"#,
        );
        assert!(resp.get("error").is_none(), "{resp}");
        let mut s = TcpStream::connect(maddr).unwrap();
        write!(s, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        s.flush().unwrap();
        let mut body = String::new();
        use std::io::Read;
        s.read_to_string(&mut body).unwrap();
        assert!(body.starts_with("HTTP/1.1 200 OK"), "{body}");
        assert!(
            body.contains("lookat_requests_completed"),
            "missing counter in exposition:\n{body}"
        );
        assert!(
            body.contains("lookat_ttft_s_bucket"),
            "missing histogram buckets in exposition:\n{body}"
        );
        server.shutdown();
    }

    #[test]
    fn trace_dump_verb_and_shutdown_write_chrome_json() {
        let path = std::env::temp_dir().join(format!(
            "lookat_trace_test_{}.json",
            std::process::id()
        ));
        let mut cfg = test_config();
        cfg.trace_out = Some(path.to_string_lossy().into_owned());
        let server = Server::start(cfg).expect("server start");
        let resp = roundtrip(
            server.local_addr,
            r#"{"prompt": "leave a trace", "max_new_tokens": 3}"#,
        );
        assert!(resp.get("error").is_none(), "{resp}");
        let dump =
            roundtrip(server.local_addr, r#"{"cmd": "trace-dump"}"#);
        let text = dump
            .get("trace")
            .and_then(Json::as_str)
            .expect("trace payload")
            .to_string();
        let events = Json::parse(&text).expect("valid chrome json");
        let names: Vec<String> = events
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|e| {
                e.get("name").and_then(Json::as_str).map(String::from)
            })
            .collect();
        for expected in ["queued", "admitted", "finish", "decode_tick"] {
            assert!(
                names.iter().any(|n| n == expected),
                "trace missing {expected}: {names:?}"
            );
        }
        server.shutdown();
        let on_disk = std::fs::read_to_string(&path)
            .expect("trace file written on shutdown");
        assert!(Json::parse(&on_disk)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .any(|e| {
                e.get("name").and_then(Json::as_str) == Some("finish")
            }));
        let _ = std::fs::remove_file(&path);

        // tracing disabled: the verb answers with an error, not a hang
        let server2 = test_server();
        let dump2 =
            roundtrip(server2.local_addr, r#"{"cmd": "trace-dump"}"#);
        assert!(dump2.get("error").is_some(), "{dump2}");
        server2.shutdown();
    }

    /// test_config with a tick fault plan (slowing or breaking ticks).
    fn faulty_config(spec: &str) -> ServerConfig {
        let mut cfg = test_config();
        cfg.batcher.faults =
            crate::util::fault::FaultPlan::parse(spec).unwrap();
        cfg
    }

    #[test]
    fn drain_verb_finishes_inflight_and_refuses_new_requests() {
        // every tick sleeps 5ms, so the 256-token request stays in
        // flight long enough to drain around it deterministically
        let server = Server::start(faulty_config("tick_delay:5ms"))
            .expect("server start");
        let addr = server.local_addr;
        let inflight = std::thread::spawn(move || {
            roundtrip(
                addr,
                r#"{"prompt": "long running", "max_new_tokens": 256}"#,
            )
        });
        // wait until the request is admitted before draining
        loop {
            let stats = roundtrip(addr, r#"{"cmd": "stats"}"#);
            let active = stats
                .get("gauges")
                .and_then(|g| g.get("active_seqs"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0);
            if active >= 1.0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let ack = roundtrip(addr, r#"{"cmd": "drain"}"#);
        assert_eq!(ack.get("ok").and_then(Json::as_str),
                   Some("draining"), "{ack}");
        // a post-drain request is refused immediately, not queued
        let refused = roundtrip(
            addr,
            r#"{"prompt": "too late", "max_new_tokens": 2}"#,
        );
        assert_eq!(refused.get("error").and_then(Json::as_str),
                   Some("draining"), "{refused}");
        // the in-flight request still completes in full
        let done = inflight.join().unwrap();
        assert!(done.get("error").is_none(), "{done}");
        assert_eq!(done.get("tokens").unwrap().as_usize(), Some(256));
        server.drain();
    }

    #[test]
    fn deadline_expired_request_answers_deadline_error() {
        // 5ms-per-tick server: 256 tokens need >1s, the 80ms deadline
        // expires mid-generation and must answer promptly
        let server = Server::start(faulty_config("tick_delay:5ms"))
            .expect("server start");
        let resp = roundtrip(
            server.local_addr,
            r#"{"prompt": "hurry", "max_new_tokens": 256,
                "timeout_ms": 80}"#,
        );
        assert_eq!(resp.get("error").and_then(Json::as_str),
                   Some("deadline"), "{resp}");
        assert!(resp.get("id").is_some());
        // the server keeps serving deadline-free requests afterwards
        let ok = roundtrip(
            server.local_addr,
            r#"{"prompt": "no rush", "max_new_tokens": 2}"#,
        );
        assert!(ok.get("error").is_none(), "{ok}");
        let stats = roundtrip(server.local_addr, r#"{"cmd": "stats"}"#);
        let expired = stats
            .get("counters")
            .and_then(|c| c.get("deadline_expired"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!(expired >= 1.0, "{stats}");
        server.shutdown();
    }

    #[test]
    fn tick_panic_is_quarantined_and_server_survives() {
        // third tick panics by plan; the victim gets a structured
        // error and the server stays up for the next client
        let server = Server::start(faulty_config("tick:panic@3"))
            .expect("server start");
        let resp = roundtrip(
            server.local_addr,
            r#"{"prompt": "doomed", "max_new_tokens": 8}"#,
        );
        assert_eq!(resp.get("error").and_then(Json::as_str),
                   Some("quarantined: internal fault"), "{resp}");
        assert!(resp.get("id").is_some());
        let ok = roundtrip(
            server.local_addr,
            r#"{"prompt": "survivor", "max_new_tokens": 3}"#,
        );
        assert!(ok.get("error").is_none(), "{ok}");
        assert_eq!(ok.get("tokens").unwrap().as_usize(), Some(3));
        let stats = roundtrip(server.local_addr, r#"{"cmd": "stats"}"#);
        let counters = stats.get("counters").unwrap();
        assert!(
            counters
                .get("panics_quarantined")
                .and_then(Json::as_f64)
                .unwrap()
                >= 1.0,
            "{stats}"
        );
        assert!(
            counters
                .get("faults_injected")
                .and_then(Json::as_f64)
                .unwrap()
                >= 1.0,
            "{stats}"
        );
        server.shutdown();
    }

    #[test]
    fn slow_reader_does_not_block_other_clients() {
        let server = test_server();
        // client A sends a request and never reads its reply
        let mut slow = TcpStream::connect(server.local_addr).unwrap();
        writeln!(slow, r#"{{"prompt": "ignored reply", "max_new_tokens": 2}}"#)
            .unwrap();
        slow.flush().unwrap();
        // client B must still be served promptly
        let ok = roundtrip(
            server.local_addr,
            r#"{"prompt": "responsive", "max_new_tokens": 2}"#,
        );
        assert!(ok.get("error").is_none(), "{ok}");
        drop(slow);
        server.shutdown();
    }
}
