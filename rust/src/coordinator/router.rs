//! The router: trace-driven serving loop + aggregate reporting.
//!
//! Drives a [`Batcher`] against a request trace with real wall-clock
//! pacing of engine work and trace-time arrival gating: a request only
//! becomes visible once the serving clock passes its arrival offset.

use std::sync::Arc;

use super::batcher::{Batcher, BatcherConfig};
use super::engine::{Engine, EngineConfig};
use super::policy::HeadPolicy;
use super::request::{CompletedRequest, Request};
use crate::model::ByteTokenizer;
use crate::telemetry::{Ctr, Hist, HistogramSnapshot, TraceRing};
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::threadpool::scratch;
use crate::util::timing::PhaseTimes;
use crate::workload::RequestSpec;

/// Router construction parameters.
#[derive(Clone, Debug, Default)]
pub struct RouterConfig {
    pub engine: EngineConfig,
    pub batcher: BatcherConfig,
    /// clamp prompts to this many tokens (keeps within artifact L)
    pub max_prompt_tokens: usize,
}

/// Serving-run report: the numbers `examples/serve.rs` and the
/// serving_throughput bench print.
#[derive(Clone, Debug)]
pub struct ServingReport {
    pub backend: String,
    /// ADC scan path the runtime ISA detection picked for this run
    /// ("avx2" or "scalar"; `LOOKAT_SIMD=scalar` pins the latter) —
    /// recorded separately from `backend` so baseline series keyed on
    /// the label stay stable across machines
    pub scan_path: String,
    /// active compression policy label ([`super::CompressionPolicy::name`])
    pub policy: String,
    /// bits/token the policy spent across every PQ (layer, head, side)
    pub policy_bits_per_token: usize,
    /// resolved per-(layer, head) policy: subspace counts and the
    /// build-time Spearman-ρ fidelity estimate — the ablation harness's
    /// per-head rho column
    pub head_policies: Vec<HeadPolicy>,
    /// tokens the L2-norm pruning policy dropped over the engine's
    /// lifetime (0 unless `--policy prune-<frac>`)
    pub pruned_tokens: u64,
    pub completed: Vec<CompletedRequest>,
    pub rejected: usize,
    /// requests that blew their deadline (queued or mid-generation)
    pub expired: usize,
    /// sequences torn down after a tick panic
    pub quarantined: usize,
    /// faults the injection plan fired during the run (0 with no plan)
    pub faults_injected: u64,
    /// swap-slab / prefix-block checksum verifications that failed
    /// (each one fell back to re-prefill)
    pub checksum_failures: u64,
    pub wall_s: f64,
    pub decode_tokens: usize,
    pub prefill_tokens: usize,
    /// sequences evicted under block pressure (preemptive policy only)
    pub preemptions: usize,
    /// preemptions that spilled to the swap tier instead of freeing
    pub swap_outs: usize,
    /// re-admissions restored from the swap tier without re-prefill
    pub swap_ins: usize,
    /// admissions that attached shared prefix-cache blocks
    pub prefix_hits: usize,
    /// peak extra holders on shared blocks — physical blocks saved by
    /// prefix sharing at the busiest instant of the run
    pub shared_blocks_peak: usize,
    pub key_cache_peak_bytes: usize,
    pub value_cache_peak_bytes: usize,
    /// per-phase time breakdown of the run (`lut_build`, `scan`,
    /// `value_decode`, `qkv`, `mlp`); phase sums count every worker
    /// thread and overlapped pipeline stage, so they may exceed
    /// `wall_s`
    pub phases: PhaseTimes,
    /// latency distributions drained from the telemetry registry for
    /// this run: time-to-first-token, inter-token gap, end-to-end, and
    /// per-tick engine latency
    pub ttft_hist: HistogramSnapshot,
    pub itl_hist: HistogramSnapshot,
    pub e2e_hist: HistogramSnapshot,
    pub tick_hist: HistogramSnapshot,
    /// scratch-arena activity over the run (leases/fresh/zeroed are
    /// deltas against the run start; process-wide pool)
    pub scratch_leases: usize,
    /// arena leases that touched the allocator — the PR 5 invariant
    /// says this stays ~0 once decode reaches steady state
    pub scratch_fresh: usize,
    pub scratch_zeroed: usize,
    /// arena retention high-water mark in bytes (absolute, not a delta)
    pub scratch_peak_bytes: usize,
}

impl ServingReport {
    pub fn throughput_tok_s(&self) -> f64 {
        self.decode_tokens as f64 / self.wall_s.max(1e-9)
    }

    pub fn requests_per_s(&self) -> f64 {
        self.completed.len() as f64 / self.wall_s.max(1e-9)
    }

    pub fn ttft_summary(&self) -> Option<Summary> {
        Summary::of(
            &self.completed.iter().map(|c| c.ttft()).collect::<Vec<_>>())
    }

    pub fn e2e_summary(&self) -> Option<Summary> {
        Summary::of(
            &self.completed.iter().map(|c| c.e2e()).collect::<Vec<_>>())
    }

    /// Smallest per-(layer, head) rho estimate in the resolved policy
    /// (1.0 when no head carries a PQ codec).
    pub fn min_rho(&self) -> f64 {
        self.head_policies
            .iter()
            .map(|h| h.rho)
            .fold(1.0f64, f64::min)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("backend", Json::Str(self.backend.clone()));
        o.set("scan_path", Json::Str(self.scan_path.clone()));
        o.set("policy", Json::Str(self.policy.clone()));
        o.set(
            "policy_bits_per_token",
            Json::Num(self.policy_bits_per_token as f64),
        );
        o.set("policy_min_rho", Json::Num(self.min_rho()));
        o.set("pruned_tokens", Json::Num(self.pruned_tokens as f64));
        o.set(
            "head_policies",
            Json::Arr(
                self.head_policies
                    .iter()
                    .map(|h| {
                        Json::from_pairs(vec![
                            ("layer", Json::Num(h.layer as f64)),
                            ("head", Json::Num(h.head as f64)),
                            ("key_m", Json::Num(h.key_m as f64)),
                            ("value_m", Json::Num(h.value_m as f64)),
                            ("rho", Json::Num(h.rho)),
                        ])
                    })
                    .collect(),
            ),
        );
        o.set("completed", Json::Num(self.completed.len() as f64));
        o.set("rejected", Json::Num(self.rejected as f64));
        o.set("expired", Json::Num(self.expired as f64));
        o.set("quarantined", Json::Num(self.quarantined as f64));
        o.set(
            "faults_injected",
            Json::Num(self.faults_injected as f64),
        );
        o.set(
            "checksum_failures",
            Json::Num(self.checksum_failures as f64),
        );
        o.set("wall_s", Json::Num(self.wall_s));
        o.set("decode_tokens", Json::Num(self.decode_tokens as f64));
        o.set("throughput_tok_s", Json::Num(self.throughput_tok_s()));
        o.set("preemptions", Json::Num(self.preemptions as f64));
        o.set("swap_outs", Json::Num(self.swap_outs as f64));
        o.set("swap_ins", Json::Num(self.swap_ins as f64));
        o.set("prefix_hits", Json::Num(self.prefix_hits as f64));
        o.set(
            "shared_blocks_peak",
            Json::Num(self.shared_blocks_peak as f64),
        );
        if let Some(t) = self.ttft_summary() {
            o.set("ttft_p50_s", Json::Num(t.p50));
            o.set("ttft_p99_s", Json::Num(t.p99));
        }
        if let Some(t) = self.e2e_summary() {
            o.set("e2e_p50_s", Json::Num(t.p50));
            o.set("e2e_p99_s", Json::Num(t.p99));
        }
        // histogram-backed latency keys (telemetry registry); omitted
        // when the run recorded nothing, so empty runs don't emit zeros
        let hist_keys: [(&str, &HistogramSnapshot, f64); 5] = [
            ("ttft_p90_s", &self.ttft_hist, 0.90),
            ("itl_p50_s", &self.itl_hist, 0.50),
            ("itl_p99_s", &self.itl_hist, 0.99),
            ("tick_p50_s", &self.tick_hist, 0.50),
            ("tick_p99_s", &self.tick_hist, 0.99),
        ];
        for (key, hist, q) in hist_keys {
            if let Some(v) = hist.percentile(q) {
                o.set(key, Json::Num(v));
            }
        }
        o.set("scratch_leases", Json::Num(self.scratch_leases as f64));
        o.set("scratch_fresh", Json::Num(self.scratch_fresh as f64));
        o.set("scratch_zeroed", Json::Num(self.scratch_zeroed as f64));
        o.set(
            "scratch_peak_bytes",
            Json::Num(self.scratch_peak_bytes as f64),
        );
        o.set(
            "key_cache_peak_bytes",
            Json::Num(self.key_cache_peak_bytes as f64),
        );
        o.set(
            "value_cache_peak_bytes",
            Json::Num(self.value_cache_peak_bytes as f64),
        );
        o.set("phases", self.phases.to_json());
        o
    }

    /// Human-readable serving summary. Latency columns render `n/a`
    /// when the run completed nothing, rather than a misleading 0.0ms.
    pub fn pretty(&self) -> String {
        let fmt_ms = |v: Option<f64>| match v {
            Some(s) => format!("{:>7.1}ms", s * 1e3),
            None => format!("{:>9}", "n/a"),
        };
        let ttft = self.ttft_summary();
        let e2e = self.e2e_summary();
        format!(
            "backend={:<14} scan={:<6} policy={:<12} completed={:<4} \
             rejected={:<3} preempt={:<3} \
             swap={}/{} prefix_hits={:<3} pruned={:<5} wall={:>7.2}s \
             decode_tok/s={:>8.1} ttft_p50={} \
             e2e_p50={} key_cache_peak={:>8} B \
             value_cache_peak={:>8} B",
            self.backend,
            self.scan_path,
            self.policy,
            self.completed.len(),
            self.rejected,
            self.preemptions,
            self.swap_outs,
            self.swap_ins,
            self.prefix_hits,
            self.pruned_tokens,
            self.wall_s,
            self.throughput_tok_s(),
            fmt_ms(ttft.as_ref().map(|t| t.p50)),
            fmt_ms(e2e.as_ref().map(|t| t.p50)),
            self.key_cache_peak_bytes,
            self.value_cache_peak_bytes,
        )
    }
}

/// The serving front door.
pub struct Router {
    batcher: Batcher,
    cfg: RouterConfig,
}

impl Router {
    pub fn build(cfg: RouterConfig) -> anyhow::Result<Router> {
        let engine = Engine::build(&cfg.engine)?;
        Ok(Router {
            batcher: Batcher::new(engine, cfg.batcher.clone()),
            cfg,
        })
    }

    /// Change the decode batch width between runs. The serving bench
    /// sweeps batch sizes over one engine so codebook training and
    /// weight init stay out of the comparison.
    pub fn set_max_batch(&mut self, max_batch: usize) {
        self.batcher.cfg.max_batch = max_batch;
    }

    /// Attach a per-request trace ring; events from every subsequent
    /// run land in it (`TraceRing::dump_chrome_json` renders them).
    pub fn set_tracer(&mut self, tracer: Arc<TraceRing>) {
        self.batcher.set_tracer(tracer);
    }

    /// Tokenize a workload trace into requests.
    pub fn tokenize_trace(&self, trace: &[RequestSpec]) -> Vec<Request> {
        let tok = ByteTokenizer::new();
        let max_len = if self.cfg.max_prompt_tokens == 0 {
            usize::MAX
        } else {
            self.cfg.max_prompt_tokens
        };
        trace
            .iter()
            .map(|spec| Request {
                id: spec.id,
                prompt: tok.encode_clamped(&spec.prompt, max_len),
                max_new_tokens: spec.gen_tokens,
                arrival_s: spec.arrival_s,
                timeout_ms: None,
            })
            .collect()
    }

    /// Serve a full trace to completion. The serving clock is wall time;
    /// arrivals are gated on it (a trace arriving faster than the engine
    /// decodes builds real queueing delay, which the report captures).
    pub fn serve_trace(&mut self, requests: Vec<Request>)
        -> anyhow::Result<ServingReport>
    {
        let t0 = std::time::Instant::now();
        let mut pending: std::collections::VecDeque<Request> =
            requests.into_iter().collect();
        let prefill_tokens: usize =
            pending.iter().map(|r| r.prompt.len()).sum();
        let mut decode_tokens = 0usize;
        let mut peak_key_bytes = 0usize;
        let mut peak_value_bytes = 0usize;
        let mut shared_blocks_peak = 0usize;

        // fresh phase window for this run (a reused router must not
        // carry an earlier run's breakdown); same for the registry's
        // latency histograms and the scratch-arena baseline
        let _ = self.batcher.engine().take_phase_times();
        let metrics = self.batcher.engine().metrics();
        for h in [Hist::TtftS, Hist::ItlS, Hist::E2eS, Hist::TickS] {
            let _ = metrics.take_hist(h);
        }
        let scratch0 = scratch().arena_stats();
        let faults0 = metrics.counter(Ctr::FaultsInjected);
        let cksum0 = metrics.counter(Ctr::ChecksumFailures);

        // a fault-injected tick error (or a transient engine failure)
        // skips the tick and retries; only a persistent failure streak
        // aborts the run
        let mut consecutive_errs = 0usize;
        while !(pending.is_empty() && self.batcher.idle()) {
            let now = t0.elapsed().as_secs_f64();
            // deliver arrived requests
            while pending
                .front()
                .map(|r| r.arrival_s <= now)
                .unwrap_or(false)
            {
                let r = pending.pop_front().unwrap();
                self.batcher.submit(r);
            }
            self.batcher.admit(now);
            if self.batcher.active() > 0 {
                match self.batcher.step(t0.elapsed().as_secs_f64()) {
                    Ok(n) => {
                        consecutive_errs = 0;
                        decode_tokens += n;
                    }
                    Err(e) => {
                        consecutive_errs += 1;
                        anyhow::ensure!(
                            consecutive_errs < 100,
                            "batcher stuck after {consecutive_errs} \
                             consecutive tick failures: {e:#}"
                        );
                        crate::log_error!(
                            "tick failed (retrying): {e:#}"
                        );
                    }
                }
                let stats = self.batcher.engine().cache_stats();
                peak_key_bytes = peak_key_bytes.max(stats.key_bytes);
                peak_value_bytes = peak_value_bytes.max(stats.value_bytes);
                shared_blocks_peak =
                    shared_blocks_peak.max(stats.shared_blocks);
            } else if let Some(r) = pending.front() {
                // idle until the next arrival
                let wait = (r.arrival_s - now).max(0.0);
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    wait.min(0.01),
                ));
            }
        }

        let scratch1 = scratch().arena_stats();
        let policy_rec = self.batcher.engine().policy_record().clone();
        Ok(ServingReport {
            backend: self.batcher.engine().label(),
            scan_path: self.batcher.engine().scan_path().to_string(),
            policy: policy_rec.policy,
            policy_bits_per_token: policy_rec.total_bits_per_token,
            head_policies: policy_rec.heads,
            pruned_tokens: self.batcher.engine().pruned_tokens(),
            completed: std::mem::take(&mut self.batcher.completed),
            // drain, don't peek: a reused router (set_max_batch sweeps)
            // must not re-report earlier runs' rejections
            rejected: std::mem::take(&mut self.batcher.rejected).len(),
            expired: std::mem::take(&mut self.batcher.expired).len(),
            quarantined: std::mem::take(&mut self.batcher.quarantined)
                .len(),
            faults_injected: metrics
                .counter(Ctr::FaultsInjected)
                .saturating_sub(faults0),
            checksum_failures: metrics
                .counter(Ctr::ChecksumFailures)
                .saturating_sub(cksum0),
            wall_s: t0.elapsed().as_secs_f64(),
            decode_tokens,
            prefill_tokens,
            preemptions: std::mem::take(&mut self.batcher.preemptions),
            swap_outs: std::mem::take(&mut self.batcher.swap_outs),
            swap_ins: std::mem::take(&mut self.batcher.swap_ins),
            prefix_hits: std::mem::take(&mut self.batcher.prefix_hits),
            shared_blocks_peak,
            key_cache_peak_bytes: peak_key_bytes,
            value_cache_peak_bytes: peak_value_bytes,
            phases: self.batcher.engine().take_phase_times(),
            ttft_hist: metrics.take_hist(Hist::TtftS),
            itl_hist: metrics.take_hist(Hist::ItlS),
            e2e_hist: metrics.take_hist(Hist::E2eS),
            tick_hist: metrics.take_hist(Hist::TickS),
            scratch_leases: scratch1
                .leases
                .saturating_sub(scratch0.leases),
            scratch_fresh: scratch1.fresh.saturating_sub(scratch0.fresh),
            scratch_zeroed: scratch1
                .zeroed
                .saturating_sub(scratch0.zeroed),
            scratch_peak_bytes: scratch1.peak_bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{AttentionBackend, ValueBackend};
    use crate::coordinator::CompressionPolicy;
    use crate::model::ModelConfig;
    use crate::workload::{TraceConfig, TraceGenerator};

    fn router(backend: AttentionBackend) -> Router {
        Router::build(RouterConfig {
            engine: EngineConfig {
                model: ModelConfig::test_tiny(),
                backend,
                value_backend: ValueBackend::Fp32,
                seed: 5,
                cache_blocks: 128,
                calib_tokens: 64,
                decode_threads: 2,
                prefill_chunk: 0,
                pipeline: true,
                prefix_cache: false,
                policy: CompressionPolicy::Uniform,
                faults: Default::default(),
            },
            batcher: BatcherConfig {
                max_batch: 4,
                max_queue: 64,
                policy: crate::coordinator::SchedulerPolicy::Fcfs,
                ..BatcherConfig::default()
            },
            max_prompt_tokens: 48,
        })
        .unwrap()
    }

    fn small_trace(n: usize) -> Vec<crate::workload::RequestSpec> {
        TraceGenerator::new(TraceConfig {
            rate: 1000.0, // all arrive ~immediately
            num_requests: n,
            prompt_chars: (60, 120),
            gen_tokens: (2, 4),
            seed: 9,
        })
        .generate()
    }

    #[test]
    fn serves_trace_to_completion_fp16() {
        let mut r = router(AttentionBackend::Fp16Exact);
        let reqs = r.tokenize_trace(&small_trace(6));
        let report = r.serve_trace(reqs).unwrap();
        assert_eq!(report.completed.len(), 6);
        assert_eq!(report.rejected, 0);
        assert!(report.decode_tokens >= 12);
        assert!(report.throughput_tok_s() > 0.0);
        for c in &report.completed {
            assert!(c.ttft() >= 0.0);
            assert!(c.e2e() >= c.ttft());
        }
    }

    #[test]
    fn serves_trace_lookat_backend() {
        let mut r = router(AttentionBackend::Lookat { m: 4, k: 64 });
        let reqs = r.tokenize_trace(&small_trace(4));
        let report = r.serve_trace(reqs).unwrap();
        assert_eq!(report.completed.len(), 4);
        assert_eq!(report.backend, "lookat-4+k64");
        assert!(
            report.scan_path == "avx2" || report.scan_path == "scalar",
            "scan_path {}",
            report.scan_path
        );
        // compressed cache: peak key bytes far below the fp16 router's
        let mut rf = router(AttentionBackend::Fp16Exact);
        let reqs2 = rf.tokenize_trace(&small_trace(4));
        let report_fp = rf.serve_trace(reqs2).unwrap();
        assert!(
            report.key_cache_peak_bytes * 4
                < report_fp.key_cache_peak_bytes,
            "lookat {} vs fp16 {}",
            report.key_cache_peak_bytes,
            report_fp.key_cache_peak_bytes
        );
    }

    #[test]
    fn serves_trace_lookat_kv_backend() {
        // fully-compressed cache: both peak byte columns shrink
        let mut r = Router::build(RouterConfig {
            engine: EngineConfig {
                model: ModelConfig::test_tiny(),
                backend: AttentionBackend::Lookat { m: 4, k: 64 },
                value_backend: ValueBackend::Pq { m: 4, k: 64 },
                seed: 5,
                cache_blocks: 128,
                calib_tokens: 64,
                decode_threads: 2,
                prefill_chunk: 0,
                pipeline: true,
                prefix_cache: false,
                policy: CompressionPolicy::Uniform,
                faults: Default::default(),
            },
            batcher: BatcherConfig {
                max_batch: 4,
                max_queue: 64,
                policy: crate::coordinator::SchedulerPolicy::Fcfs,
                ..BatcherConfig::default()
            },
            max_prompt_tokens: 48,
        })
        .unwrap();
        let reqs = r.tokenize_trace(&small_trace(4));
        let report = r.serve_trace(reqs).unwrap();
        assert_eq!(report.completed.len(), 4);
        assert_eq!(report.backend, "lookat-4+k64+vpq-4+k64");
        let mut rf = router(AttentionBackend::Fp16Exact);
        let reqs_fp = rf.tokenize_trace(&small_trace(4));
        let report_fp = rf.serve_trace(reqs_fp).unwrap();
        assert!(
            report.value_cache_peak_bytes * 4
                < report_fp.value_cache_peak_bytes,
            "vpq {} vs fp32 {}",
            report.value_cache_peak_bytes,
            report_fp.value_cache_peak_bytes
        );
        assert!(report.to_json().get("value_cache_peak_bytes").is_some());
    }

    #[test]
    fn batch_width_does_not_change_tokens() {
        // the same trace served at batch 1 and batch 4 must emit
        // identical generations — batched decode is bit-exact
        let backend = AttentionBackend::Lookat { m: 4, k: 64 };
        let mut r1 = router(backend.clone());
        r1.set_max_batch(1);
        let reqs1 = r1.tokenize_trace(&small_trace(4));
        let rep1 = r1.serve_trace(reqs1).unwrap();

        let mut r4 = router(backend);
        let reqs4 = r4.tokenize_trace(&small_trace(4));
        let rep4 = r4.serve_trace(reqs4).unwrap();

        let by_id = |rep: &ServingReport| {
            let mut v: Vec<(u64, Vec<u32>)> = rep
                .completed
                .iter()
                .map(|c| (c.id, c.generated.clone()))
                .collect();
            v.sort();
            v
        };
        assert_eq!(by_id(&rep1), by_id(&rep4));
    }

    #[test]
    fn report_json_has_core_fields() {
        let mut r = router(AttentionBackend::Fp16Exact);
        let reqs = r.tokenize_trace(&small_trace(2));
        let report = r.serve_trace(reqs).unwrap();
        let j = report.to_json();
        for k in [
            "backend",
            "scan_path",
            "policy",
            "policy_bits_per_token",
            "policy_min_rho",
            "pruned_tokens",
            "head_policies",
            "completed",
            "expired",
            "quarantined",
            "faults_injected",
            "checksum_failures",
            "wall_s",
            "throughput_tok_s",
            "preemptions",
            "swap_outs",
            "swap_ins",
            "prefix_hits",
            "shared_blocks_peak",
            "phases",
        ] {
            assert!(j.get(k).is_some(), "missing {k}");
        }
        assert_eq!(
            j.get("policy").unwrap().as_str(),
            Some("uniform"),
            "default policy label"
        );
        let phases = j.get("phases").unwrap();
        for k in
            ["lut_build_s", "scan_s", "value_decode_s", "qkv_s", "mlp_s"]
        {
            assert!(phases.get(k).is_some(), "missing phase {k}");
        }
        // a served run booked real compute into the breakdown
        assert!(report.phases.qkv_s > 0.0, "qkv phase empty");
        assert!(report.phases.mlp_s > 0.0, "mlp phase empty");
        assert!(!report.pretty().is_empty());
    }

    #[test]
    fn preemptive_chunked_router_serves_oversubscribed_trace() {
        // tiny block budget + chunked prefill + preemption: the trace
        // still completes, nothing is rejected, and the report carries
        // the preemption count
        let mut r = Router::build(RouterConfig {
            engine: EngineConfig {
                model: ModelConfig::test_tiny(),
                backend: AttentionBackend::Lookat { m: 4, k: 64 },
                value_backend: ValueBackend::Fp32,
                seed: 5,
                cache_blocks: 4,
                calib_tokens: 64,
                decode_threads: 2,
                prefill_chunk: 8,
                pipeline: true,
                prefix_cache: false,
                policy: CompressionPolicy::Uniform,
                faults: Default::default(),
            },
            batcher: BatcherConfig {
                max_batch: 4,
                max_queue: 64,
                policy: crate::coordinator::SchedulerPolicy::Preempt,
                ..BatcherConfig::default()
            },
            max_prompt_tokens: 48,
        })
        .unwrap();
        let reqs = r.tokenize_trace(&small_trace(6));
        let report = r.serve_trace(reqs).unwrap();
        assert_eq!(report.completed.len(), 6);
        assert_eq!(report.rejected, 0);
        assert!(report.to_json().get("preemptions").is_some());
    }

    #[test]
    fn empty_run_report_omits_latency_keys_and_prints_na() {
        // a run that completes nothing must not fabricate latencies:
        // the JSON drops every percentile key and pretty() says n/a
        let mut r = router(AttentionBackend::Fp16Exact);
        let report = r.serve_trace(Vec::new()).unwrap();
        assert_eq!(report.completed.len(), 0);
        let j = report.to_json();
        for k in [
            "ttft_p50_s",
            "ttft_p90_s",
            "ttft_p99_s",
            "e2e_p50_s",
            "e2e_p99_s",
            "itl_p50_s",
            "itl_p99_s",
            "tick_p50_s",
            "tick_p99_s",
        ] {
            assert!(j.get(k).is_none(), "empty run leaked {k}");
        }
        let line = report.pretty();
        assert!(line.contains("n/a"), "pretty lacks n/a: {line}");
        assert!(
            !line.contains("0.0ms"),
            "pretty reports 0.0ms on an empty run: {line}"
        );
    }

    #[test]
    fn report_gains_histogram_backed_latency_fields() {
        let mut r = router(AttentionBackend::Lookat { m: 4, k: 64 });
        let reqs = r.tokenize_trace(&small_trace(4));
        let report = r.serve_trace(reqs).unwrap();
        assert_eq!(report.completed.len(), 4);
        // one TTFT observation per completed request, drained into the
        // report's histogram
        assert_eq!(report.ttft_hist.count as usize, 4);
        assert_eq!(report.e2e_hist.count as usize, 4);
        // every request generates >= 2 tokens, so inter-token gaps and
        // engine ticks both recorded
        assert!(report.itl_hist.count > 0);
        assert!(report.tick_hist.count > 0);
        assert!(report.scratch_leases > 0, "no scratch leases recorded");
        let j = report.to_json();
        for k in [
            "ttft_p50_s",
            "ttft_p90_s",
            "ttft_p99_s",
            "itl_p50_s",
            "itl_p99_s",
            "tick_p50_s",
            "tick_p99_s",
            "scratch_leases",
            "scratch_fresh",
            "scratch_zeroed",
            "scratch_peak_bytes",
        ] {
            assert!(j.get(k).is_some(), "missing {k}");
        }
        // the histogram p50 agrees with the exact per-request summary
        // to within one geometric bucket (ratio sqrt(2))
        let exact = report.ttft_summary().unwrap().p50;
        let hist = j.get("ttft_p50_s").unwrap().as_f64().unwrap();
        assert!(
            hist >= exact / 2.0 && hist <= exact * 2.0,
            "hist p50 {hist} vs exact {exact}"
        );
        // a second run on the same router starts from a clean registry
        let reqs2 = r.tokenize_trace(&small_trace(4));
        let report2 = r.serve_trace(reqs2).unwrap();
        assert_eq!(report2.ttft_hist.count as usize, 4);
    }

    #[test]
    fn deadline_expiry_reaches_the_report() {
        let mut r = router(AttentionBackend::Fp16Exact);
        // a zero-ms default SLO expires every request at its first
        // admission sweep, deterministically
        r.batcher.cfg.deadline_ms = Some(0);
        let reqs = r.tokenize_trace(&small_trace(3));
        let report = r.serve_trace(reqs).unwrap();
        assert_eq!(report.expired, 3);
        assert!(report.completed.is_empty());
        assert_eq!(
            report.to_json().get("expired").and_then(Json::as_f64),
            Some(report.expired as f64)
        );
        // all cache reclaimed despite the mid-flight teardowns
        assert_eq!(r.batcher.engine().cache_stats().tokens, 0);
        assert_eq!(r.batcher.engine().cache_stats().blocks_allocated, 0);
    }

    #[test]
    fn injected_tick_errors_are_retried_and_counted() {
        let mut r = router(AttentionBackend::Lookat { m: 4, k: 64 });
        r.batcher.cfg.faults =
            crate::util::fault::FaultPlan::parse("tick:err@2").unwrap();
        let reqs = r.tokenize_trace(&small_trace(3));
        let report = r.serve_trace(reqs).unwrap();
        assert_eq!(report.completed.len(), 3, "run survives the fault");
        assert_eq!(report.faults_injected, 1);
        assert!(report.to_json().get("faults_injected").is_some());
    }

    #[test]
    fn report_carries_per_head_policy_detail() {
        // calibrated run: the report must expose each (layer, head)'s
        // resolved m and rho — the ablation harness reads these
        let mut r = Router::build(RouterConfig {
            engine: EngineConfig {
                model: ModelConfig::test_tiny(),
                backend: AttentionBackend::Lookat { m: 4, k: 64 },
                value_backend: ValueBackend::Fp32,
                seed: 5,
                cache_blocks: 128,
                calib_tokens: 64,
                decode_threads: 2,
                prefill_chunk: 0,
                pipeline: true,
                prefix_cache: false,
                policy: CompressionPolicy::Calibrated { bits: 150 },
                faults: Default::default(),
            },
            batcher: BatcherConfig::default(),
            max_prompt_tokens: 48,
        })
        .unwrap();
        let reqs = r.tokenize_trace(&small_trace(3));
        let report = r.serve_trace(reqs).unwrap();
        assert_eq!(report.completed.len(), 3);
        assert_eq!(report.policy, "calibrated-150");
        assert!(report.policy_bits_per_token <= 150);
        assert_eq!(report.head_policies.len(), 8); // 2 layers × 4 heads
        assert!(report.min_rho().is_finite());
        let j = report.to_json();
        let heads = j.get("head_policies").unwrap().as_arr().unwrap();
        assert_eq!(heads.len(), 8);
        for h in heads {
            let m =
                h.get("key_m").and_then(Json::as_f64).unwrap() as usize;
            assert!([2, 4, 8].contains(&m), "key_m {m}");
            assert!(h.get("rho").and_then(Json::as_f64).is_some());
        }

        // prune run: the dropped-token counter reaches the report
        let mut rp = Router::build(RouterConfig {
            engine: EngineConfig {
                model: ModelConfig::test_tiny(),
                backend: AttentionBackend::Fp16Exact,
                value_backend: ValueBackend::Fp32,
                seed: 5,
                cache_blocks: 128,
                calib_tokens: 64,
                decode_threads: 2,
                prefill_chunk: 0,
                pipeline: true,
                prefix_cache: false,
                policy: CompressionPolicy::Prune { frac: 0.5 },
                faults: Default::default(),
            },
            batcher: BatcherConfig::default(),
            max_prompt_tokens: 48,
        })
        .unwrap();
        let reqs = rp.tokenize_trace(&small_trace(3));
        let report = rp.serve_trace(reqs).unwrap();
        assert_eq!(report.completed.len(), 3);
        assert_eq!(report.policy, "prune-0.5");
        assert!(report.pruned_tokens > 0, "no tokens pruned");
        assert!(
            report
                .to_json()
                .get("pruned_tokens")
                .and_then(Json::as_f64)
                .unwrap()
                > 0.0
        );
    }
}
