//! Adaptive compression policies: how the engine distributes its
//! compression budget across (layer, head, side) at build time.
//!
//! The paper's O(d_k/mK) fidelity bound says error tracks the
//! per-subspace dimensionality — but sensitivity is not uniform across
//! layers and heads. [`CompressionPolicy::Calibrated`] measures
//! per-(layer, head) reconstruction error on the calibration corpus
//! (the same prefill that trains the codebooks) and assigns each slot
//! its own subspace count `m` inside a total bits/token budget, via
//! the deterministic greedy allocator in [`allocate_budget`].
//! [`CompressionPolicy::Prune`] drops low-L2-norm keys entirely
//! ("A Simple and Effective L2 Norm-Based Strategy", PAPERS.md): the
//! threshold is the `frac`-quantile of the calibration tokens' norms
//! ([`prune_threshold`]) and tokens below it are never appended to the
//! cache — attention runs over the surviving set.
//!
//! Everything here is pure (no engine, no I/O): resolution takes error
//! tables in and returns per-slot subspace counts, so the budget
//! invariants are unit- and property-testable in isolation. The engine
//! wires the result into per-head codec sets
//! ([`crate::kvcache::KeyStorage::pq`] accepts heterogeneous m) and
//! records the outcome as a [`PolicySummary`] for reports.

/// The policy axis of [`crate::coordinator::EngineConfig`]: resolved
/// once at engine build, immutable afterwards.
#[derive(Clone, Debug, PartialEq)]
pub enum CompressionPolicy {
    /// One global (m, K) per cache side — the pre-policy engine,
    /// bit-identical to it by construction (same codec training calls
    /// in the same order).
    Uniform,
    /// Per-(layer, head, side) subspace counts chosen from calibration
    /// error under a total budget of `bits` bits/token summed over
    /// every PQ-coded (layer, head, side) slot.
    Calibrated { bits: usize },
    /// L2-norm token pruning: drop the lowest-norm `frac` fraction of
    /// tokens (threshold calibrated per layer); codec geometry stays
    /// uniform.
    Prune { frac: f64 },
}

impl Default for CompressionPolicy {
    fn default() -> Self {
        CompressionPolicy::Uniform
    }
}

impl CompressionPolicy {
    /// Stable label for reports and bench scenario keys.
    pub fn name(&self) -> String {
        match self {
            CompressionPolicy::Uniform => "uniform".into(),
            CompressionPolicy::Calibrated { bits } => {
                format!("calibrated-{bits}")
            }
            CompressionPolicy::Prune { frac } => format!("prune-{frac}"),
        }
    }

    /// Parse the CLI spelling: `uniform`, `calibrated-<bits>` or
    /// `prune-<frac>` (frac strictly inside (0, 1)).
    pub fn parse(s: &str) -> Result<CompressionPolicy, String> {
        let usage = format!(
            "unknown --policy '{s}' (uniform, calibrated-<bits>, \
             prune-<frac> with 0 < frac < 1)"
        );
        if s == "uniform" {
            return Ok(CompressionPolicy::Uniform);
        }
        if let Some(b) = s.strip_prefix("calibrated-") {
            let bits: usize = b.parse().map_err(|_| usage.clone())?;
            if bits == 0 {
                return Err(usage);
            }
            return Ok(CompressionPolicy::Calibrated { bits });
        }
        if let Some(fr) = s.strip_prefix("prune-") {
            let frac: f64 = fr.parse().map_err(|_| usage.clone())?;
            if !(frac > 0.0 && frac < 1.0) {
                return Err(usage);
            }
            return Ok(CompressionPolicy::Prune { frac });
        }
        Err(usage)
    }
}

/// Which cache side a budget item belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    Key,
    Value,
}

/// One (layer, head, side) slot competing for the bits/token budget.
#[derive(Clone, Debug)]
pub struct BudgetItem {
    pub layer: usize,
    pub head: usize,
    pub side: Side,
    /// bits per stored code on this side (⌈log2 K⌉)
    pub code_bits: usize,
    /// candidate subspace counts, strictly ascending in `m`, each with
    /// its calibration error proxy (summed per-subspace k-means MSE)
    pub candidates: Vec<(usize, f64)>,
}

impl BudgetItem {
    fn bits_at(&self, choice: usize) -> usize {
        self.candidates[choice].0 * self.code_bits
    }
}

/// Deterministic budget allocation: pick one candidate `m` per item so
/// that Σ m·code_bits ≤ `budget_bits`, greedily spending bits where
/// they buy the most error reduction.
///
/// Every item starts at its cheapest candidate; each round upgrades
/// the single (item, candidate) step with the best positive error
/// reduction per extra bit that still fits the budget (first item wins
/// ties, so the result is a pure function of the inputs). As a safety
/// net the best *uniform* assignment that fits the budget is computed
/// too, and wins if its total error is strictly lower — so a
/// calibrated allocation never does worse than the uniform policy at
/// equal total bits/token.
///
/// Returns the chosen candidate index per item, or an error if even
/// the minimal assignment exceeds the budget.
pub fn allocate_budget(
    items: &[BudgetItem],
    budget_bits: usize,
) -> Result<Vec<usize>, String> {
    for it in items {
        assert!(
            !it.candidates.is_empty()
                && it.candidates.windows(2).all(|w| w[0].0 < w[1].0),
            "candidates must be non-empty and ascending in m"
        );
    }
    let mut choice = vec![0usize; items.len()];
    let mut spent: usize =
        items.iter().map(|it| it.bits_at(0)).sum();
    if spent > budget_bits {
        return Err(format!(
            "bits/token budget {budget_bits} is below the minimal \
             assignment ({spent} bits across {} slots)",
            items.len()
        ));
    }
    loop {
        // best single upgrade: any later candidate of any item, ranked
        // by error reduction per extra bit
        let mut best: Option<(f64, usize, usize)> = None;
        for (i, it) in items.iter().enumerate() {
            let (_, e0) = it.candidates[choice[i]];
            let base_bits = it.bits_at(choice[i]);
            for j in choice[i] + 1..it.candidates.len() {
                let extra = it.bits_at(j) - base_bits;
                if spent + extra > budget_bits {
                    continue;
                }
                let gain = (e0 - it.candidates[j].1) / extra as f64;
                if gain <= 0.0 {
                    continue;
                }
                if best.map_or(true, |(g, _, _)| gain > g) {
                    best = Some((gain, i, j));
                }
            }
        }
        match best {
            Some((_, i, j)) => {
                spent += items[i].bits_at(j) - items[i].bits_at(choice[i]);
                choice[i] = j;
            }
            None => break,
        }
    }

    // uniform safety net: the calibrated result must never lose to the
    // best single-m assignment at the same budget
    let total = |ch: &[usize]| -> f64 {
        items
            .iter()
            .zip(ch)
            .map(|(it, &c)| it.candidates[c].1)
            .sum()
    };
    let greedy_err = total(&choice);
    if let Some(first) = items.first() {
        for (ci, &(m, _)) in first.candidates.iter().enumerate() {
            let uni: Option<Vec<usize>> = items
                .iter()
                .map(|it| {
                    it.candidates.iter().position(|&(mm, _)| mm == m)
                })
                .collect();
            let _ = ci;
            let Some(uni) = uni else { continue };
            let bits: usize = items
                .iter()
                .zip(&uni)
                .map(|(it, &c)| it.bits_at(c))
                .sum();
            if bits <= budget_bits && total(&uni) < greedy_err {
                return Ok(uni);
            }
        }
    }
    Ok(choice)
}

/// The norm threshold for [`CompressionPolicy::Prune`]: the
/// `frac`-quantile of the calibration tokens' mean-head key L2 norms.
/// Tokens whose norm falls strictly below the returned value are
/// pruned at append time, so roughly `frac` of a calibration-like
/// stream is dropped (`frac = 0` prunes nothing).
pub fn prune_threshold(norms: &[f32], frac: f64) -> f32 {
    assert!(!norms.is_empty(), "prune_threshold needs calibration norms");
    let mut sorted = norms.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let idx = ((frac * sorted.len() as f64) as usize).min(sorted.len() - 1);
    sorted[idx]
}

/// Resolved policy outcome for one (layer, head): the telemetry /
/// report surface of the tentpole ("rho-per-(layer,head) in the
/// report", ROADMAP).
#[derive(Clone, Copy, Debug)]
pub struct HeadPolicy {
    pub layer: usize,
    pub head: usize,
    /// key-side subspace count (0 = raw FP16 keys)
    pub key_m: usize,
    /// value-side subspace count (0 = raw FP32 values)
    pub value_m: usize,
    /// estimated key-score fidelity: Spearman ρ between exact and ADC
    /// scores on calibration probes (1.0 for raw keys)
    pub rho: f64,
}

/// The engine's record of what the policy resolved to, captured at
/// build time and surfaced through `ServingReport`.
#[derive(Clone, Debug, Default)]
pub struct PolicySummary {
    /// [`CompressionPolicy::name`] of the active policy
    pub policy: String,
    /// bits/token actually spent across every PQ (layer, head, side)
    pub total_bits_per_token: usize,
    /// per-layer prune thresholds (empty when pruning is off)
    pub prune_thresholds: Vec<f32>,
    /// one entry per (layer, head)
    pub heads: Vec<HeadPolicy>,
}

impl PolicySummary {
    /// Smallest per-(layer, head) rho estimate (1.0 when no PQ side
    /// exists) — the single-number fidelity floor for reports.
    pub fn min_rho(&self) -> f64 {
        self.heads
            .iter()
            .map(|h| h.rho)
            .fold(1.0f64, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(
        layer: usize,
        head: usize,
        side: Side,
        code_bits: usize,
        cands: &[(usize, f64)],
    ) -> BudgetItem {
        BudgetItem {
            layer,
            head,
            side,
            code_bits,
            candidates: cands.to_vec(),
        }
    }

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        for s in ["uniform", "calibrated-512", "prune-0.1"] {
            let p = CompressionPolicy::parse(s).unwrap();
            assert_eq!(p.name(), s);
        }
        for bad in [
            "", "none", "calibrated-", "calibrated-0", "calibrated-x",
            "prune-0", "prune-1", "prune-1.5", "prune-abc",
        ] {
            let err = CompressionPolicy::parse(bad).unwrap_err();
            assert!(err.contains("--policy"), "{err}");
            assert!(err.contains("calibrated-<bits>"), "{err}");
        }
    }

    #[test]
    fn allocator_spends_budget_where_error_drops_fastest() {
        // head 0's error collapses with more subspaces, head 1's is
        // already flat — the budget should go to head 0
        let items = vec![
            item(0, 0, Side::Key, 8, &[(2, 10.0), (4, 1.0), (8, 0.5)]),
            item(0, 1, Side::Key, 8, &[(2, 1.0), (4, 0.99), (8, 0.98)]),
        ];
        // budget: 2+4 subspaces · 8 bits = 48 bits
        let choice = allocate_budget(&items, 48).unwrap();
        assert_eq!(items[0].candidates[choice[0]].0, 4);
        assert_eq!(items[1].candidates[choice[1]].0, 2);
    }

    #[test]
    fn allocator_errors_below_minimal_budget() {
        let items =
            vec![item(0, 0, Side::Key, 8, &[(2, 1.0), (4, 0.5)])];
        let err = allocate_budget(&items, 15).unwrap_err();
        assert!(err.contains("budget"), "{err}");
    }

    #[test]
    fn allocator_never_loses_to_uniform_at_equal_bits() {
        // adversarial: greedy's per-bit ranking would splurge on item
        // 0's early win and strand item 1 at its worst candidate; the
        // uniform safety net must still hold
        let items = vec![
            item(0, 0, Side::Key, 8, &[(2, 5.0), (4, 4.9), (8, 0.1)]),
            item(0, 1, Side::Key, 8, &[(2, 5.0), (4, 0.2), (8, 0.19)]),
        ];
        for budget in [32usize, 48, 64, 96, 128] {
            let choice = allocate_budget(&items, budget).unwrap();
            let err: f64 = items
                .iter()
                .zip(&choice)
                .map(|(it, &c)| it.candidates[c].1)
                .sum();
            // best uniform at this budget
            let mut best_uni = f64::INFINITY;
            for &(m, _) in &items[0].candidates {
                let bits: usize =
                    items.iter().map(|it| m * it.code_bits).sum();
                if bits > budget {
                    continue;
                }
                let e: f64 = items
                    .iter()
                    .map(|it| {
                        it.candidates
                            .iter()
                            .find(|&&(mm, _)| mm == m)
                            .unwrap()
                            .1
                    })
                    .sum();
                best_uni = best_uni.min(e);
            }
            if best_uni.is_finite() {
                assert!(
                    err <= best_uni + 1e-12,
                    "budget {budget}: calibrated {err} > uniform \
                     {best_uni}"
                );
            }
        }
    }

    #[test]
    fn allocation_property_budget_and_determinism() {
        // property: for random error tables the allocation (a) never
        // exceeds the budget, (b) is reproducible from identical
        // inputs — the "deterministic for a fixed calibration seed"
        // half of the tentpole contract
        crate::prop_assert!("policy-budget", 200, |g| {
            let n_items = g.usize_in(1, 12);
            let code_bits = [4usize, 6, 8][g.usize_in(0, 2)];
            let items: Vec<BudgetItem> = (0..n_items)
                .map(|i| {
                    // errors drawn decreasing-ish in m, like real
                    // k-means residuals
                    let mut e = g.f32_in(0.5, 4.0) as f64;
                    let cands: Vec<(usize, f64)> = [2usize, 4, 8, 16]
                        .iter()
                        .map(|&m| {
                            e *= g.f32_in(0.3, 1.05) as f64;
                            (m, e)
                        })
                        .collect();
                    BudgetItem {
                        layer: i / 4,
                        head: i % 4,
                        side: if i % 2 == 0 {
                            Side::Key
                        } else {
                            Side::Value
                        },
                        code_bits,
                        candidates: cands,
                    }
                })
                .collect();
            let min_bits: usize =
                items.iter().map(|it| it.bits_at(0)).sum();
            let budget =
                min_bits + g.usize_in(0, 16 * code_bits * n_items);
            let a = allocate_budget(&items, budget)
                .map_err(|e| e.to_string())?;
            let spent: usize = items
                .iter()
                .zip(&a)
                .map(|(it, &c)| it.bits_at(c))
                .sum();
            if spent > budget {
                return Err(format!("spent {spent} > budget {budget}"));
            }
            let b = allocate_budget(&items, budget).unwrap();
            if a != b {
                return Err("allocation is not deterministic".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prune_threshold_is_the_frac_quantile() {
        let norms: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        // 10% quantile of 1..=100 → the 11th smallest (index 10)
        assert_eq!(prune_threshold(&norms, 0.1), 11.0);
        // pruning is strict-below, so frac→0 keeps everything
        assert_eq!(prune_threshold(&norms, 0.0), 1.0);
        assert_eq!(prune_threshold(&norms, 0.999), 100.0);
        // order-independent
        let mut rev = norms.clone();
        rev.reverse();
        assert_eq!(prune_threshold(&rev, 0.1), 11.0);
    }

    #[test]
    fn summary_min_rho_floors_over_heads() {
        let s = PolicySummary {
            policy: "calibrated-256".into(),
            total_bits_per_token: 256,
            prune_thresholds: Vec::new(),
            heads: vec![
                HeadPolicy {
                    layer: 0,
                    head: 0,
                    key_m: 4,
                    value_m: 0,
                    rho: 0.99,
                },
                HeadPolicy {
                    layer: 0,
                    head: 1,
                    key_m: 2,
                    value_m: 0,
                    rho: 0.97,
                },
            ],
        };
        assert!((s.min_rho() - 0.97).abs() < 1e-12);
        assert_eq!(PolicySummary::default().min_rho(), 1.0);
    }
}
