//! Continuous batching: cache-aware admission + round-robin decode
//! scheduling (the Orca/vLLM iteration-level scheduling policy, scaled
//! to this testbed).

use std::collections::VecDeque;

use super::engine::Engine;
use super::request::{CompletedRequest, Request};
use crate::kvcache::{SeqId, BLOCK_TOKENS};

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// max sequences decoding concurrently
    pub max_batch: usize,
    /// max queued requests before rejection (backpressure)
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_queue: 64 }
    }
}

struct Active {
    req: Request,
    admitted_s: f64,
    first_token_s: Option<f64>,
    generated: Vec<u32>,
}

/// Iteration-level batcher over one engine.
pub struct Batcher {
    pub cfg: BatcherConfig,
    engine: Engine,
    queue: VecDeque<Request>,
    active: Vec<Active>,
    pub completed: Vec<CompletedRequest>,
    pub rejected: Vec<SeqId>,
}

impl Batcher {
    pub fn new(engine: Engine, cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            engine,
            queue: VecDeque::new(),
            active: Vec::new(),
            completed: Vec::new(),
            rejected: Vec::new(),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Submit a request. Returns false (and records the rejection) when
    /// the queue is full — the router's backpressure signal.
    pub fn submit(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.cfg.max_queue {
            self.rejected.push(req.id);
            return false;
        }
        self.queue.push_back(req);
        true
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Anything left to do?
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Admit queued requests while batch slots and cache blocks allow.
    /// FCFS with head-of-line blocking (matching the paper setting of a
    /// single bandwidth-constrained device; no preemption). Everything
    /// admissible this tick prefills in one [`Engine::start_seq_batch`]
    /// call, so prompt prefills run concurrently.
    pub fn admit(&mut self, now_s: f64) {
        // drain the admissible prefix of the queue against a cumulative
        // block budget (prompt + full generation, the no-preemption
        // worst case)
        let mut budget = self.engine.free_blocks();
        let mut picked: Vec<Request> = Vec::new();
        while self.active.len() + picked.len() < self.cfg.max_batch {
            let Some(front) = self.queue.front() else { break };
            let total = front.prompt.len() + front.max_new_tokens;
            let need = total.div_ceil(BLOCK_TOKENS);
            if need > budget {
                break; // wait for cache space
            }
            budget -= need;
            picked.push(self.queue.pop_front().unwrap());
        }
        if picked.is_empty() {
            return;
        }
        let reqs: Vec<(SeqId, &[u32])> = picked
            .iter()
            .map(|r| (r.id, r.prompt.as_slice()))
            .collect();
        let results = self.engine.start_seq_batch(&reqs);
        drop(reqs);
        let mut requeue = Vec::new();
        for (req, res) in picked.into_iter().zip(results) {
            match res {
                Ok(()) => self.active.push(Active {
                    req,
                    admitted_s: now_s,
                    first_token_s: None,
                    generated: Vec::new(),
                }),
                // cache raced below the estimate — requeue in order
                Err(_) => requeue.push(req),
            }
        }
        for req in requeue.into_iter().rev() {
            self.queue.push_front(req);
        }
    }

    /// One decode iteration across the active batch: a single
    /// [`Engine::decode_batch`] tick over every active sequence —
    /// independent (seq, head) attention items run concurrently inside
    /// the engine. Returns the number of tokens produced; `now_s`
    /// stamps completion records.
    pub fn step(&mut self, now_s: f64) -> anyhow::Result<usize> {
        if self.active.is_empty() {
            return Ok(0);
        }
        let ids: Vec<SeqId> =
            self.active.iter().map(|a| a.req.id).collect();
        let toks = self.engine.decode_batch(&ids)?;
        let produced = toks.len();
        for (a, &tok) in self.active.iter_mut().zip(&toks) {
            if a.first_token_s.is_none() {
                a.first_token_s = Some(now_s);
            }
            a.generated.push(tok);
        }
        // sweep completions after the tick
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated.len()
                >= self.active[i].req.max_new_tokens
            {
                let a = self.active.swap_remove(i);
                self.engine.release(a.req.id)?;
                self.completed.push(CompletedRequest {
                    id: a.req.id,
                    prompt_tokens: a.req.prompt.len(),
                    generated: a.generated,
                    arrival_s: a.req.arrival_s,
                    admitted_s: a.admitted_s,
                    first_token_s: a.first_token_s.unwrap(),
                    finished_s: now_s,
                });
            } else {
                i += 1;
            }
        }
        Ok(produced)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{AttentionBackend, EngineConfig};
    use crate::model::{ByteTokenizer, ModelConfig};

    fn mk_batcher(max_batch: usize, max_queue: usize, blocks: usize)
        -> Batcher
    {
        let engine = Engine::build(&EngineConfig {
            model: ModelConfig::test_tiny(),
            backend: AttentionBackend::Fp16Exact,
            value_backend:
                crate::coordinator::engine::ValueBackend::Fp32,
            seed: 3,
            cache_blocks: blocks,
            calib_tokens: 64,
            decode_threads: 2,
        })
        .unwrap();
        Batcher::new(engine, BatcherConfig { max_batch, max_queue })
    }

    fn req(id: u64, gen: usize) -> Request {
        Request {
            id,
            prompt: ByteTokenizer::new().encode("prompt text"),
            max_new_tokens: gen,
            arrival_s: 0.0,
        }
    }

    #[test]
    fn processes_all_requests_to_completion() {
        let mut b = mk_batcher(2, 16, 64);
        for i in 0..5 {
            assert!(b.submit(req(i, 3)));
        }
        let mut now = 0.0;
        let mut iters = 0;
        while !b.idle() {
            b.admit(now);
            b.step(now).unwrap();
            now += 0.01;
            iters += 1;
            assert!(iters < 1000, "stuck");
        }
        assert_eq!(b.completed.len(), 5);
        for c in &b.completed {
            assert_eq!(c.generated.len(), 3);
            assert!(c.finished_s >= c.first_token_s);
        }
        // all cache released
        assert_eq!(b.engine().cache_stats().tokens, 0);
    }

    #[test]
    fn drains_queue_on_fully_compressed_engine() {
        // admission + decode ticks over the lookat-kv (PQ keys + PQ
        // values) engine: block accounting is storage-agnostic, so the
        // batcher needs no special casing — this pins that down
        let engine = Engine::build(&EngineConfig {
            model: ModelConfig::test_tiny(),
            backend: AttentionBackend::Lookat { m: 4, k: 64 },
            value_backend:
                crate::coordinator::engine::ValueBackend::Pq {
                    m: 4,
                    k: 64,
                },
            seed: 3,
            cache_blocks: 64,
            calib_tokens: 64,
            decode_threads: 2,
        })
        .unwrap();
        let mut b =
            Batcher::new(engine, BatcherConfig { max_batch: 2, max_queue: 16 });
        for i in 0..4 {
            assert!(b.submit(req(i, 3)));
        }
        let mut now = 0.0;
        let mut iters = 0;
        while !b.idle() {
            b.admit(now);
            b.step(now).unwrap();
            now += 0.01;
            iters += 1;
            assert!(iters < 1000, "stuck");
        }
        assert_eq!(b.completed.len(), 4);
        assert_eq!(b.engine().cache_stats().tokens, 0);
    }

    #[test]
    fn respects_max_batch() {
        let mut b = mk_batcher(2, 16, 64);
        for i in 0..6 {
            b.submit(req(i, 10));
        }
        b.admit(0.0);
        assert_eq!(b.active(), 2);
        assert_eq!(b.queued(), 4);
    }

    #[test]
    fn queue_backpressure_rejects() {
        let mut b = mk_batcher(1, 2, 64);
        assert!(b.submit(req(0, 1)));
        assert!(b.submit(req(1, 1)));
        assert!(!b.submit(req(2, 1)), "third submit must be rejected");
        assert_eq!(b.rejected, vec![2]);
    }

    #[test]
    fn cache_pressure_blocks_admission() {
        // 2 blocks = 64 tokens total; each request needs ~12+30 tokens
        let mut b = mk_batcher(8, 16, 2);
        for i in 0..4 {
            b.submit(req(i, 30));
        }
        b.admit(0.0);
        assert!(b.active() <= 2, "cache should limit admissions");
        assert!(b.active() >= 1);
    }

    #[test]
    fn completion_frees_capacity_for_queue() {
        let mut b = mk_batcher(1, 16, 64);
        b.submit(req(0, 2));
        b.submit(req(1, 2));
        let mut now = 0.0;
        while !b.idle() {
            b.admit(now);
            b.step(now).unwrap();
            now += 1.0;
        }
        assert_eq!(b.completed.len(), 2);
        // FCFS: request 0 finished first
        assert_eq!(b.completed[0].id, 0);
        assert_eq!(b.completed[1].id, 1);
        assert!(b.completed[1].admitted_s > b.completed[0].admitted_s - 1e-9);
    }

    #[test]
    fn batch_size_invariant_property() {
        let mut b = mk_batcher(3, 64, 64);
        let mut next_id = 0u64;
        let mut now = 0.0;
        crate::prop_assert!("batch-bounds", 150, |g| {
            match g.usize_in(0, 2) {
                0 => {
                    b.submit(req(next_id, g.usize_in(1, 4)));
                    next_id += 1;
                }
                _ => {
                    b.admit(now);
                    b.step(now).map_err(|e| e.to_string())?;
                    now += 0.1;
                }
            }
            if b.active() > 3 {
                return Err(format!("batch overflow: {}", b.active()));
            }
            // conservation: submitted == queued + active + done + rejected
            let total = b.queued() + b.active() + b.completed.len()
                + b.rejected.len();
            if total != next_id as usize {
                return Err(format!("lost requests: {total} != {next_id}"));
            }
            Ok(())
        });
    }
}
