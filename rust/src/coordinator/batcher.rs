//! Continuous batching: cache-aware admission, Sarathi-style chunked
//! prefill and (optionally) preemptive iteration-level scheduling over
//! one engine.
//!
//! Every [`Batcher::step`] tick assembles one mixed [`TickEntry`] plan:
//! each decoding sequence contributes a one-token decode entry and each
//! still-prefilling sequence contributes its next prefill chunk
//! (`EngineConfig::prefill_chunk` tokens, 0 = monolithic), so long
//! prompts interleave with decode instead of stalling it. Under
//! [`SchedulerPolicy::Preempt`], block pressure evicts the
//! lowest-priority running sequence. With the swap tier enabled
//! (`BatcherConfig::swap`) a [`SwapCostModel`] picks per victim between
//! spilling its cache blocks to the host-side store (restored
//! bit-identically on re-admission — no recompute at all) and the
//! legacy path of freeing the blocks and re-prefilling from tokens;
//! either way the engine guarantees the resumed logits are
//! bit-identical to the uninterrupted run.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use super::engine::{Engine, TickEntry};
use super::request::{CompletedRequest, Request};
use crate::kvcache::{CacheError, SeqId, BLOCK_TOKENS};
use crate::telemetry::{Ctr, Gauge, Hist, MetricsRegistry, TraceKind, TraceRing};
use crate::util::fault::{FaultAction, FaultPlan, FaultSite};

/// How the batcher arbitrates cache blocks between running sequences.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// FCFS with head-of-line blocking (the paper setting of a single
    /// bandwidth-constrained device): admission charges the full
    /// prompt + generation worst case up front and running sequences
    /// are never evicted.
    Fcfs,
    /// Preemptive continuous batching: admission charges only the
    /// first prefill chunk, and when the block budget runs dry the
    /// lowest-priority (latest-arrived) running sequence frees its
    /// blocks and re-enters the queue front for later re-prefill.
    Preempt,
}

/// Recompute-vs-swap cost model consulted when a sequence is preempted
/// under [`SchedulerPolicy::Preempt`]: spill the cache to the host-side
/// swap tier when copying it out and back is estimated cheaper than
/// re-running prefill over the sequence's context. With LOOKAT's
/// 1 B/subspace codes the spill is ~64× smaller than fp16, so swap wins
/// for all but the shortest contexts.
#[derive(Clone, Copy, Debug)]
pub struct SwapCostModel {
    /// host copy bandwidth for spill + restore, bytes/s
    pub copy_bytes_per_s: f64,
    /// prefill recompute throughput, tokens/s
    pub prefill_tok_s: f64,
}

impl Default for SwapCostModel {
    fn default() -> Self {
        Self {
            copy_bytes_per_s: 8e9,
            prefill_tok_s: 2000.0,
        }
    }
}

impl SwapCostModel {
    /// Swap when round-tripping `spill_bytes` through the host costs
    /// less than re-prefilling `ctx_tokens`.
    pub fn should_swap(&self, spill_bytes: usize, ctx_tokens: usize) -> bool {
        let copy_s =
            2.0 * spill_bytes as f64 / self.copy_bytes_per_s.max(1.0);
        let recompute_s =
            ctx_tokens as f64 / self.prefill_tok_s.max(1e-9);
        copy_s < recompute_s
    }
}

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// max sequences decoding concurrently
    pub max_batch: usize,
    /// max queued requests before rejection (backpressure); preempted
    /// sequences re-enter at the front and may transiently exceed this
    pub max_queue: usize,
    /// block arbitration policy
    pub policy: SchedulerPolicy,
    /// spill preempted sequences to the swap tier instead of
    /// re-prefilling, when the cost model agrees (Preempt policy only)
    pub swap: bool,
    /// recompute-vs-swap decision model
    pub swap_cost: SwapCostModel,
    /// server-side default deadline for requests that carry no
    /// `timeout_ms` of their own (`None` = unlimited). A request past
    /// its deadline is expired: blocks reclaimed, id pushed to
    /// [`Batcher::expired`] for the caller to answer
    pub deadline_ms: Option<u64>,
    /// scheduler-side fault injection (the `tick` site); disabled plans
    /// cost one branch per tick
    pub faults: FaultPlan,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_queue: 64,
            policy: SchedulerPolicy::Fcfs,
            swap: true,
            swap_cost: SwapCostModel::default(),
            deadline_ms: None,
            faults: FaultPlan::default(),
        }
    }
}

/// A queued request, possibly carrying preemption state.
struct Queued {
    req: Request,
    /// tokens generated before a preemption — re-prefilled (not
    /// re-generated) on re-admission
    resume: Vec<u32>,
    /// original admission time, preserved across preemptions
    first_admitted_s: Option<f64>,
    /// original first-token time, preserved across preemptions
    first_token_s: Option<f64>,
    /// cache state is resident in the engine's swap tier — re-admission
    /// restores it instead of re-prefilling
    swapped: bool,
}

impl Queued {
    fn fresh(req: Request) -> Self {
        Self {
            req,
            resume: Vec::new(),
            first_admitted_s: None,
            first_token_s: None,
            swapped: false,
        }
    }

    /// Tokens this request must (re-)prefill on admission.
    fn context_len(&self) -> usize {
        self.req.prompt.len() + self.resume.len()
    }
}

struct Active {
    req: Request,
    admitted_s: f64,
    first_token_s: Option<f64>,
    /// when the most recent token was produced — inter-token latency
    /// histogram source (not preserved across preemptions: the ITL a
    /// client observes across a swap gap includes that gap)
    last_token_s: Option<f64>,
    /// prompt ++ resumed tokens — the prefill source
    prefill_src: Vec<u32>,
    /// tokens of `prefill_src` already in cache
    prefilled: usize,
    /// all generated tokens (resumed ones included)
    generated: Vec<u32>,
}

impl Active {
    fn prefilling(&self) -> bool {
        self.prefilled < self.prefill_src.len()
    }
}

/// Iteration-level batcher over one engine.
pub struct Batcher {
    pub cfg: BatcherConfig,
    engine: Engine,
    queue: VecDeque<Queued>,
    active: Vec<Active>,
    pub completed: Vec<CompletedRequest>,
    pub rejected: Vec<SeqId>,
    /// requests that blew their deadline (queued or active); blocks are
    /// already reclaimed — the caller owes each id a `deadline` error
    pub expired: Vec<SeqId>,
    /// sequences torn down by [`Batcher::quarantine_active`] after a
    /// tick panic; the caller owes each id a structured error
    pub quarantined: Vec<SeqId>,
    /// sequences evicted under block pressure (cumulative; drained by
    /// the router per serving run)
    pub preemptions: usize,
    /// preemptions that spilled to the swap tier instead of freeing
    pub swap_outs: usize,
    /// re-admissions restored from the swap tier (no re-prefill)
    pub swap_ins: usize,
    /// admissions that attached shared prefix-cache blocks
    pub prefix_hits: usize,
    /// live metrics sink, shared with the engine (`Engine::metrics`)
    metrics: Arc<MetricsRegistry>,
    /// opt-in per-request event ring (`--trace-out`); absent = zero cost
    tracer: Option<Arc<TraceRing>>,
}

impl Batcher {
    pub fn new(engine: Engine, cfg: BatcherConfig) -> Self {
        let metrics = engine.metrics();
        Self {
            cfg,
            engine,
            queue: VecDeque::new(),
            active: Vec::new(),
            completed: Vec::new(),
            rejected: Vec::new(),
            expired: Vec::new(),
            quarantined: Vec::new(),
            preemptions: 0,
            swap_outs: 0,
            swap_ins: 0,
            prefix_hits: 0,
            metrics,
            tracer: None,
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Attach a per-request event tracer. Scheduling decisions and tick
    /// spans are recorded into its ring from this point on.
    pub fn set_tracer(&mut self, tracer: Arc<TraceRing>) {
        self.tracer = Some(tracer);
    }

    pub fn tracer(&self) -> Option<&Arc<TraceRing>> {
        self.tracer.as_ref()
    }

    #[inline]
    fn trace(&self, ts_s: f64, seq: SeqId, kind: TraceKind, dur_s: f64, arg: usize) {
        if let Some(t) = &self.tracer {
            t.record(ts_s, seq, kind, dur_s, arg.min(u32::MAX as usize) as u32);
        }
    }

    /// Submit a request. Returns false (and records the rejection) when
    /// the queue is full — the router's backpressure signal.
    pub fn submit(&mut self, req: Request) -> bool {
        self.metrics.inc(Ctr::RequestsSubmitted, 1);
        if self.queue.len() >= self.cfg.max_queue {
            self.metrics.inc(Ctr::RequestsRejected, 1);
            self.trace(
                req.arrival_s,
                req.id,
                TraceKind::Rejected,
                0.0,
                req.prompt.len(),
            );
            self.rejected.push(req.id);
            return false;
        }
        self.trace(
            req.arrival_s,
            req.id,
            TraceKind::Queued,
            0.0,
            req.prompt.len(),
        );
        self.queue.push_back(Queued::fresh(req));
        self.metrics.set(Gauge::QueueDepth, self.queue.len() as u64);
        true
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Anything left to do?
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Expire queued and active requests past their deadline
    /// ([`Request::timeout_ms`], defaulting to
    /// `BatcherConfig::deadline_ms`). Cache state — live blocks for
    /// active sequences, spill-store slabs for swapped queued ones — is
    /// reclaimed through [`Engine::release`]; the id lands in
    /// [`Batcher::expired`] so the caller can answer the connection.
    fn expire_deadlines(&mut self, now_s: f64) {
        let default_ms = self.cfg.deadline_ms;
        let mut i = 0;
        while i < self.queue.len() {
            let past = self.queue[i]
                .req
                .deadline_s(default_ms)
                .is_some_and(|d| now_s >= d);
            if !past {
                i += 1;
                continue;
            }
            let q = self.queue.remove(i).unwrap();
            // swapped entries hold spill-store state, fresh ones hold
            // nothing at all — release is best-effort either way
            let _ = self.engine.release(q.req.id);
            self.expire(q.req.id, now_s, q.context_len());
        }
        let mut i = 0;
        while i < self.active.len() {
            let past = self.active[i]
                .req
                .deadline_s(default_ms)
                .is_some_and(|d| now_s >= d);
            if !past {
                i += 1;
                continue;
            }
            let a = self.active.swap_remove(i);
            let _ = self.engine.release(a.req.id);
            self.expire(a.req.id, now_s, a.generated.len());
        }
    }

    fn expire(&mut self, id: SeqId, now_s: f64, arg: usize) {
        self.metrics.inc(Ctr::DeadlineExpired, 1);
        self.trace(now_s, id, TraceKind::Rejected, 0.0, arg);
        self.expired.push(id);
    }

    /// Tear down every active sequence after a tick panic: blocks are
    /// freed (best effort — the engine itself may be mid-fault), ids
    /// land in [`Batcher::quarantined`] for the caller to answer, and
    /// the scheduler is left clean so serving continues. Returns the
    /// quarantined ids.
    pub fn quarantine_active(&mut self, now_s: f64) -> Vec<SeqId> {
        let mut ids = Vec::with_capacity(self.active.len());
        for a in self.active.drain(..) {
            let _ = self.engine.release(a.req.id);
            ids.push(a.req.id);
        }
        if !ids.is_empty() {
            self.metrics.inc(Ctr::PanicsQuarantined, 1);
        }
        for &id in &ids {
            self.trace(now_s, id, TraceKind::Rejected, 0.0, 0);
        }
        self.quarantined.extend_from_slice(&ids);
        self.metrics.set(Gauge::ActiveSeqs, self.active.len() as u64);
        ids
    }

    /// Blocks the queue-front request needs to be admitted under the
    /// current policy.
    fn admission_need(&self, q: &Queued) -> usize {
        let ctx = q.context_len();
        match self.cfg.policy {
            // worst case: the whole prompt plus every future token,
            // because nothing is ever evicted
            SchedulerPolicy::Fcfs => {
                (ctx + q.req.max_new_tokens).div_ceil(BLOCK_TOKENS)
            }
            // only the first prefill chunk is charged; later pressure
            // is resolved by preemption, so admission stops rejecting
            // requests the scheduler can handle
            SchedulerPolicy::Preempt => {
                let chunk = self.engine.prefill_chunk();
                let first = if chunk == 0 { ctx } else { ctx.min(chunk) };
                first.max(1).div_ceil(BLOCK_TOKENS).max(1)
            }
        }
    }

    /// Admit queued requests while batch slots and cache blocks allow.
    /// Admission only registers the sequence (no prefill compute): the
    /// prompt is fed to the engine chunk by chunk inside
    /// [`Batcher::step`]'s mixed ticks.
    pub fn admit(&mut self, now_s: f64) {
        self.expire_deadlines(now_s);
        let mut budget = self.engine.free_blocks();
        let total = self.engine.total_blocks();
        while self.active.len() < self.cfg.max_batch {
            let Some(front) = self.queue.front() else { break };
            // swap-tier re-admission: the sequence's cache state is
            // resident in the spill store — restore it wholesale
            // instead of re-prefilling (its peak already passed
            // admission once, so no peak-fit re-check)
            if front.swapped {
                let need = self.engine.swapped_blocks(front.req.id);
                if need > budget {
                    break; // wait for cache space
                }
                let mut q = self.queue.pop_front().unwrap();
                match self.engine.swap_in(q.req.id) {
                    Ok(()) => {
                        budget -= need;
                        self.swap_ins += 1;
                        self.trace(
                            now_s,
                            q.req.id,
                            TraceKind::SwapIn,
                            0.0,
                            need,
                        );
                        let mut prefill_src = q.req.prompt.clone();
                        prefill_src.extend_from_slice(&q.resume);
                        // everything through pos is already in cache:
                        // a decode-phase victim resumes decoding
                        // immediately, a mid-prefill one continues
                        // chunking where it stopped
                        let prefilled = self
                            .engine
                            .seq_pos(q.req.id)
                            .unwrap_or(0)
                            .min(prefill_src.len());
                        self.active.push(Active {
                            admitted_s: q.first_admitted_s.unwrap_or(now_s),
                            first_token_s: q.first_token_s.take(),
                            last_token_s: None,
                            prefill_src,
                            prefilled,
                            generated: std::mem::take(&mut q.resume),
                            req: q.req,
                        });
                    }
                    Err(CacheError::OutOfBlocks) => {
                        // budget raced with the engine: retry later
                        self.queue.push_front(q);
                        break;
                    }
                    Err(_) => {
                        // spill entry unusable — fall back to the
                        // re-prefill path on the next iteration
                        q.swapped = false;
                        self.queue.push_front(q);
                    }
                }
                continue;
            }
            // a request whose peak context (prompt + full generation)
            // can never fit in the whole cache would either head-of-line
            // block forever (fcfs) or hard-error mid-generation
            // (preempt) — reject it outright
            let peak = front.req.prompt.len() + front.req.max_new_tokens;
            if peak.div_ceil(BLOCK_TOKENS) > total {
                let q = self.queue.pop_front().unwrap();
                self.metrics.inc(Ctr::RequestsRejected, 1);
                self.trace(
                    now_s,
                    q.req.id,
                    TraceKind::Rejected,
                    0.0,
                    q.req.prompt.len(),
                );
                self.rejected.push(q.req.id);
                continue;
            }
            let need = self.admission_need(front);
            if need > budget {
                break; // wait for cache space
            }
            let mut q = self.queue.pop_front().unwrap();
            let mut prefill_src = q.req.prompt.clone();
            prefill_src.extend_from_slice(&q.resume);
            let shared = match self
                .engine
                .begin_seq_with_prefix(q.req.id, &prefill_src)
            {
                Ok(shared) => shared,
                Err(_) => {
                    // id collision with a live sequence: refuse it
                    self.metrics.inc(Ctr::RequestsRejected, 1);
                    self.rejected.push(q.req.id);
                    continue;
                }
            };
            if shared > 0 {
                self.prefix_hits += 1;
                self.metrics.inc(Ctr::PrefixHits, 1);
                self.metrics.inc(Ctr::PrefixTokensReused, shared as u64);
            }
            self.trace(now_s, q.req.id, TraceKind::Admitted, 0.0, shared);
            budget -= need.min(budget);
            self.active.push(Active {
                admitted_s: q.first_admitted_s.unwrap_or(now_s),
                first_token_s: q.first_token_s.take(),
                last_token_s: None,
                prefill_src,
                prefilled: shared,
                generated: std::mem::take(&mut q.resume),
                req: q.req,
            });
        }
        self.metrics.set(Gauge::QueueDepth, self.queue.len() as u64);
        self.metrics.set(Gauge::ActiveSeqs, self.active.len() as u64);
    }

    /// This tick's span for one active sequence: the next prefill chunk
    /// while prefilling, one decode token afterwards.
    fn tick_span(&self, a: &Active) -> usize {
        if a.prefilling() {
            let rem = a.prefill_src.len() - a.prefilled;
            let chunk = self.engine.prefill_chunk();
            if chunk == 0 {
                rem
            } else {
                rem.min(chunk)
            }
        } else {
            1
        }
    }

    /// New cache blocks the tick's spans demand beyond what the active
    /// sequences already hold.
    fn tick_block_need(&self, spans: &[usize]) -> usize {
        self.active
            .iter()
            .zip(spans)
            .map(|(a, &s)| {
                let len = self.engine.seq_pos(a.req.id).unwrap_or(0);
                (len + s).div_ceil(BLOCK_TOKENS)
                    - len.div_ceil(BLOCK_TOKENS)
            })
            .sum()
    }

    /// Evict the lowest-priority active sequence (latest arrival, ties
    /// to the larger id). When the swap tier is on and the cost model
    /// favors it, the victim's cache blocks spill to the host-side
    /// store for bit-identical restore; otherwise its blocks are freed
    /// and it re-queues carrying its generated-so-far tokens for
    /// re-prefill. Returns false when there is nothing to evict.
    fn preempt_one(&mut self, now_s: f64) -> bool {
        let Some(idx) = (0..self.active.len()).max_by(|&i, &j| {
            let a = &self.active[i].req;
            let b = &self.active[j].req;
            a.arrival_s
                .total_cmp(&b.arrival_s)
                .then(a.id.cmp(&b.id))
        }) else {
            return false;
        };
        let a = self.active.swap_remove(idx);
        let id = a.req.id;
        // context that would need recomputing on the re-prefill path
        let ctx = a.req.prompt.len() + a.generated.len();
        let spill_bytes = self.engine.seq_spill_bytes(id);
        let swapped = self.cfg.swap
            && ctx > 0
            && self.cfg.swap_cost.should_swap(spill_bytes, ctx)
            && self.engine.swap_out(id).is_ok();
        if swapped {
            self.swap_outs += 1;
            self.trace(now_s, id, TraceKind::SwapOut, 0.0, spill_bytes);
        } else {
            let _ = self.engine.release(id);
            self.trace(now_s, id, TraceKind::Preempt, 0.0, ctx);
        }
        self.preemptions += 1;
        self.metrics.inc(Ctr::Preemptions, 1);
        self.queue.push_front(Queued {
            resume: a.generated,
            first_admitted_s: Some(a.admitted_s),
            first_token_s: a.first_token_s,
            swapped,
            req: a.req,
        });
        true
    }

    /// One serving iteration across the active batch: a single mixed
    /// [`Engine::step_batch`] tick — decode entries for decoding
    /// sequences, the next prefill chunk for prefilling ones; all
    /// (seq, head) work items run through the same per-layer plan.
    /// Under the preemptive policy, block pressure is resolved *before*
    /// the tick by evicting low-priority sequences. Returns the number
    /// of decode tokens produced; `now_s` stamps completion records.
    pub fn step(&mut self, now_s: f64) -> anyhow::Result<usize> {
        // tick-site fault hook, evaluated before any scheduler or
        // engine state changes so a panic here quarantines cleanly
        match self.cfg.faults.check(FaultSite::Tick) {
            None => {}
            Some(FaultAction::Delay(d)) => {
                self.metrics.inc(Ctr::FaultsInjected, 1);
                std::thread::sleep(d);
            }
            Some(FaultAction::Err) => {
                self.metrics.inc(Ctr::FaultsInjected, 1);
                anyhow::bail!("injected fault: tick");
            }
            Some(FaultAction::Panic) => {
                self.metrics.inc(Ctr::FaultsInjected, 1);
                panic!("injected fault: tick");
            }
        }
        self.expire_deadlines(now_s);
        if self.active.is_empty() {
            return Ok(0);
        }
        // plan spans, preempting under pressure until the tick fits
        let mut spans: Vec<usize> =
            self.active.iter().map(|a| self.tick_span(a)).collect();
        if self.cfg.policy == SchedulerPolicy::Preempt {
            while self.tick_block_need(&spans) > self.engine.free_blocks()
                && self.active.len() > 1
            {
                self.preempt_one(now_s);
                spans = self
                    .active
                    .iter()
                    .map(|a| self.tick_span(a))
                    .collect();
            }
            // last resort: a single sequence whose prefill chunk
            // outgrows the remaining budget gets a shorter chunk
            if self.active.len() == 1 && self.active[0].prefilling() {
                let free = self.engine.free_blocks();
                if self.tick_block_need(&spans) > free {
                    let len = self
                        .engine
                        .seq_pos(self.active[0].req.id)
                        .unwrap_or(0);
                    let tail = len.div_ceil(BLOCK_TOKENS) * BLOCK_TOKENS
                        - len;
                    let fit = tail + free * BLOCK_TOKENS;
                    if fit >= 1 {
                        spans[0] = spans[0].min(fit);
                    }
                }
            }
        }

        let entries: Vec<TickEntry<'_>> = self
            .active
            .iter()
            .zip(&spans)
            .map(|(a, &s)| {
                if a.prefilling() {
                    TickEntry::Prefill {
                        seq: a.req.id,
                        tokens: &a.prefill_src
                            [a.prefilled..a.prefilled + s],
                    }
                } else {
                    TickEntry::Decode(a.req.id)
                }
            })
            .collect();
        let tick_start = Instant::now();
        let outcomes = self.engine.step_batch(&entries)?;
        let tick_s = tick_start.elapsed().as_secs_f64();
        drop(entries);
        self.metrics.observe(Hist::TickS, tick_s);
        self.metrics.observe(Hist::BatchOccupancy, self.active.len() as f64);

        let mut produced = 0usize;
        for (i, out) in outcomes.iter().enumerate() {
            let a = &mut self.active[i];
            match out.token {
                Some(tok) => {
                    if a.first_token_s.is_none() {
                        a.first_token_s = Some(now_s);
                        self.metrics
                            .observe(Hist::TtftS, now_s - a.req.arrival_s);
                    } else if let Some(last) = a.last_token_s {
                        self.metrics.observe(Hist::ItlS, now_s - last);
                    }
                    a.last_token_s = Some(now_s);
                    a.generated.push(tok);
                    produced += 1;
                }
                None => {
                    a.prefilled += spans[i];
                    if let Some(t) = &self.tracer {
                        t.record(
                            now_s,
                            a.req.id,
                            TraceKind::PrefillChunk,
                            tick_s,
                            spans[i].min(u32::MAX as usize) as u32,
                        );
                    }
                    if !a.prefilling() {
                        // prefill just finished: publish its full
                        // blocks into the prefix cache (no-op when the
                        // cache is disabled)
                        self.engine
                            .register_prefix(a.req.id, &a.prefill_src);
                    }
                }
            }
        }

        if produced > 0 {
            // one engine-wide decode span per tick (lane 0) — per-token
            // events would exhaust the ring in seconds at scale
            self.trace(now_s, 0, TraceKind::DecodeTick, tick_s, produced);
        }

        // sweep completions after the tick
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated.len()
                >= self.active[i].req.max_new_tokens
            {
                let a = self.active.swap_remove(i);
                self.engine.release(a.req.id)?;
                self.metrics.inc(Ctr::RequestsCompleted, 1);
                self.metrics.observe(Hist::E2eS, now_s - a.req.arrival_s);
                self.trace(
                    now_s,
                    a.req.id,
                    TraceKind::Finish,
                    0.0,
                    a.generated.len(),
                );
                self.completed.push(CompletedRequest {
                    id: a.req.id,
                    prompt_tokens: a.req.prompt.len(),
                    generated: a.generated,
                    arrival_s: a.req.arrival_s,
                    admitted_s: a.admitted_s,
                    // None only for max_new_tokens == 0 (prefill-only
                    // requests complete without ever decoding)
                    first_token_s: a.first_token_s.unwrap_or(now_s),
                    finished_s: now_s,
                });
            } else {
                i += 1;
            }
        }
        self.metrics.set(Gauge::ActiveSeqs, self.active.len() as u64);
        self.metrics.set(Gauge::QueueDepth, self.queue.len() as u64);
        Ok(produced)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{AttentionBackend, EngineConfig};
    use crate::model::{ByteTokenizer, ModelConfig};

    fn mk_batcher_policy(
        max_batch: usize,
        max_queue: usize,
        blocks: usize,
        policy: SchedulerPolicy,
        prefill_chunk: usize,
    ) -> Batcher {
        let engine = Engine::build(&EngineConfig {
            model: ModelConfig::test_tiny(),
            backend: AttentionBackend::Fp16Exact,
            value_backend:
                crate::coordinator::engine::ValueBackend::Fp32,
            seed: 3,
            cache_blocks: blocks,
            calib_tokens: 64,
            decode_threads: 2,
            prefill_chunk,
            pipeline: true,
            prefix_cache: false,
            policy: crate::coordinator::CompressionPolicy::Uniform,
            faults: Default::default(),
        })
        .unwrap();
        Batcher::new(
            engine,
            BatcherConfig {
                max_batch,
                max_queue,
                policy,
                ..BatcherConfig::default()
            },
        )
    }

    fn mk_batcher(max_batch: usize, max_queue: usize, blocks: usize)
        -> Batcher
    {
        mk_batcher_policy(
            max_batch, max_queue, blocks, SchedulerPolicy::Fcfs, 0)
    }

    fn req(id: u64, gen: usize) -> Request {
        Request {
            id,
            prompt: ByteTokenizer::new().encode("prompt text"),
            max_new_tokens: gen,
            arrival_s: 0.0,
            timeout_ms: None,
        }
    }

    fn drain(b: &mut Batcher) {
        let mut now = 0.0;
        let mut iters = 0;
        while !b.idle() {
            b.admit(now);
            b.step(now).unwrap();
            now += 0.01;
            iters += 1;
            assert!(iters < 2000, "stuck");
        }
    }

    #[test]
    fn processes_all_requests_to_completion() {
        let mut b = mk_batcher(2, 16, 64);
        for i in 0..5 {
            assert!(b.submit(req(i, 3)));
        }
        drain(&mut b);
        assert_eq!(b.completed.len(), 5);
        for c in &b.completed {
            assert_eq!(c.generated.len(), 3);
            assert!(c.finished_s >= c.first_token_s);
        }
        // all cache released
        assert_eq!(b.engine().cache_stats().tokens, 0);
    }

    #[test]
    fn drains_queue_on_fully_compressed_engine() {
        // admission + decode ticks over the lookat-kv (PQ keys + PQ
        // values) engine: block accounting is storage-agnostic, so the
        // batcher needs no special casing — this pins that down
        let engine = Engine::build(&EngineConfig {
            model: ModelConfig::test_tiny(),
            backend: AttentionBackend::Lookat { m: 4, k: 64 },
            value_backend:
                crate::coordinator::engine::ValueBackend::Pq {
                    m: 4,
                    k: 64,
                },
            seed: 3,
            cache_blocks: 64,
            calib_tokens: 64,
            decode_threads: 2,
            prefill_chunk: 0,
            pipeline: true,
            prefix_cache: false,
            policy: crate::coordinator::CompressionPolicy::Uniform,
            faults: Default::default(),
        })
        .unwrap();
        let mut b = Batcher::new(
            engine,
            BatcherConfig {
                max_batch: 2,
                max_queue: 16,
                policy: SchedulerPolicy::Fcfs,
                ..BatcherConfig::default()
            },
        );
        for i in 0..4 {
            assert!(b.submit(req(i, 3)));
        }
        drain(&mut b);
        assert_eq!(b.completed.len(), 4);
        assert_eq!(b.engine().cache_stats().tokens, 0);
    }

    #[test]
    fn respects_max_batch() {
        let mut b = mk_batcher(2, 16, 64);
        for i in 0..6 {
            b.submit(req(i, 10));
        }
        b.admit(0.0);
        assert_eq!(b.active(), 2);
        assert_eq!(b.queued(), 4);
    }

    #[test]
    fn queue_backpressure_rejects() {
        let mut b = mk_batcher(1, 2, 64);
        assert!(b.submit(req(0, 1)));
        assert!(b.submit(req(1, 1)));
        assert!(!b.submit(req(2, 1)), "third submit must be rejected");
        assert_eq!(b.rejected, vec![2]);
    }

    #[test]
    fn cache_pressure_blocks_admission() {
        // 2 blocks = 64 tokens total; each request needs ~12+30 tokens
        let mut b = mk_batcher(8, 16, 2);
        for i in 0..4 {
            b.submit(req(i, 30));
        }
        b.admit(0.0);
        assert!(b.active() <= 2, "cache should limit admissions");
        assert!(b.active() >= 1);
    }

    #[test]
    fn preemptive_admission_charges_only_first_chunk() {
        // same 2-block cache: the FCFS worst-case charge admits one
        // request, the preemptive chunk charge admits several — the
        // admission bugfix the preemptive scheduler enables
        let mut fcfs = mk_batcher_policy(
            8, 16, 2, SchedulerPolicy::Fcfs, 8);
        let mut pre = mk_batcher_policy(
            8, 16, 2, SchedulerPolicy::Preempt, 8);
        for i in 0..4 {
            fcfs.submit(req(i, 30));
            pre.submit(req(i, 30));
        }
        fcfs.admit(0.0);
        pre.admit(0.0);
        assert!(pre.active() > fcfs.active(),
                "chunk-charged admission must admit more: {} vs {}",
                pre.active(), fcfs.active());
    }

    #[test]
    fn oversubscription_drains_with_preemption() {
        // far more demand than blocks: FCFS would reject or stall, the
        // preemptive scheduler cycles everything through to completion
        let mut b = mk_batcher_policy(
            4, 32, 3, SchedulerPolicy::Preempt, 8);
        for i in 0..6 {
            assert!(b.submit(req(i, 25)));
        }
        drain(&mut b);
        assert_eq!(b.completed.len(), 6);
        assert!(b.rejected.is_empty(), "no admitted request was dropped");
        assert_eq!(b.engine().cache_stats().tokens, 0);
    }

    #[test]
    fn zero_generation_request_completes_without_decode() {
        // prefill-only requests (max_new_tokens = 0) complete after
        // their prefill tick without ever producing a token — and
        // without panicking on the missing first-token timestamp
        let mut b = mk_batcher(2, 8, 64);
        b.submit(Request {
            id: 0,
            prompt: ByteTokenizer::new().encode("prefill only"),
            max_new_tokens: 0,
            arrival_s: 0.0,
            timeout_ms: None,
        });
        drain(&mut b);
        assert_eq!(b.completed.len(), 1);
        assert!(b.completed[0].generated.is_empty());
        assert_eq!(b.engine().cache_stats().tokens, 0);
    }

    #[test]
    fn never_fitting_request_is_rejected_not_stuck() {
        let mut b = mk_batcher_policy(
            2, 16, 2, SchedulerPolicy::Preempt, 8);
        let huge = Request {
            id: 9,
            prompt: vec![1u32; 3 * BLOCK_TOKENS],
            max_new_tokens: 4,
            arrival_s: 0.0,
            timeout_ms: None,
        };
        b.submit(huge);
        b.submit(req(1, 2));
        drain(&mut b);
        assert_eq!(b.rejected, vec![9]);
        assert_eq!(b.completed.len(), 1);
        assert_eq!(b.completed[0].id, 1);
    }

    #[test]
    fn completion_frees_capacity_for_queue() {
        let mut b = mk_batcher(1, 16, 64);
        b.submit(req(0, 2));
        b.submit(req(1, 2));
        let mut now = 0.0;
        while !b.idle() {
            b.admit(now);
            b.step(now).unwrap();
            now += 1.0;
        }
        assert_eq!(b.completed.len(), 2);
        // FCFS: request 0 finished first
        assert_eq!(b.completed[0].id, 0);
        assert_eq!(b.completed[1].id, 1);
        assert!(b.completed[1].admitted_s > b.completed[0].admitted_s - 1e-9);
    }

    #[test]
    fn batch_size_invariant_property() {
        let mut b = mk_batcher(3, 64, 64);
        let mut next_id = 0u64;
        let mut now = 0.0;
        crate::prop_assert!("batch-bounds", 150, |g| {
            match g.usize_in(0, 2) {
                0 => {
                    b.submit(req(next_id, g.usize_in(1, 4)));
                    next_id += 1;
                }
                _ => {
                    b.admit(now);
                    b.step(now).map_err(|e| e.to_string())?;
                    now += 0.1;
                }
            }
            if b.active() > 3 {
                return Err(format!("batch overflow: {}", b.active()));
            }
            // conservation: submitted == queued + active + done + rejected
            let total = b.queued() + b.active() + b.completed.len()
                + b.rejected.len();
            if total != next_id as usize {
                return Err(format!("lost requests: {total} != {next_id}"));
            }
            Ok(())
        });
    }

    #[test]
    fn swap_tier_resume_matches_reprefill_path() {
        // same oversubscribed workload with the swap tier on and off:
        // spilled-and-restored sequences must produce exactly the
        // tokens the re-prefill path produces
        let run = |swap: bool| {
            let mut b = mk_batcher_policy(
                4, 32, 3, SchedulerPolicy::Preempt, 8);
            b.cfg.swap = swap;
            for i in 0..6 {
                assert!(b.submit(req(i, 25)));
            }
            drain(&mut b);
            assert_eq!(b.completed.len(), 6);
            assert_eq!(b.engine().cache_stats().tokens, 0);
            let mut toks: Vec<(u64, Vec<u32>)> = b
                .completed
                .iter()
                .map(|c| (c.id, c.generated.clone()))
                .collect();
            toks.sort();
            (toks, b.swap_outs, b.swap_ins)
        };
        let (with_swap, outs, ins) = run(true);
        let (without, outs_off, _) = run(false);
        assert!(outs > 0, "oversubscription must exercise the swap tier");
        assert_eq!(ins, outs, "every spilled sequence must be restored");
        assert_eq!(outs_off, 0, "swap off must never spill");
        assert_eq!(with_swap, without,
                   "swap-tier restore must match re-prefill tokens");
    }

    #[test]
    fn prefix_cache_hit_skips_shared_prefill() {
        let engine = Engine::build(&EngineConfig {
            model: ModelConfig::test_tiny(),
            backend: AttentionBackend::Fp16Exact,
            value_backend:
                crate::coordinator::engine::ValueBackend::Fp32,
            seed: 3,
            cache_blocks: 64,
            calib_tokens: 64,
            decode_threads: 2,
            prefill_chunk: 0,
            pipeline: true,
            prefix_cache: true,
            policy: crate::coordinator::CompressionPolicy::Uniform,
            faults: Default::default(),
        })
        .unwrap();
        let mut b = Batcher::new(
            engine,
            BatcherConfig {
                max_batch: 2,
                max_queue: 8,
                policy: SchedulerPolicy::Fcfs,
                ..BatcherConfig::default()
            },
        );
        // 69 tokens: two full blocks worth of shareable prefix
        let prompt = vec![7u32; 2 * BLOCK_TOKENS + 5];
        b.submit(Request {
            id: 1,
            prompt: prompt.clone(),
            max_new_tokens: 8,
            arrival_s: 0.0,
            timeout_ms: None,
        });
        b.admit(0.0);
        b.step(0.0).unwrap(); // monolithic prefill registers the prefix
        b.submit(Request {
            id: 2,
            prompt,
            max_new_tokens: 8,
            arrival_s: 0.1,
            timeout_ms: None,
        });
        b.admit(0.1);
        assert_eq!(b.prefix_hits, 1,
                   "second admission must attach the shared prefix");
        assert!(b.engine().cache_stats().shared_blocks >= 2);
        drain(&mut b);
        assert_eq!(b.completed.len(), 2);
        assert_eq!(b.completed[0].generated, b.completed[1].generated,
                   "shared-prefix sequence must decode identically");
        let s = b.engine().cache_stats();
        assert_eq!(s.blocks_allocated, 0, "no refcount leaks");
        assert_eq!(s.shared_blocks, 0);
    }

    #[test]
    fn telemetry_registry_covers_scheduler_cache_swap_and_phases() {
        // oversubscribed preemptive run with the swap tier on: every
        // scheduler/cache/swap counter family must light up
        let mut b = mk_batcher_policy(
            4, 32, 3, SchedulerPolicy::Preempt, 8);
        for i in 0..6 {
            assert!(b.submit(req(i, 25)));
        }
        drain(&mut b);
        let m = b.engine().metrics();
        assert_eq!(m.counter(Ctr::RequestsSubmitted), 6);
        assert_eq!(m.counter(Ctr::RequestsCompleted), 6);
        assert!(m.counter(Ctr::Preemptions) > 0);
        assert!(m.counter(Ctr::SwapOuts) > 0);
        assert_eq!(m.counter(Ctr::SwapOuts), m.counter(Ctr::SwapIns));
        assert!(m.counter(Ctr::SwapBytesOut) > 0);
        assert_eq!(
            m.counter(Ctr::SwapBytesOut),
            m.counter(Ctr::SwapBytesIn),
            "restores must read back exactly what spills wrote"
        );
        assert_eq!(m.counter(Ctr::DecodeTokens), 6 * 25);
        assert!(m.counter(Ctr::PrefillTokens) > 0);
        assert!(m.counter(Ctr::ScanBytes) > 0);
        assert!(m.counter(Ctr::Ticks) > 0);
        assert!(
            m.counter(Ctr::PhaseScanNs) > 0,
            "phase timer deltas must reach the registry"
        );
        assert_eq!(m.gauge(Gauge::BlocksTotal), 3);
        assert_eq!(m.gauge(Gauge::ActiveSeqs), 0, "drained run");
        assert!(m.gauge(Gauge::ScratchLeases) > 0);
        assert_eq!(m.hist(Hist::TickS).count(), m.counter(Ctr::Ticks));
        assert_eq!(m.hist(Hist::TtftS).count(), 6);
        assert_eq!(m.hist(Hist::E2eS).count(), 6);
        assert!(m.hist(Hist::ItlS).count() > 0);
        assert!(m.hist(Hist::BatchOccupancy).count() > 0);
    }

    #[test]
    fn tracer_attached_run_matches_untraced_tokens() {
        // bit-parity with telemetry enabled: attaching the event ring
        // must not perturb scheduling or generation
        let run = |traced: bool| {
            let mut b = mk_batcher_policy(
                4, 32, 3, SchedulerPolicy::Preempt, 8);
            if traced {
                b.set_tracer(Arc::new(TraceRing::new(4096)));
            }
            for i in 0..6 {
                assert!(b.submit(req(i, 25)));
            }
            drain(&mut b);
            let mut toks: Vec<(u64, Vec<u32>)> = b
                .completed
                .iter()
                .map(|c| (c.id, c.generated.clone()))
                .collect();
            toks.sort();
            let events =
                b.tracer().map(|t| t.events()).unwrap_or_default();
            (toks, events)
        };
        let (traced, events) = run(true);
        let (plain, _) = run(false);
        assert_eq!(traced, plain, "tracing must not change tokens");
        for kind in [
            TraceKind::Queued,
            TraceKind::Admitted,
            TraceKind::PrefillChunk,
            TraceKind::DecodeTick,
            TraceKind::SwapIn,
            TraceKind::Finish,
        ] {
            assert!(
                events.iter().any(|e| e.kind == kind),
                "missing {kind:?} events"
            );
        }
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, TraceKind::SwapOut | TraceKind::Preempt)));
    }

    #[test]
    fn preemptive_conservation_property() {
        // the same conservation law must survive preemption churn: a
        // preempted request lives in the queue, never in limbo
        let mut b = mk_batcher_policy(
            3, 64, 3, SchedulerPolicy::Preempt, 4);
        let mut next_id = 0u64;
        let mut now = 0.0;
        crate::prop_assert!("preempt-conservation", 150, |g| {
            match g.usize_in(0, 2) {
                0 => {
                    b.submit(req(next_id, g.usize_in(1, 6)));
                    next_id += 1;
                }
                _ => {
                    b.admit(now);
                    b.step(now).map_err(|e| e.to_string())?;
                    now += 0.1;
                }
            }
            let s = b.engine().cache_stats();
            if s.blocks_allocated > s.blocks_total {
                return Err("block budget exceeded".into());
            }
            let total = b.queued() + b.active() + b.completed.len()
                + b.rejected.len();
            if total != next_id as usize {
                return Err(format!("lost requests: {total} != {next_id}"));
            }
            Ok(())
        });
    }

    #[test]
    fn queued_request_past_deadline_is_expired_not_admitted() {
        let mut b = mk_batcher(1, 16, 64);
        // id 0 occupies the single batch slot; id 1 waits in queue
        b.submit(req(0, 50));
        let mut slow = req(1, 2);
        slow.timeout_ms = Some(100);
        b.submit(slow);
        b.admit(0.0);
        assert_eq!(b.active(), 1);
        assert_eq!(b.queued(), 1);
        // the queued request's deadline (arrival 0.0 + 100ms) passes
        b.admit(0.2);
        assert_eq!(b.expired, vec![1]);
        assert_eq!(b.queued(), 0);
        drain(&mut b);
        assert_eq!(b.completed.len(), 1);
        assert_eq!(b.completed[0].id, 0);
        assert_eq!(b.engine().cache_stats().tokens, 0);
        assert_eq!(
            b.engine().metrics().counter(Ctr::DeadlineExpired),
            1
        );
    }

    #[test]
    fn active_request_past_deadline_frees_its_blocks() {
        let mut b = mk_batcher(2, 16, 64);
        let mut r = req(0, 1000);
        r.timeout_ms = Some(50);
        b.submit(r);
        b.submit(req(1, 3));
        b.admit(0.0);
        b.step(0.0).unwrap();
        assert_eq!(b.active(), 2);
        // mid-generation expiry: blocks reclaimed, peer unaffected
        b.step(0.1).unwrap();
        assert_eq!(b.expired, vec![0]);
        assert_eq!(b.active(), 1);
        drain(&mut b);
        assert_eq!(b.completed.len(), 1);
        assert_eq!(b.completed[0].id, 1);
        assert_eq!(b.engine().cache_stats().tokens, 0);
        assert_eq!(b.engine().cache_stats().blocks_allocated, 0);
    }

    #[test]
    fn server_default_deadline_applies_when_request_has_none() {
        let mut b = mk_batcher(1, 16, 64);
        b.cfg.deadline_ms = Some(100);
        b.submit(req(0, 1000));
        b.admit(0.0);
        b.step(0.0).unwrap();
        b.step(0.2).unwrap();
        assert_eq!(b.expired, vec![0]);
        assert!(b.idle());
        assert_eq!(b.engine().cache_stats().blocks_allocated, 0);
    }

    #[test]
    fn per_request_timeout_overrides_server_default() {
        let mut b = mk_batcher(2, 16, 64);
        b.cfg.deadline_ms = Some(50);
        let mut patient = req(0, 4);
        patient.timeout_ms = Some(60_000);
        b.submit(patient);
        b.admit(0.0);
        let mut now = 0.0;
        while !b.idle() {
            b.admit(now);
            b.step(now).unwrap();
            now += 0.1; // every tick is past the 50ms default
        }
        assert_eq!(b.completed.len(), 1, "own timeout must win");
        assert!(b.expired.is_empty());
    }

    #[test]
    fn injected_tick_error_surfaces_and_recovers() {
        let mut b = mk_batcher(2, 16, 64);
        b.cfg.faults = FaultPlan::parse("tick:err@2").unwrap();
        b.submit(req(0, 3));
        b.admit(0.0);
        b.step(0.0).unwrap(); // tick 1: clean
        let err = b.step(0.1).unwrap_err(); // tick 2: injected
        assert!(err.to_string().contains("injected fault: tick"));
        drain(&mut b); // later ticks are clean again
        assert_eq!(b.completed.len(), 1);
        assert_eq!(
            b.engine().metrics().counter(Ctr::FaultsInjected),
            1
        );
    }

    #[test]
    fn tick_panic_quarantines_active_and_serving_continues() {
        let mut b = mk_batcher(2, 16, 64);
        b.cfg.faults = FaultPlan::parse("tick:panic@2").unwrap();
        b.submit(req(0, 3));
        b.submit(req(1, 3));
        b.admit(0.0);
        b.step(0.0).unwrap();
        let panicked = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| b.step(0.1)),
        )
        .is_err();
        assert!(panicked, "tick 2 must panic by plan");
        let ids = b.quarantine_active(0.1);
        assert_eq!(ids, vec![0, 1]);
        assert_eq!(b.quarantined, vec![0, 1]);
        assert_eq!(b.engine().cache_stats().blocks_allocated, 0);
        assert_eq!(
            b.engine().metrics().counter(Ctr::PanicsQuarantined),
            1
        );
        // the batcher keeps serving fresh work after the quarantine
        b.submit(req(7, 2));
        drain(&mut b);
        assert_eq!(b.completed.len(), 1);
        assert_eq!(b.completed[0].id, 7);
        assert_eq!(b.engine().cache_stats().tokens, 0);
    }

    #[test]
    fn disabled_fault_plan_changes_nothing() {
        // bit-parity: default (disabled) plan vs no plan at all
        let run = |spec: Option<&str>| {
            let mut b = mk_batcher_policy(
                4, 32, 3, SchedulerPolicy::Preempt, 8);
            if let Some(s) = spec {
                b.cfg.faults = FaultPlan::parse(s).unwrap();
            }
            for i in 0..6 {
                assert!(b.submit(req(i, 25)));
            }
            drain(&mut b);
            let mut toks: Vec<(u64, Vec<u32>)> = b
                .completed
                .iter()
                .map(|c| (c.id, c.generated.clone()))
                .collect();
            toks.sort();
            toks
        };
        assert_eq!(run(None), run(Some("")));
        assert_eq!(run(None), run(Some("seed:42")));
    }
}
