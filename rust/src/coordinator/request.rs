//! Request lifecycle types.

use crate::kvcache::SeqId;

/// A serving request as submitted to the router.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: SeqId,
    /// prompt token ids (tokenized upstream)
    pub prompt: Vec<u32>,
    /// number of tokens to generate
    pub max_new_tokens: usize,
    /// arrival time offset (seconds from trace start)
    pub arrival_s: f64,
    /// per-request deadline: the request must finish within this many
    /// milliseconds of arrival or it is expired (blocks reclaimed, a
    /// `deadline` error answered). `None` defers to the scheduler's
    /// configured default, which may also be unlimited
    pub timeout_ms: Option<u64>,
}

impl Request {
    /// The absolute deadline in trace time, given the scheduler's
    /// default timeout (`None` = no deadline).
    pub fn deadline_s(&self, default_ms: Option<u64>) -> Option<f64> {
        self.timeout_ms
            .or(default_ms)
            .map(|ms| self.arrival_s + ms as f64 / 1e3)
    }
}

/// Where a request currently is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Prefilling,
    Decoding,
    Finished,
    /// rejected by admission control (cache exhausted and queue full)
    Rejected,
}

/// Completed-request record with the standard serving latency breakdown.
#[derive(Clone, Debug)]
pub struct CompletedRequest {
    pub id: SeqId,
    pub prompt_tokens: usize,
    pub generated: Vec<u32>,
    pub arrival_s: f64,
    /// admission (start of prefill)
    pub admitted_s: f64,
    /// first generated token (TTFT measured from arrival)
    pub first_token_s: f64,
    pub finished_s: f64,
}

impl CompletedRequest {
    /// Time-to-first-token, seconds.
    pub fn ttft(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// End-to-end latency, seconds.
    pub fn e2e(&self) -> f64 {
        self.finished_s - self.arrival_s
    }

    /// Mean inter-token latency over the decode phase, seconds.
    pub fn itl(&self) -> f64 {
        let n = self.generated.len();
        if n <= 1 {
            return 0.0;
        }
        (self.finished_s - self.first_token_s) / (n - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn done() -> CompletedRequest {
        CompletedRequest {
            id: 1,
            prompt_tokens: 10,
            generated: vec![1, 2, 3, 4, 5],
            arrival_s: 1.0,
            admitted_s: 1.5,
            first_token_s: 2.0,
            finished_s: 4.0,
        }
    }

    #[test]
    fn latency_breakdown() {
        let c = done();
        assert!((c.ttft() - 1.0).abs() < 1e-12);
        assert!((c.e2e() - 3.0).abs() < 1e-12);
        assert!((c.itl() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn itl_degenerate_cases() {
        let mut c = done();
        c.generated = vec![7];
        assert_eq!(c.itl(), 0.0);
        c.generated = vec![];
        assert_eq!(c.itl(), 0.0);
    }
}
