//! The serving coordinator — Layer 3 of the stack.
//!
//! A vLLM-router-style serving runtime scaled to this testbed:
//!
//! * [`request`] — request lifecycle types and per-request latency records
//! * [`engine`] — the inference engine: pure-rust GPT-2 forward with a
//!   pluggable attention backend (FP16 exact, LOOKAT ADC, scalar-quant
//!   baselines, or the PJRT-executed AOT artifacts) over the paged
//!   [`crate::kvcache`]
//! * [`batcher`] — continuous batching with cache-aware admission
//!   control, chunked prefill and preemptive scheduling
//! * [`policy`] — adaptive compression policies: per-(layer, head)
//!   subspace budgets from calibration error, and L2-norm token
//!   pruning, resolved once at engine build time
//! * [`router`] — the front door: trace-driven serving loop, backpressure,
//!   latency/throughput accounting
//!
//! LOOKAT drops in *here*: the engine's cache stores PQ codes instead of
//! keys and decode-attention runs over lookup tables — no other component
//! changes, which is the paper's "no architecture changes" claim at the
//! systems level.

pub mod batcher;
pub mod engine;
pub mod policy;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatcherConfig, SchedulerPolicy, SwapCostModel};
pub use engine::{
    AttentionBackend, Engine, EngineConfig, EngineError, TickEntry,
    TickOutcome, ValueBackend,
};
pub use policy::{CompressionPolicy, HeadPolicy, PolicySummary};
pub use request::{CompletedRequest, Request, RequestState};
pub use router::{Router, RouterConfig, ServingReport};
pub use server::{Server, ServerConfig};
