//! The inference engine: transformer decode over the paged KV-cache with
//! a pluggable batched attention kernel.
//!
//! Backends (each an [`AttentionKernel`] implementation):
//! * `Fp16Exact` — raw keys in cache, exact attention (the baseline)
//! * `Lookat{m}` — keys stored as PQ codes, block-resident ADC attention
//!   (the paper; zero per-step key-code copies)
//! * `ScalarQuant{bits}` — raw keys, INT4/INT8 round-trip attention
//! * `PjrtFp16` / `PjrtLookat{m}` — attention steps executed through the
//!   AOT artifacts on the PJRT CPU client (proves the 3-layer contract
//!   end-to-end in the serving loop)
//!
//! Decode is batched: [`Engine::decode_batch`] advances every sequence
//! of the batcher's drained tick by one token, building one
//! [`DecodePlan`] per layer — all (seq, head) work items at once — and
//! fanning the independent items (plus the per-sequence QKV/MLP math)
//! out on `util::threadpool`. Per-sequence results are bit-identical to
//! a batch of one: items never interact.
//!
//! LOOKAT codebooks are trained once at engine build from a calibration
//! corpus (paper §3.4); the serving hot path never touches python.

use anyhow::{bail, Context};

use crate::attention::kernel::{
    Fp16Kernel, LookatKernel, PjrtFp16Kernel, PjrtLookatKernel,
    ScalarQuantKernel,
};
use crate::attention::{AttentionKernel, DecodePlan, WorkItem};
use crate::kvcache::{
    CacheError, KeyStorage, KvCache, SeqId, ValueStorage,
};
use crate::model::{Gpt2, ModelConfig, PrefillOutput, Weights};
use crate::pq::{PqCodec, TrainOpts};
use crate::runtime::Runtime;
use crate::util::threadpool::{parallel_map, parallel_try_map};
use crate::workload::{Corpus, Genre};

/// Which attention implementation the engine uses at decode time.
#[derive(Clone, Debug, PartialEq)]
pub enum AttentionBackend {
    /// exact attention over FP16-stored keys
    Fp16Exact,
    /// LOOKAT: ADC over PQ codes with `m` subspaces, K centroids
    Lookat { m: usize, k: usize },
    /// INT4/INT8 dequantize-then-attend baseline
    ScalarQuant { bits: u8 },
    /// FP16 attention executed via the AOT artifact on PJRT
    PjrtFp16,
    /// LOOKAT attention executed via the AOT artifact on PJRT
    PjrtLookat { m: usize },
}

impl AttentionBackend {
    pub fn name(&self) -> String {
        match self {
            AttentionBackend::Fp16Exact => "fp16".into(),
            AttentionBackend::Lookat { m, .. } => format!("lookat-{m}"),
            AttentionBackend::ScalarQuant { bits } => format!("int{bits}"),
            AttentionBackend::PjrtFp16 => "pjrt-fp16".into(),
            AttentionBackend::PjrtLookat { m } => format!("pjrt-lookat-{m}"),
        }
    }

    fn needs_pq(&self) -> Option<(usize, usize)> {
        match self {
            AttentionBackend::Lookat { m, k } => Some((*m, *k)),
            AttentionBackend::PjrtLookat { m } => Some((*m, 256)),
            _ => None,
        }
    }
}

/// How the engine's caches store values — the value-side axis of the
/// backend matrix, orthogonal to [`AttentionBackend`] (which picks the
/// key representation and scoring path).
#[derive(Clone, Debug, PartialEq)]
pub enum ValueBackend {
    /// raw values (the default; "FP16" under the paper's byte model)
    Fp32,
    /// PQ-coded values with `m` subspaces, K centroids: the fused
    /// blocked weighted decode serves attention with zero per-step
    /// value dequantization copies
    Pq { m: usize, k: usize },
}

impl ValueBackend {
    pub fn name(&self) -> String {
        match self {
            ValueBackend::Fp32 => "fp32".into(),
            ValueBackend::Pq { m, .. } => format!("vpq-{m}"),
        }
    }

    fn needs_pq(&self) -> Option<(usize, usize)> {
        match self {
            ValueBackend::Fp32 => None,
            ValueBackend::Pq { m, k } => Some((*m, *k)),
        }
    }
}

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: ModelConfig,
    pub backend: AttentionBackend,
    /// value-side storage (orthogonal to `backend`; PJRT backends
    /// require `Fp32`)
    pub value_backend: ValueBackend,
    pub seed: u64,
    /// KV-cache budget in blocks per layer
    pub cache_blocks: usize,
    /// tokens of calibration text for PQ codebook training
    pub calib_tokens: usize,
    /// worker threads for the batched decode fan-out (0 = one per
    /// available core)
    pub decode_threads: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            model: ModelConfig::gpt2_layer0(),
            backend: AttentionBackend::Fp16Exact,
            value_backend: ValueBackend::Fp32,
            seed: 0xE47,
            cache_blocks: 256,
            calib_tokens: 384,
            decode_threads: 0,
        }
    }
}

struct SeqMeta {
    pos: usize,
    last_hidden: Vec<f32>,
}

/// The engine: model + per-layer caches + batched attention kernel.
pub struct Engine {
    pub model: Gpt2,
    pub backend: AttentionBackend,
    pub value_backend: ValueBackend,
    caches: Vec<KvCache>,
    seqs: std::collections::HashMap<SeqId, SeqMeta>,
    kernel: Box<dyn AttentionKernel>,
    threads: usize,
}

impl Engine {
    /// Build an engine: init weights, train codebooks if the backend
    /// needs them, open the PJRT runtime if requested.
    pub fn build(cfg: &EngineConfig) -> anyhow::Result<Engine> {
        let weights = Weights::random(&cfg.model, cfg.seed);
        Self::with_weights(cfg, weights)
    }

    /// Build with explicit weights (examples load from disk).
    pub fn with_weights(cfg: &EngineConfig, weights: Weights)
        -> anyhow::Result<Engine>
    {
        let model = Gpt2::new(weights);
        let (h, d_k) = (cfg.model.n_head, cfg.model.d_head);

        let key_pq = cfg.backend.needs_pq();
        let value_pq = cfg.value_backend.needs_pq();
        if value_pq.is_some()
            && matches!(
                cfg.backend,
                AttentionBackend::PjrtFp16
                    | AttentionBackend::PjrtLookat { .. }
            )
        {
            bail!(
                "PQ value storage is not supported on PJRT backends \
                 (the artifacts have no value-code contract); use \
                 --value-backend fp32"
            );
        }

        // PQ backends: train per-layer, per-head codebooks on a
        // calibration corpus exactly like the paper's §3.4 (prefill
        // once, take each head's keys — and values, for the §5.2
        // value-side extension — from every layer).
        let calib: Option<PrefillOutput> =
            if key_pq.is_some() || value_pq.is_some() {
                Some(Self::calibration_prefill(&model, cfg)?)
            } else {
                None
            };
        let train = |data: &[f32], m: usize, k: usize, salt: u64| {
            PqCodec::train(
                data,
                d_k,
                m,
                k,
                &TrainOpts { seed: cfg.seed ^ salt, ..Default::default() },
            )
        };

        let mut caches = Vec::with_capacity(cfg.model.n_layer);
        for layer in 0..cfg.model.n_layer {
            let storage = if let Some((m, k)) = key_pq {
                let out = calib.as_ref().unwrap();
                let codecs: Vec<PqCodec> = (0..h)
                    .map(|head| {
                        train(&out.head_keys(layer, head, d_k), m, k, 0x90)
                    })
                    .collect();
                KeyStorage::pq(codecs).map_err(|e| anyhow::anyhow!("{e}"))?
            } else {
                KeyStorage::Fp16
            };
            let value_storage = if let Some((m, k)) = value_pq {
                let out = calib.as_ref().unwrap();
                let codecs: Vec<PqCodec> = (0..h)
                    .map(|head| {
                        train(
                            &out.head_values(layer, head, d_k), m, k, 0x91)
                    })
                    .collect();
                ValueStorage::pq(codecs)
                    .map_err(|e| anyhow::anyhow!("{e}"))?
            } else {
                ValueStorage::Fp32
            };
            caches.push(KvCache::new(
                h, d_k, cfg.cache_blocks, storage, value_storage));
        }

        let kernel = Self::build_kernel(cfg)?;
        let threads = if cfg.decode_threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            cfg.decode_threads
        };

        Ok(Engine {
            model,
            backend: cfg.backend.clone(),
            value_backend: cfg.value_backend.clone(),
            caches,
            seqs: std::collections::HashMap::new(),
            kernel,
            threads,
        })
    }

    /// Combined backend label for reports: the key backend's name, plus
    /// a `+vpq-<m>` suffix when values are PQ-coded (fp32 values keep
    /// the bare name, so perf trajectories stay comparable across PRs).
    pub fn label(&self) -> String {
        match &self.value_backend {
            ValueBackend::Fp32 => self.backend.name(),
            vb => format!("{}+{}", self.backend.name(), vb.name()),
        }
    }

    /// Instantiate the backend's attention kernel. PJRT backends open
    /// the runtime here and move it into the kernel — the engine itself
    /// no longer talks to the artifact executor.
    fn build_kernel(cfg: &EngineConfig)
        -> anyhow::Result<Box<dyn AttentionKernel>>
    {
        Ok(match cfg.backend {
            AttentionBackend::Fp16Exact => Box::new(Fp16Kernel),
            AttentionBackend::Lookat { .. } => Box::new(LookatKernel),
            AttentionBackend::ScalarQuant { bits } => {
                Box::new(ScalarQuantKernel { bits })
            }
            AttentionBackend::PjrtFp16
            | AttentionBackend::PjrtLookat { .. } => {
                let runtime = Runtime::open_default().context(
                    "PJRT backend needs artifacts (run `make artifacts`)",
                )?;
                let kind = if matches!(cfg.backend,
                                       AttentionBackend::PjrtFp16) {
                    "attn_fp16"
                } else {
                    "attn_lookat"
                };
                let mut lens: Vec<usize> = runtime
                    .manifest
                    .by_kind(kind)
                    .iter()
                    .filter(|a| match cfg.backend {
                        AttentionBackend::PjrtLookat { m } => {
                            a.meta_usize("m") == Some(m)
                        }
                        _ => true,
                    })
                    .filter_map(|a| a.meta_usize("L"))
                    .collect();
                lens.sort_unstable();
                if lens.is_empty() {
                    bail!("no artifacts for backend {:?}", cfg.backend);
                }
                match cfg.backend {
                    AttentionBackend::PjrtFp16 => {
                        Box::new(PjrtFp16Kernel::new(runtime, lens))
                    }
                    AttentionBackend::PjrtLookat { m } => {
                        Box::new(PjrtLookatKernel::new(runtime, lens, m))
                    }
                    _ => unreachable!(),
                }
            }
        })
    }

    /// Calibration prefill over a mixed-genre corpus: one forward pass
    /// whose per-layer caches supply both the key and the value
    /// codebook training sets.
    fn calibration_prefill(model: &Gpt2, cfg: &EngineConfig)
        -> anyhow::Result<PrefillOutput>
    {
        let tok = crate::model::ByteTokenizer::new();
        let mut text = String::new();
        for (i, g) in Genre::ALL.iter().enumerate() {
            text.push_str(
                &Corpus::new(*g, cfg.seed ^ i as u64)
                    .generate(cfg.calib_tokens * 2),
            );
        }
        let ids = tok.encode_clamped(
            &text,
            cfg.calib_tokens.min(cfg.model.max_pos),
        );
        Ok(model.prefill(&ids))
    }

    /// Sequences currently registered.
    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Cache stats of layer 0 (all layers are symmetric).
    pub fn cache_stats(&self) -> crate::kvcache::CacheStats {
        self.caches[0].stats()
    }

    /// Whether the cache can admit a sequence of `prompt + gen` tokens.
    pub fn can_admit(&self, total_tokens: usize) -> bool {
        self.free_blocks()
            >= total_tokens.div_ceil(crate::kvcache::BLOCK_TOKENS)
    }

    /// Free cache blocks available right now (min across layers) — the
    /// batcher's cumulative admission budget.
    pub fn free_blocks(&self) -> usize {
        self.caches
            .iter()
            .map(|c| {
                let s = c.stats();
                s.blocks_total - s.blocks_allocated
            })
            .min()
            .unwrap_or(0)
    }

    /// Admit a sequence: prefill its prompt, fill every layer's cache,
    /// return nothing (call [`Engine::decode_batch`] for tokens).
    pub fn start_seq(&mut self, id: SeqId, prompt: &[u32])
        -> Result<(), CacheError>
    {
        assert!(!prompt.is_empty(), "empty prompt");
        let out = self.model.prefill(prompt);
        self.install_prefill(id, prompt.len(), out)
    }

    /// Admit several sequences in one tick: the prompt prefills (pure
    /// model math, the TTFT-dominant cost) run concurrently on the
    /// decode thread budget; the cache fills stay serial. Returns one
    /// result per request, in order — failed admissions leave no
    /// residue and the rest still land.
    pub fn start_seq_batch(&mut self, reqs: &[(SeqId, &[u32])])
        -> Vec<Result<(), CacheError>>
    {
        for &(_, prompt) in reqs {
            assert!(!prompt.is_empty(), "empty prompt");
        }
        let model = &self.model;
        let prefills: Vec<PrefillOutput> =
            match parallel_try_map(reqs.len(), self.threads, |i| {
                Ok::<_, std::convert::Infallible>(model.prefill(reqs[i].1))
            }) {
                Ok(p) => p,
                Err(e) => match e {},
            };
        reqs.iter()
            .zip(prefills)
            .map(|(&(id, prompt), out)| {
                self.install_prefill(id, prompt.len(), out)
            })
            .collect()
    }

    /// Register a prefilled sequence: fill every layer's cache and store
    /// its decode state. Rolls back cleanly on cache exhaustion.
    fn install_prefill(
        &mut self,
        id: SeqId,
        prompt_len: usize,
        out: PrefillOutput,
    ) -> Result<(), CacheError> {
        for c in self.caches.iter_mut() {
            c.create_seq(id)?;
        }
        for layer in 0..self.model.n_layer() {
            let (k_cache, v_cache) = &out.caches[layer];
            for t in 0..prompt_len {
                // rows are (d_model) = heads contiguous — exactly the
                // (H × d_k) layout append expects
                let res = self.caches[layer].append(
                    id, k_cache.row(t), v_cache.row(t));
                if let Err(e) = res {
                    // roll back so the caller can retry later
                    for c in self.caches.iter_mut() {
                        let _ = c.free_seq(id);
                    }
                    return Err(e);
                }
            }
        }
        self.seqs.insert(
            id,
            SeqMeta { pos: prompt_len, last_hidden: out.last_hidden },
        );
        Ok(())
    }

    /// Generate one token for a sequence (greedy): a batch of one.
    pub fn decode_one(&mut self, id: SeqId) -> anyhow::Result<u32> {
        Ok(self.decode_batch(&[id])?[0])
    }

    /// One decode tick for a batch of sequences: every sequence gets one
    /// greedy token appended to its cache.
    ///
    /// Per layer, all (seq, head) attention items form one [`DecodePlan`]
    /// that the backend kernel executes; QKV projections, the greedy
    /// logits pass and the block MLPs fan out per sequence on the same
    /// thread budget. Sequences are independent, so the result for each
    /// is bit-identical to decoding it in a batch of one.
    pub fn decode_batch(&mut self, ids: &[SeqId])
        -> anyhow::Result<Vec<u32>>
    {
        if ids.is_empty() {
            return Ok(Vec::new());
        }
        let (h, d_k) = (self.model.n_head(), self.model.d_head());
        for &id in ids {
            let meta = self
                .seqs
                .get(&id)
                .with_context(|| format!("unknown seq {id}"))?;
            if meta.pos >= self.model.weights.config.max_pos {
                bail!("sequence {id} exceeded max position");
            }
        }
        // pre-flight the tick's block demand so a mid-batch OutOfBlocks
        // can't leave some sequences' caches ahead of their SeqMeta
        // (admission over-commits by design: it reserves against current
        // allocation, not outstanding generation)
        for (layer, cache) in self.caches.iter().enumerate() {
            let mut need = 0usize;
            for &id in ids {
                let len =
                    cache.seq_len(id).map_err(|e| anyhow::anyhow!("{e}"))?;
                if len % crate::kvcache::BLOCK_TOKENS == 0 {
                    need += 1;
                }
            }
            let s = cache.stats();
            if need > s.blocks_total - s.blocks_allocated {
                bail!(
                    "out of cache blocks for decode tick \
                     (layer {layer}: need {need} new blocks)"
                );
            }
        }

        // greedy next-token + embedding per sequence
        let model = &self.model;
        let seqs = &self.seqs;
        let picked: Vec<(u32, Vec<f32>)> =
            parallel_map(ids.len(), self.threads, |i| {
                let meta = &seqs[&ids[i]];
                let token = model.greedy_next(&meta.last_hidden);
                (token, model.embed(token, meta.pos))
            });
        let (tokens, mut xs): (Vec<u32>, Vec<Vec<f32>>) =
            picked.into_iter().unzip();

        for layer in 0..self.model.n_layer() {
            // QKV projections (independent per sequence)
            let model = &self.model;
            let xs_ref = &xs;
            let qkvs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> =
                parallel_map(ids.len(), self.threads, |i| {
                    model.qkv(layer, &xs_ref[i])
                });
            // cache appends mutate the paged storage — serial
            for (i, &id) in ids.iter().enumerate() {
                self.caches[layer]
                    .append(id, &qkvs[i].1, &qkvs[i].2)
                    .map_err(|e| anyhow::anyhow!("cache append: {e}"))?;
            }
            // one DecodePlan for the tick: all (seq, head) items,
            // seq-major with ascending heads (the kernel contract)
            let mut items = Vec::with_capacity(ids.len() * h);
            for (i, &id) in ids.iter().enumerate() {
                let q = &qkvs[i].0;
                for head in 0..h {
                    items.push(WorkItem {
                        seq: id,
                        head,
                        q: &q[head * d_k..(head + 1) * d_k],
                    });
                }
            }
            let plan = DecodePlan {
                cache: &self.caches[layer],
                d_k,
                threads: self.threads,
                items,
            };
            let outs = self.kernel.decode_batch(&plan)?;
            if outs.len() != ids.len() * h {
                bail!(
                    "kernel returned {} outputs for {} work items",
                    outs.len(),
                    ids.len() * h
                );
            }
            // concat heads + residual/MLP tail (independent per sequence)
            let model = &self.model;
            let xs_ref = &xs;
            let outs_ref = &outs;
            let next: Vec<Vec<f32>> =
                parallel_map(ids.len(), self.threads, |i| {
                    let mut attn = vec![0.0f32; h * d_k];
                    for head in 0..h {
                        attn[head * d_k..(head + 1) * d_k]
                            .copy_from_slice(&outs_ref[i * h + head].out);
                    }
                    model.finish_block(layer, &xs_ref[i], &attn)
                });
            xs = next;
        }

        for (i, &id) in ids.iter().enumerate() {
            let meta = self.seqs.get_mut(&id).unwrap();
            meta.pos += 1;
            meta.last_hidden = std::mem::take(&mut xs[i]);
        }
        Ok(tokens)
    }

    /// Release a finished sequence's cache.
    pub fn release(&mut self, id: SeqId) -> anyhow::Result<()> {
        self.seqs.remove(&id).with_context(|| format!("unknown seq {id}"))?;
        for c in self.caches.iter_mut() {
            c.free_seq(id).map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ByteTokenizer;

    fn tiny_cfg(backend: AttentionBackend) -> EngineConfig {
        EngineConfig {
            model: ModelConfig::test_tiny(),
            backend,
            value_backend: ValueBackend::Fp32,
            seed: 1,
            cache_blocks: 32,
            calib_tokens: 96,
            decode_threads: 2,
        }
    }

    #[test]
    fn fp16_engine_generates_deterministically() {
        let mut e = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        let ids = ByteTokenizer::new().encode("hello engine");
        e.start_seq(1, &ids).unwrap();
        let toks: Vec<u32> =
            (0..8).map(|_| e.decode_one(1).unwrap()).collect();

        let mut e2 = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        e2.start_seq(9, &ids).unwrap();
        let toks2: Vec<u32> =
            (0..8).map(|_| e2.decode_one(9).unwrap()).collect();
        assert_eq!(toks, toks2);
    }

    #[test]
    fn engine_decode_matches_reference_model() {
        // Engine Fp16Exact must reproduce Gpt2::decode_step exactly
        let cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        let mut e = Engine::build(&cfg).unwrap();
        let ids = ByteTokenizer::new().encode("reference check");
        e.start_seq(1, &ids).unwrap();

        // reference: raw decode over Tensor2 caches
        let weights = Weights::random(&cfg.model, cfg.seed);
        let model = Gpt2::new(weights);
        let pre = model.prefill(&ids);
        let mut caches = pre.caches;
        let mut hidden = pre.last_hidden;
        let mut pos = ids.len();

        for _ in 0..5 {
            let tok_engine = e.decode_one(1).unwrap();
            let tok_ref = model.greedy_next(&hidden);
            assert_eq!(tok_engine, tok_ref);
            hidden = model.decode_step(tok_ref, pos, &mut caches);
            pos += 1;
        }
    }

    #[test]
    fn lookat_engine_tracks_fp16_closely() {
        let ids = ByteTokenizer::new().encode(
            "the quick brown fox jumps over the lazy dog again and again");
        let mut fp = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        fp.start_seq(1, &ids).unwrap();
        let mut lk = Engine::build(&tiny_cfg(AttentionBackend::Lookat {
            m: 4,
            k: 64,
        }))
        .unwrap();
        lk.start_seq(1, &ids).unwrap();
        // same model weights (same seed) — only attention path differs
        let t_fp: Vec<u32> = (0..6).map(|_| fp.decode_one(1).unwrap())
            .collect();
        let t_lk: Vec<u32> = (0..6).map(|_| lk.decode_one(1).unwrap())
            .collect();
        // greedy tokens may diverge eventually but the first token comes
        // from an identical prefill hidden state
        assert_eq!(t_fp[0], t_lk[0]);
        let _ = (t_fp, t_lk);
    }

    // batched-vs-serial bit-parity per backend lives in
    // tests/decode_parity.rs (it needs full engine builds per backend;
    // no point paying for them twice in CI)

    #[test]
    fn admission_and_release_cycle() {
        let mut e = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        let ids = ByteTokenizer::new().encode("abc");
        assert!(e.can_admit(ids.len() + 4));
        e.start_seq(5, &ids).unwrap();
        assert_eq!(e.active_seqs(), 1);
        let _ = e.decode_one(5).unwrap();
        assert!(e.cache_stats().tokens > 0);
        e.release(5).unwrap();
        assert_eq!(e.active_seqs(), 0);
        assert_eq!(e.cache_stats().tokens, 0);
    }

    #[test]
    fn cache_exhaustion_rolls_back_cleanly() {
        let mut cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        cfg.cache_blocks = 1; // 32 tokens only
        let mut e = Engine::build(&cfg).unwrap();
        let long: Vec<u32> = (0..100).map(|i| (i % 200) as u32).collect();
        assert!(e.start_seq(1, &long).is_err());
        // rollback: no partial residue
        assert_eq!(e.cache_stats().tokens, 0);
        assert_eq!(e.cache_stats().blocks_allocated, 0);
        // a short sequence still fits afterwards
        e.start_seq(2, &long[..16]).unwrap();
        assert_eq!(e.cache_stats().tokens, 16);
    }

    #[test]
    fn unknown_seq_errors() {
        let mut e = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        assert!(e.decode_one(42).is_err());
        assert!(e.decode_batch(&[1, 42]).is_err());
        assert!(e.release(42).is_err());
        assert!(e.decode_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn scalar_quant_backend_runs() {
        let mut e = Engine::build(&tiny_cfg(
            AttentionBackend::ScalarQuant { bits: 8 })).unwrap();
        let ids = ByteTokenizer::new().encode("int8 path");
        e.start_seq(1, &ids).unwrap();
        for _ in 0..3 {
            e.decode_one(1).unwrap();
        }
    }

    #[test]
    fn backend_names() {
        assert_eq!(AttentionBackend::Fp16Exact.name(), "fp16");
        assert_eq!(AttentionBackend::Lookat { m: 4, k: 256 }.name(),
                   "lookat-4");
        assert_eq!(AttentionBackend::ScalarQuant { bits: 4 }.name(), "int4");
        assert_eq!(AttentionBackend::PjrtLookat { m: 2 }.name(),
                   "pjrt-lookat-2");
        assert_eq!(ValueBackend::Fp32.name(), "fp32");
        assert_eq!(ValueBackend::Pq { m: 8, k: 256 }.name(), "vpq-8");
    }

    #[test]
    fn lookat_kv_engine_generates_and_compresses_values() {
        let mut cfg = tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 });
        cfg.value_backend = ValueBackend::Pq { m: 4, k: 64 };
        let mut e = Engine::build(&cfg).unwrap();
        assert_eq!(e.label(), "lookat-4+vpq-4");
        let ids = ByteTokenizer::new().encode("fully compressed serve");
        e.start_seq(1, &ids).unwrap();
        for _ in 0..4 {
            e.decode_one(1).unwrap();
        }
        let s = e.cache_stats();
        // value accounting reflects the PQ mode: m_v B/token/head
        assert_eq!(s.value_bytes, s.tokens * cfg.model.n_head * 4);
        e.release(1).unwrap();
    }

    #[test]
    fn pjrt_backend_rejects_pq_values() {
        let mut cfg = tiny_cfg(AttentionBackend::PjrtFp16);
        cfg.value_backend = ValueBackend::Pq { m: 4, k: 64 };
        let err = Engine::build(&cfg).unwrap_err().to_string();
        assert!(err.contains("PQ value storage"), "{err}");
    }

    #[test]
    fn value_backend_does_not_change_attention_weights_path() {
        // same seed, same prompts: the first decoded token (prefill
        // hidden state) must match between fp32 and pq value storage
        let ids = ByteTokenizer::new().encode("value invariance probe");
        let base = tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 });
        let mut fp = Engine::build(&base).unwrap();
        fp.start_seq(1, &ids).unwrap();
        let mut cfg = base.clone();
        cfg.value_backend = ValueBackend::Pq { m: 8, k: 64 };
        let mut vq = Engine::build(&cfg).unwrap();
        vq.start_seq(1, &ids).unwrap();
        assert_eq!(
            fp.decode_one(1).unwrap(),
            vq.decode_one(1).unwrap(),
            "first token comes from an identical prefill hidden state"
        );
    }
}
