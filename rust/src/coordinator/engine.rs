//! The inference engine: transformer decode over the paged KV-cache with
//! a pluggable attention backend.
//!
//! Backends:
//! * `Fp16Exact` — raw keys in cache, exact attention (the baseline)
//! * `Lookat{m}` — keys stored as PQ codes, ADC attention (the paper)
//! * `ScalarQuant{bits}` — raw keys, INT4/INT8 round-trip attention
//! * `PjrtFp16` / `PjrtLookat{m}` — attention steps executed through the
//!   AOT artifacts on the PJRT CPU client (proves the 3-layer contract
//!   end-to-end in the serving loop)
//!
//! LOOKAT codebooks are trained once at engine build from a calibration
//! corpus (paper §3.4); the serving hot path never touches python.

use std::sync::Arc;

use anyhow::{bail, Context};

use crate::attention;
use crate::kvcache::{CacheError, KeyStorage, KvCache, SeqId};
use crate::model::{Gpt2, ModelConfig, Weights};
use crate::pq::{LookupTable, PqCodec, TrainOpts};
use crate::runtime::{InputArg, Runtime};
use crate::workload::{Corpus, Genre};

/// Which attention implementation the engine uses at decode time.
#[derive(Clone, Debug, PartialEq)]
pub enum AttentionBackend {
    /// exact attention over FP16-stored keys
    Fp16Exact,
    /// LOOKAT: ADC over PQ codes with `m` subspaces, K centroids
    Lookat { m: usize, k: usize },
    /// INT4/INT8 dequantize-then-attend baseline
    ScalarQuant { bits: u8 },
    /// FP16 attention executed via the AOT artifact on PJRT
    PjrtFp16,
    /// LOOKAT attention executed via the AOT artifact on PJRT
    PjrtLookat { m: usize },
}

impl AttentionBackend {
    pub fn name(&self) -> String {
        match self {
            AttentionBackend::Fp16Exact => "fp16".into(),
            AttentionBackend::Lookat { m, .. } => format!("lookat-{m}"),
            AttentionBackend::ScalarQuant { bits } => format!("int{bits}"),
            AttentionBackend::PjrtFp16 => "pjrt-fp16".into(),
            AttentionBackend::PjrtLookat { m } => format!("pjrt-lookat-{m}"),
        }
    }

    fn needs_pq(&self) -> Option<(usize, usize)> {
        match self {
            AttentionBackend::Lookat { m, k } => Some((*m, *k)),
            AttentionBackend::PjrtLookat { m } => Some((*m, 256)),
            _ => None,
        }
    }
}

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: ModelConfig,
    pub backend: AttentionBackend,
    pub seed: u64,
    /// KV-cache budget in blocks per layer
    pub cache_blocks: usize,
    /// tokens of calibration text for PQ codebook training
    pub calib_tokens: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            model: ModelConfig::gpt2_layer0(),
            backend: AttentionBackend::Fp16Exact,
            seed: 0xE47,
            cache_blocks: 256,
            calib_tokens: 384,
        }
    }
}

struct SeqMeta {
    pos: usize,
    last_hidden: Vec<f32>,
}

/// The engine: model + per-layer caches + backend dispatch.
pub struct Engine {
    pub model: Gpt2,
    pub backend: AttentionBackend,
    caches: Vec<KvCache>,
    seqs: std::collections::HashMap<SeqId, SeqMeta>,
    runtime: Option<Runtime>,
    /// padded cache lengths the PJRT artifacts were lowered at
    pjrt_lens: Vec<usize>,
    // scratch buffers reused across decode steps (no hot-loop allocation)
    scratch_keys: Vec<f32>,
    scratch_vals: Vec<f32>,
    scratch_codes: Vec<u8>,
}

impl Engine {
    /// Build an engine: init weights, train codebooks if the backend
    /// needs them, open the PJRT runtime if requested.
    pub fn build(cfg: &EngineConfig) -> anyhow::Result<Engine> {
        let weights = Weights::random(&cfg.model, cfg.seed);
        Self::with_weights(cfg, weights)
    }

    /// Build with explicit weights (examples load from disk).
    pub fn with_weights(cfg: &EngineConfig, weights: Weights)
        -> anyhow::Result<Engine>
    {
        let model = Gpt2::new(weights);
        let (h, d_k) = (cfg.model.n_head, cfg.model.d_head);

        // PQ backends: train per-layer, per-head codebooks on calibration
        // keys extracted exactly like the paper's §3.4 (prefill a corpus,
        // take each head's keys).
        let storage_per_layer: Vec<KeyStorage> =
            if let Some((m, k)) = cfg.backend.needs_pq() {
                let calib = Self::calibration_keys(&model, cfg)?;
                calib
                    .into_iter()
                    .map(|per_head| {
                        let codecs: Vec<PqCodec> = per_head
                            .iter()
                            .map(|keys| {
                                PqCodec::train(
                                    keys,
                                    d_k,
                                    m,
                                    k,
                                    &TrainOpts {
                                        seed: cfg.seed ^ 0x90,
                                        ..Default::default()
                                    },
                                )
                            })
                            .collect();
                        KeyStorage::Pq { codecs: Arc::new(codecs) }
                    })
                    .collect()
            } else {
                (0..cfg.model.n_layer).map(|_| KeyStorage::Fp16).collect()
            };

        let caches = storage_per_layer
            .into_iter()
            .map(|st| KvCache::new(h, d_k, cfg.cache_blocks, st))
            .collect();

        let runtime = match cfg.backend {
            AttentionBackend::PjrtFp16 | AttentionBackend::PjrtLookat { .. } => {
                Some(Runtime::open_default().context(
                    "PJRT backend needs artifacts (run `make artifacts`)",
                )?)
            }
            _ => None,
        };
        let pjrt_lens = match &runtime {
            Some(rt) => {
                let kind = if matches!(cfg.backend,
                                       AttentionBackend::PjrtFp16) {
                    "attn_fp16"
                } else {
                    "attn_lookat"
                };
                let mut lens: Vec<usize> = rt
                    .manifest
                    .by_kind(kind)
                    .iter()
                    .filter(|a| match cfg.backend {
                        AttentionBackend::PjrtLookat { m } => {
                            a.meta_usize("m") == Some(m)
                        }
                        _ => true,
                    })
                    .filter_map(|a| a.meta_usize("L"))
                    .collect();
                lens.sort_unstable();
                if lens.is_empty() {
                    bail!("no artifacts for backend {:?}", cfg.backend);
                }
                lens
            }
            None => vec![],
        };

        Ok(Engine {
            model,
            backend: cfg.backend.clone(),
            caches,
            seqs: std::collections::HashMap::new(),
            runtime,
            pjrt_lens,
            scratch_keys: Vec::new(),
            scratch_vals: Vec::new(),
            scratch_codes: Vec::new(),
        })
    }

    /// Calibration keys per layer per head: prefill a mixed-genre corpus.
    fn calibration_keys(model: &Gpt2, cfg: &EngineConfig)
        -> anyhow::Result<Vec<Vec<Vec<f32>>>>
    {
        let tok = crate::model::ByteTokenizer::new();
        let mut text = String::new();
        for (i, g) in Genre::ALL.iter().enumerate() {
            text.push_str(
                &Corpus::new(*g, cfg.seed ^ i as u64)
                    .generate(cfg.calib_tokens * 2),
            );
        }
        let ids = tok.encode_clamped(
            &text,
            cfg.calib_tokens.min(cfg.model.max_pos),
        );
        let out = model.prefill(&ids);
        let d_k = cfg.model.d_head;
        Ok((0..cfg.model.n_layer)
            .map(|layer| {
                (0..cfg.model.n_head)
                    .map(|head| out.head_keys(layer, head, d_k))
                    .collect()
            })
            .collect())
    }

    /// Sequences currently registered.
    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Cache stats of layer 0 (all layers are symmetric).
    pub fn cache_stats(&self) -> crate::kvcache::CacheStats {
        self.caches[0].stats()
    }

    /// Whether the cache can admit a sequence of `prompt + gen` tokens.
    pub fn can_admit(&self, total_tokens: usize) -> bool {
        let blocks_needed =
            total_tokens.div_ceil(crate::kvcache::BLOCK_TOKENS);
        self.caches.iter().all(|c| {
            c.stats().blocks_total - c.stats().blocks_allocated
                >= blocks_needed
        })
    }

    /// Admit a sequence: prefill its prompt, fill every layer's cache,
    /// return nothing (call [`Engine::decode_one`] for tokens).
    pub fn start_seq(&mut self, id: SeqId, prompt: &[u32])
        -> Result<(), CacheError>
    {
        assert!(!prompt.is_empty(), "empty prompt");
        for c in self.caches.iter_mut() {
            c.create_seq(id)?;
        }
        let out = self.model.prefill(prompt);
        let (h, d_k) = (self.model.n_head(), self.model.d_head());
        for layer in 0..self.model.n_layer() {
            let (k_cache, v_cache) = &out.caches[layer];
            for t in 0..prompt.len() {
                // rows are (d_model) = heads contiguous — exactly the
                // (H × d_k) layout append expects
                let res = self.caches[layer].append(
                    id, k_cache.row(t), v_cache.row(t));
                if let Err(e) = res {
                    // roll back so the caller can retry later
                    for c in self.caches.iter_mut() {
                        let _ = c.free_seq(id);
                    }
                    return Err(e);
                }
            }
            let _ = h;
        }
        self.seqs.insert(
            id,
            SeqMeta { pos: prompt.len(), last_hidden: out.last_hidden },
        );
        let _ = d_k;
        Ok(())
    }

    /// Generate one token for a sequence (greedy). Appends the token's
    /// K/V to the cache. Returns the token id.
    pub fn decode_one(&mut self, id: SeqId) -> anyhow::Result<u32> {
        let meta = self
            .seqs
            .get(&id)
            .with_context(|| format!("unknown seq {id}"))?;
        let token = self.model.greedy_next(&meta.last_hidden);
        let pos = meta.pos;
        if pos >= self.model.weights.config.max_pos {
            bail!("sequence {id} exceeded max position");
        }

        let mut x = self.model.embed(token, pos);
        for layer in 0..self.model.n_layer() {
            let (q, k_new, v_new) = self.model.qkv(layer, &x);
            self.caches[layer]
                .append(id, &k_new, &v_new)
                .map_err(|e| anyhow::anyhow!("cache append: {e}"))?;
            let attn = self.attend_layer(layer, id, &q)?;
            x = self.model.finish_block(layer, &x, &attn);
        }
        let meta = self.seqs.get_mut(&id).unwrap();
        meta.pos += 1;
        meta.last_hidden = x;
        Ok(token)
    }

    /// One decode-step attention over all heads of one layer.
    fn attend_layer(&mut self, layer: usize, id: SeqId, q: &[f32])
        -> anyhow::Result<Vec<f32>>
    {
        let (h, d_k) = (self.model.n_head(), self.model.d_head());
        match &self.backend {
            AttentionBackend::PjrtFp16 => {
                return self.attend_pjrt_fp16(layer, id, q);
            }
            AttentionBackend::PjrtLookat { .. } => {
                return self.attend_pjrt_lookat(layer, id, q);
            }
            _ => {}
        }
        let mut out = vec![0.0f32; h * d_k];
        for head in 0..h {
            let qh = &q[head * d_k..(head + 1) * d_k];
            let n = self.caches[layer]
                .gather_values_into(id, head, &mut self.scratch_vals)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let res = match &self.backend {
                AttentionBackend::Fp16Exact => {
                    self.caches[layer]
                        .gather_keys_into(id, head, &mut self.scratch_keys)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                    attention::exact_attention(
                        qh, &self.scratch_keys, &self.scratch_vals, n)
                }
                AttentionBackend::ScalarQuant { bits } => {
                    self.caches[layer]
                        .gather_keys_into(id, head, &mut self.scratch_keys)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                    attention::scalar_quant_attention(
                        qh, &self.scratch_keys, &self.scratch_vals, n, *bits)
                }
                AttentionBackend::Lookat { .. } => {
                    self.caches[layer]
                        .gather_codes_into(id, head, &mut self.scratch_codes)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                    let codec =
                        &self.caches[layer].codecs().unwrap()[head];
                    let lut = LookupTable::build(qh, &codec.codebook);
                    attention::lookat_attention_with_lut(
                        &lut, &self.scratch_codes, &self.scratch_vals, n,
                        d_k)
                }
                _ => unreachable!(),
            };
            out[head * d_k..(head + 1) * d_k].copy_from_slice(&res.out);
        }
        Ok(out)
    }

    /// Smallest artifact length that fits `n` cached tokens.
    fn pjrt_len_for(&self, n: usize) -> anyhow::Result<usize> {
        self.pjrt_lens
            .iter()
            .copied()
            .find(|&l| l >= n)
            .with_context(|| {
                format!(
                    "cache length {n} exceeds largest artifact L={:?}",
                    self.pjrt_lens.last()
                )
            })
    }

    fn attend_pjrt_fp16(&mut self, layer: usize, id: SeqId, q: &[f32])
        -> anyhow::Result<Vec<f32>>
    {
        let (h, d_k) = (self.model.n_head(), self.model.d_head());
        let n = self.caches[layer].seq_len(id)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let l = self.pjrt_len_for(n)?;
        // pack (H, L, d_k) padded keys/values + (L,) mask
        let mut k = vec![0.0f32; h * l * d_k];
        let mut v = vec![0.0f32; h * l * d_k];
        let mut mask = vec![0.0f32; l];
        mask[..n].fill(1.0);
        for head in 0..h {
            self.caches[layer]
                .gather_keys_into(id, head, &mut self.scratch_keys)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            self.caches[layer]
                .gather_values_into(id, head, &mut self.scratch_vals)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            k[head * l * d_k..head * l * d_k + n * d_k]
                .copy_from_slice(&self.scratch_keys);
            v[head * l * d_k..head * l * d_k + n * d_k]
                .copy_from_slice(&self.scratch_vals);
        }
        let name = format!("attn_fp16_L{l}");
        let rt = self.runtime.as_mut().unwrap();
        let outs = rt.execute(
            &name,
            &[
                InputArg::F32(q),
                InputArg::F32(&k),
                InputArg::F32(&v),
                InputArg::F32(&mask),
            ],
        )?;
        Ok(outs.into_iter().next().unwrap())
    }

    fn attend_pjrt_lookat(&mut self, layer: usize, id: SeqId, q: &[f32])
        -> anyhow::Result<Vec<f32>>
    {
        let (h, d_k) = (self.model.n_head(), self.model.d_head());
        let m = match self.backend {
            AttentionBackend::PjrtLookat { m } => m,
            _ => unreachable!(),
        };
        let n = self.caches[layer].seq_len(id)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let l = self.pjrt_len_for(n)?;
        let kk = self.caches[layer].codecs().unwrap()[0].codebook.k;
        let d_sub = d_k / m;
        let mut codes = vec![0i32; h * l * m];
        let mut cbs = vec![0.0f32; h * m * kk * d_sub];
        let mut v = vec![0.0f32; h * l * d_k];
        let mut mask = vec![0.0f32; l];
        mask[..n].fill(1.0);
        for head in 0..h {
            self.caches[layer]
                .gather_codes_into(id, head, &mut self.scratch_codes)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            self.caches[layer]
                .gather_values_into(id, head, &mut self.scratch_vals)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            for (i, &c) in self.scratch_codes.iter().enumerate() {
                codes[head * l * m + i] = c as i32;
            }
            v[head * l * d_k..head * l * d_k + n * d_k]
                .copy_from_slice(&self.scratch_vals);
            let flat =
                self.caches[layer].codecs().unwrap()[head].codebook.to_flat();
            cbs[head * m * kk * d_sub..(head + 1) * m * kk * d_sub]
                .copy_from_slice(&flat);
        }
        let name = format!("attn_lookat_m{m}_L{l}");
        let rt = self.runtime.as_mut().unwrap();
        let outs = rt.execute(
            &name,
            &[
                InputArg::F32(q),
                InputArg::I32(&codes),
                InputArg::F32(&cbs),
                InputArg::F32(&v),
                InputArg::F32(&mask),
            ],
        )?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Release a finished sequence's cache.
    pub fn release(&mut self, id: SeqId) -> anyhow::Result<()> {
        self.seqs.remove(&id).with_context(|| format!("unknown seq {id}"))?;
        for c in self.caches.iter_mut() {
            c.free_seq(id).map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ByteTokenizer;

    fn tiny_cfg(backend: AttentionBackend) -> EngineConfig {
        EngineConfig {
            model: ModelConfig::test_tiny(),
            backend,
            seed: 1,
            cache_blocks: 32,
            calib_tokens: 96,
        }
    }

    #[test]
    fn fp16_engine_generates_deterministically() {
        let mut e = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        let ids = ByteTokenizer::new().encode("hello engine");
        e.start_seq(1, &ids).unwrap();
        let toks: Vec<u32> =
            (0..8).map(|_| e.decode_one(1).unwrap()).collect();

        let mut e2 = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        e2.start_seq(9, &ids).unwrap();
        let toks2: Vec<u32> =
            (0..8).map(|_| e2.decode_one(9).unwrap()).collect();
        assert_eq!(toks, toks2);
    }

    #[test]
    fn engine_decode_matches_reference_model() {
        // Engine Fp16Exact must reproduce Gpt2::decode_step exactly
        let cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        let mut e = Engine::build(&cfg).unwrap();
        let ids = ByteTokenizer::new().encode("reference check");
        e.start_seq(1, &ids).unwrap();

        // reference: raw decode over Tensor2 caches
        let weights = Weights::random(&cfg.model, cfg.seed);
        let model = Gpt2::new(weights);
        let pre = model.prefill(&ids);
        let mut caches = pre.caches;
        let mut hidden = pre.last_hidden;
        let mut pos = ids.len();

        for _ in 0..5 {
            let tok_engine = e.decode_one(1).unwrap();
            let tok_ref = model.greedy_next(&hidden);
            assert_eq!(tok_engine, tok_ref);
            hidden = model.decode_step(tok_ref, pos, &mut caches);
            pos += 1;
        }
    }

    #[test]
    fn lookat_engine_tracks_fp16_closely() {
        let ids = ByteTokenizer::new().encode(
            "the quick brown fox jumps over the lazy dog again and again");
        let mut fp = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        fp.start_seq(1, &ids).unwrap();
        let mut lk = Engine::build(&tiny_cfg(AttentionBackend::Lookat {
            m: 4,
            k: 64,
        }))
        .unwrap();
        lk.start_seq(1, &ids).unwrap();
        // same model weights (same seed) — only attention path differs
        let t_fp: Vec<u32> = (0..6).map(|_| fp.decode_one(1).unwrap())
            .collect();
        let t_lk: Vec<u32> = (0..6).map(|_| lk.decode_one(1).unwrap())
            .collect();
        // greedy tokens may diverge eventually but the first token comes
        // from an identical prefill hidden state
        assert_eq!(t_fp[0], t_lk[0]);
        let _ = (t_fp, t_lk);
    }

    #[test]
    fn admission_and_release_cycle() {
        let mut e = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        let ids = ByteTokenizer::new().encode("abc");
        assert!(e.can_admit(ids.len() + 4));
        e.start_seq(5, &ids).unwrap();
        assert_eq!(e.active_seqs(), 1);
        let _ = e.decode_one(5).unwrap();
        assert!(e.cache_stats().tokens > 0);
        e.release(5).unwrap();
        assert_eq!(e.active_seqs(), 0);
        assert_eq!(e.cache_stats().tokens, 0);
    }

    #[test]
    fn cache_exhaustion_rolls_back_cleanly() {
        let mut cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        cfg.cache_blocks = 1; // 32 tokens only
        let mut e = Engine::build(&cfg).unwrap();
        let long: Vec<u32> = (0..100).map(|i| (i % 200) as u32).collect();
        assert!(e.start_seq(1, &long).is_err());
        // rollback: no partial residue
        assert_eq!(e.cache_stats().tokens, 0);
        assert_eq!(e.cache_stats().blocks_allocated, 0);
        // a short sequence still fits afterwards
        e.start_seq(2, &long[..16]).unwrap();
        assert_eq!(e.cache_stats().tokens, 16);
    }

    #[test]
    fn unknown_seq_errors() {
        let mut e = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        assert!(e.decode_one(42).is_err());
        assert!(e.release(42).is_err());
    }

    #[test]
    fn scalar_quant_backend_runs() {
        let mut e = Engine::build(&tiny_cfg(
            AttentionBackend::ScalarQuant { bits: 8 })).unwrap();
        let ids = ByteTokenizer::new().encode("int8 path");
        e.start_seq(1, &ids).unwrap();
        for _ in 0..3 {
            e.decode_one(1).unwrap();
        }
    }

    #[test]
    fn backend_names() {
        assert_eq!(AttentionBackend::Fp16Exact.name(), "fp16");
        assert_eq!(AttentionBackend::Lookat { m: 4, k: 256 }.name(),
                   "lookat-4");
        assert_eq!(AttentionBackend::ScalarQuant { bits: 4 }.name(), "int4");
        assert_eq!(AttentionBackend::PjrtLookat { m: 2 }.name(),
                   "pjrt-lookat-2");
    }
}
