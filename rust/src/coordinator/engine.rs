//! The inference engine: transformer decode over the paged KV-cache with
//! a pluggable batched attention kernel.
//!
//! Backends (each an [`AttentionKernel`] implementation):
//! * `Fp16Exact` — raw keys in cache, exact attention (the baseline)
//! * `Lookat{m}` — keys stored as PQ codes, block-resident ADC attention
//!   (the paper; zero per-step key-code copies)
//! * `ScalarQuant{bits}` — raw keys, INT4/INT8 round-trip attention
//! * `PjrtFp16` / `PjrtLookat{m}` — attention steps executed through the
//!   AOT artifacts on the PJRT CPU client (proves the 3-layer contract
//!   end-to-end in the serving loop)
//!
//! The serving tick is unified: [`Engine::step_batch`] advances a mixed
//! set of [`TickEntry`]s — decode items (one greedy token each) and
//! prefill chunks (a span of prompt tokens) — by building one
//! [`DecodePlan`] per layer containing *all* (seq, head) work items of
//! the tick. Prefill rides the same backend kernel as decode (a decode
//! item is just a one-row span), which has two consequences the
//! scheduler leans on:
//!
//! * chunked prefill is bit-identical to monolithic prefill on every
//!   backend — a span row's math depends only on (query row, cache
//!   prefix), never on how rows were grouped into ticks;
//! * a preempted sequence resumes exactly: re-prefilling its prompt +
//!   generated-so-far tokens re-encodes codes and replays the identical
//!   per-token computation, so the resumed hidden state (and every
//!   subsequent logit) matches the uninterrupted run bit for bit.
//!
//! LOOKAT codebooks are trained once at engine build from a calibration
//! corpus (paper §3.4); the serving hot path never touches python.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context};

use super::policy::{
    allocate_budget, prune_threshold, BudgetItem, CompressionPolicy,
    HeadPolicy, PolicySummary, Side,
};
use crate::attention::kernel::{
    Fp16Kernel, LookatKernel, PjrtFp16Kernel, PjrtLookatKernel,
    ScalarQuantKernel,
};
use crate::attention::{AttentionKernel, AttnOutput, DecodePlan, WorkItem};
use crate::kvcache::{
    BlockId, CacheError, KeyStorage, KvCache, SeqId, ValueStorage,
    BLOCK_TOKENS,
};
use crate::model::{Gpt2, ModelConfig, PrefillOutput, Weights};
use crate::pq::{PqCodec, TrainOpts};
use crate::runtime::Runtime;
use crate::telemetry::{Ctr, Gauge, MetricsRegistry};
use crate::util::fault::{FaultAction, FaultPlan, FaultSite};
use crate::util::threadpool::{self, parallel_map, scratch};
use crate::util::timing::{timed, Phase, PhaseTimers, PhaseTimes};
use crate::workload::{Corpus, Genre};

/// Which attention implementation the engine uses at decode time.
#[derive(Clone, Debug, PartialEq)]
pub enum AttentionBackend {
    /// exact attention over FP16-stored keys
    Fp16Exact,
    /// LOOKAT: ADC over PQ codes with `m` subspaces, K centroids
    Lookat { m: usize, k: usize },
    /// INT4/INT8 dequantize-then-attend baseline
    ScalarQuant { bits: u8 },
    /// FP16 attention executed via the AOT artifact on PJRT
    PjrtFp16,
    /// LOOKAT attention executed via the AOT artifact on PJRT
    PjrtLookat { m: usize },
}

impl AttentionBackend {
    pub fn name(&self) -> String {
        match self {
            AttentionBackend::Fp16Exact => "fp16".into(),
            // K = 256 is the paper's default and keeps its historical
            // bare label so perf baselines stay comparable; narrower
            // codebooks (the 4-bit fast-scan mode) are spelled out
            AttentionBackend::Lookat { m, k: 256 } => format!("lookat-{m}"),
            AttentionBackend::Lookat { m, k } => format!("lookat-{m}+k{k}"),
            AttentionBackend::ScalarQuant { bits } => format!("int{bits}"),
            AttentionBackend::PjrtFp16 => "pjrt-fp16".into(),
            AttentionBackend::PjrtLookat { m } => format!("pjrt-lookat-{m}"),
        }
    }

    fn needs_pq(&self) -> Option<(usize, usize)> {
        match self {
            AttentionBackend::Lookat { m, k } => Some((*m, *k)),
            AttentionBackend::PjrtLookat { m } => Some((*m, 256)),
            _ => None,
        }
    }
}

/// How the engine's caches store values — the value-side axis of the
/// backend matrix, orthogonal to [`AttentionBackend`] (which picks the
/// key representation and scoring path).
#[derive(Clone, Debug, PartialEq)]
pub enum ValueBackend {
    /// raw values (the default; "FP16" under the paper's byte model)
    Fp32,
    /// PQ-coded values with `m` subspaces, K centroids: the fused
    /// blocked weighted decode serves attention with zero per-step
    /// value dequantization copies
    Pq { m: usize, k: usize },
}

impl ValueBackend {
    pub fn name(&self) -> String {
        match self {
            ValueBackend::Fp32 => "fp32".into(),
            ValueBackend::Pq { m, k: 256 } => format!("vpq-{m}"),
            ValueBackend::Pq { m, k } => format!("vpq-{m}+k{k}"),
        }
    }

    fn needs_pq(&self) -> Option<(usize, usize)> {
        match self {
            ValueBackend::Fp32 => None,
            ValueBackend::Pq { m, k } => Some((*m, *k)),
        }
    }
}

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: ModelConfig,
    pub backend: AttentionBackend,
    /// value-side storage (orthogonal to `backend`; PJRT backends
    /// require `Fp32`)
    pub value_backend: ValueBackend,
    pub seed: u64,
    /// KV-cache budget in blocks per layer
    pub cache_blocks: usize,
    /// tokens of calibration text for PQ codebook training
    pub calib_tokens: usize,
    /// worker threads for the batched decode fan-out (0 = one per
    /// available core)
    pub decode_threads: usize,
    /// prefill chunk size in tokens: the scheduler splits every prompt
    /// into spans of at most this many tokens so long prefills
    /// interleave with decode ticks (0 = monolithic, Sarathi-style off)
    pub prefill_chunk: usize,
    /// software-pipelined layer executor (`--pipeline on|off`): split
    /// each tick's entries into two groups and overlap one group's
    /// layer-`l` attention (ADC scan + finish) with the other group's
    /// QKV projection on the scoped pool. Output is bit-identical
    /// either way (per-row math never changes, only scheduling);
    /// ticks with < 2 entries or a single worker run the serial path
    pub pipeline: bool,
    /// hash-keyed copy-on-write prefix cache (`--prefix-cache on|off`):
    /// full prompt blocks are content-hashed at prefill completion and
    /// later sequences whose prompts start with the same token blocks
    /// attach the physical blocks instead of recomputing them
    pub prefix_cache: bool,
    /// compression policy (`--policy uniform|calibrated-<bits>|
    /// prune-<frac>`), resolved once at build time. `Uniform` trains
    /// one (m, K) per cache side exactly as before (bit-identical to
    /// the pre-policy engine); `Calibrated` distributes a total
    /// bits/token budget across (layer, head, side) by calibration
    /// error; `Prune` drops low-L2-norm tokens at append time. PJRT
    /// backends accept only `Uniform` (the artifacts bake in one
    /// global m)
    pub policy: CompressionPolicy,
    /// deterministic fault-injection plan (chaos testing; the default
    /// disabled plan is a single branch on the hot path). Engine-side
    /// hooks: block allocation, swap out/in, prefix attach
    pub faults: FaultPlan,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            model: ModelConfig::gpt2_layer0(),
            backend: AttentionBackend::Fp16Exact,
            value_backend: ValueBackend::Fp32,
            seed: 0xE47,
            cache_blocks: 256,
            calib_tokens: 384,
            decode_threads: 0,
            prefill_chunk: 0,
            pipeline: true,
            prefix_cache: false,
            policy: CompressionPolicy::Uniform,
            faults: FaultPlan::default(),
        }
    }
}

/// One unit of a serving tick, as assembled by the batcher.
#[derive(Clone, Copy, Debug)]
pub enum TickEntry<'t> {
    /// advance a decoding sequence by one greedy token
    Decode(SeqId),
    /// process the sequence's next prefill chunk; `tokens[r]` lands at
    /// cache position `pos + r`
    Prefill { seq: SeqId, tokens: &'t [u32] },
}

impl TickEntry<'_> {
    fn seq(&self) -> SeqId {
        match self {
            TickEntry::Decode(id) => *id,
            TickEntry::Prefill { seq, .. } => *seq,
        }
    }

    fn span(&self) -> usize {
        match self {
            TickEntry::Decode(_) => 1,
            TickEntry::Prefill { tokens, .. } => tokens.len(),
        }
    }
}

/// Per-entry result of one [`Engine::step_batch`] tick.
#[derive(Clone, Copy, Debug)]
pub struct TickOutcome {
    pub seq: SeqId,
    /// the greedy token produced this tick — `Some` for decode entries,
    /// `None` for prefill chunks
    pub token: Option<u32>,
}

struct SeqMeta {
    pos: usize,
    /// final hidden state of the last processed position; empty until
    /// the first prefill chunk lands
    last_hidden: Vec<f32>,
}

/// One shared full prompt block: its exact tokens (hash-collision
/// verification), one physical block id per layer, and how many live
/// sequences hold it (registered or attached). The entry is dropped
/// when the last holder releases — the block ids are only valid while
/// some holder's per-layer refcounts keep the blocks alive.
struct PrefixEntry {
    tokens: Vec<u32>,
    blocks: Vec<BlockId>,
    holders: usize,
    /// FNV-1a over the blocks' cache content (all layers, chained),
    /// stamped at registration and re-verified before any attach —
    /// shared blocks are immutable, so drift means corruption and the
    /// entry is dropped instead of served
    checksum: u64,
}

/// Chain-hash-keyed index of shared prompt blocks. The key for block
/// `i` hashes block `i-1`'s key plus block `i`'s tokens, so a lookup
/// that matches k blocks proves the full k-block token prefix matches.
#[derive(Default)]
struct PrefixIndex {
    entries: std::collections::HashMap<u64, PrefixEntry>,
    /// which entry hashes each live sequence holds
    held: std::collections::HashMap<SeqId, Vec<u64>>,
}

/// FNV-1a over a parent chain hash and one block's token bytes.
fn chain_hash(parent: u64, tokens: &[u32]) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h = parent ^ 0xcbf29ce484222325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

/// The engine: model + per-layer caches + batched attention kernel.
pub struct Engine {
    pub model: Gpt2,
    pub backend: AttentionBackend,
    pub value_backend: ValueBackend,
    caches: Vec<KvCache>,
    seqs: std::collections::HashMap<SeqId, SeqMeta>,
    /// decode-state of swapped-out sequences (their cache content lives
    /// in each layer's spill store until swap-in)
    swapped_meta: std::collections::HashMap<SeqId, SeqMeta>,
    prefix: PrefixIndex,
    prefix_cache: bool,
    kernel: Box<dyn AttentionKernel>,
    threads: usize,
    prefill_chunk: usize,
    pipeline: bool,
    /// per-phase wall-time accumulators (lut_build / scan /
    /// value_decode from the kernels, qkv / mlp from the stage loop);
    /// drained per serving run via [`Engine::take_phase_times`]
    timers: PhaseTimers,
    /// live serving telemetry; shared out via [`Engine::metrics`] so the
    /// batcher/router/server publish and read through one registry
    metrics: Arc<MetricsRegistry>,
    /// cumulative phase snapshot at the last per-tick publish — the
    /// registry's phase counters advance by the delta each tick
    last_phases: Mutex<PhaseTimes>,
    /// the active compression policy (resolved at build)
    policy: CompressionPolicy,
    /// build-time policy record: per-(layer, head) subspace counts,
    /// rho estimates, prune thresholds, total bits/token
    summary: PolicySummary,
    /// cumulative pruned-token count at the last per-tick publish
    last_pruned: AtomicU64,
    /// deterministic fault-injection plan (disabled by default; see
    /// [`crate::util::fault`])
    faults: FaultPlan,
}

/// Typed failure from the engine's admission path. `Cache` errors are
/// retryable capacity signals (the scheduler preempts or re-queues);
/// `Fault` wraps what used to be a `panic!` — a non-cache prefill
/// failure (position overflow, kernel fault) the scheduler answers by
/// quarantining the one sequence and keeping everyone else alive.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineError {
    Cache(CacheError),
    Fault { seq: SeqId, msg: String },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Cache(e) => write!(f, "{e}"),
            EngineError::Fault { seq, msg } => {
                write!(f, "sequence {seq} prefill fault: {msg}")
            }
        }
    }
}

impl std::error::Error for EngineError {}

impl From<CacheError> for EngineError {
    fn from(e: CacheError) -> Self {
        EngineError::Cache(e)
    }
}

impl Engine {
    /// Build an engine: init weights, train codebooks if the backend
    /// needs them, open the PJRT runtime if requested.
    pub fn build(cfg: &EngineConfig) -> anyhow::Result<Engine> {
        let weights = Weights::random(&cfg.model, cfg.seed);
        Self::with_weights(cfg, weights)
    }

    /// Build with explicit weights (examples load from disk).
    pub fn with_weights(cfg: &EngineConfig, weights: Weights)
        -> anyhow::Result<Engine>
    {
        let model = Gpt2::new(weights);
        let (h, d_k) = (cfg.model.n_head, cfg.model.d_head);

        let key_pq = cfg.backend.needs_pq();
        let value_pq = cfg.value_backend.needs_pq();
        if value_pq.is_some()
            && matches!(
                cfg.backend,
                AttentionBackend::PjrtFp16
                    | AttentionBackend::PjrtLookat { .. }
            )
        {
            bail!(
                "PQ value storage is not supported on PJRT backends \
                 (the artifacts have no value-code contract); use \
                 --value-backend fp32"
            );
        }

        // Policy validation up front: the PJRT artifacts bake in one
        // global m, and prefix sharing indexes blocks by token
        // position, which pruning breaks.
        if cfg.policy != CompressionPolicy::Uniform
            && matches!(
                cfg.backend,
                AttentionBackend::PjrtFp16
                    | AttentionBackend::PjrtLookat { .. }
            )
        {
            bail!(
                "--policy {} is not supported on PJRT backends (the \
                 artifacts assume one global m); use --policy uniform",
                cfg.policy.name()
            );
        }
        if matches!(cfg.policy, CompressionPolicy::Calibrated { .. })
            && key_pq.is_none()
            && value_pq.is_none()
        {
            bail!(
                "--policy {} needs a PQ side to budget; pick a lookat \
                 backend and/or a vpq value backend",
                cfg.policy.name()
            );
        }
        if matches!(cfg.policy, CompressionPolicy::Prune { .. })
            && cfg.prefix_cache
        {
            bail!(
                "--prefix-cache cannot combine with --policy {}: pruned \
                 caches break block-aligned prefix sharing",
                cfg.policy.name()
            );
        }

        // PQ backends: train per-layer, per-head codebooks on a
        // calibration corpus exactly like the paper's §3.4 (prefill
        // once, take each head's keys — and values, for the §5.2
        // value-side extension — from every layer). The pruning policy
        // rides the same prefill for its norm thresholds even when the
        // key side stays raw.
        let calib: Option<PrefillOutput> = if key_pq.is_some()
            || value_pq.is_some()
            || cfg.policy != CompressionPolicy::Uniform
        {
            Some(Self::calibration_prefill(&model, cfg)?)
        } else {
            None
        };
        let train = |data: &[f32], m: usize, k: usize, salt: u64| {
            PqCodec::train(
                data,
                d_k,
                m,
                k,
                &TrainOpts { seed: cfg.seed ^ salt, ..Default::default() },
            )
        };

        // Resolve the policy into per-(layer, head) codec sets for each
        // PQ side. Uniform (and Prune, whose codec geometry is uniform)
        // performs the exact historical training calls, so it is
        // bit-identical to the pre-policy engine; Calibrated trains a
        // candidate ladder per slot and spends the bits/token budget
        // where calibration error drops fastest.
        let n_layer = cfg.model.n_layer;
        type LayerCodecs = Vec<Option<Vec<PqCodec>>>;
        let (key_codecs, val_codecs): (LayerCodecs, LayerCodecs) =
            match cfg.policy {
                CompressionPolicy::Calibrated { bits } => {
                    let out = calib.as_ref().unwrap();
                    let mut items: Vec<BudgetItem> = Vec::new();
                    let mut trained: Vec<Vec<PqCodec>> = Vec::new();
                    for (side, base, salt) in [
                        (Side::Key, key_pq, 0x90u64),
                        (Side::Value, value_pq, 0x91),
                    ] {
                        let Some((m0, k)) = base else { continue };
                        let cands = candidate_ms(d_k, m0);
                        for layer in 0..n_layer {
                            for head in 0..h {
                                let data = match side {
                                    Side::Key => {
                                        out.head_keys(layer, head, d_k)
                                    }
                                    Side::Value => {
                                        out.head_values(layer, head, d_k)
                                    }
                                };
                                let codecs: Vec<PqCodec> = cands
                                    .iter()
                                    .map(|&m| train(&data, m, k, salt))
                                    .collect();
                                let candidates = codecs
                                    .iter()
                                    .map(|c| {
                                        (
                                            c.codebook.m,
                                            c.train_mse
                                                .iter()
                                                .sum::<f64>(),
                                        )
                                    })
                                    .collect();
                                items.push(BudgetItem {
                                    layer,
                                    head,
                                    side,
                                    code_bits: code_bits(k),
                                    candidates,
                                });
                                trained.push(codecs);
                            }
                        }
                    }
                    let choice = allocate_budget(&items, bits).map_err(
                        |e| {
                            anyhow::anyhow!(
                                "--policy {}: {e}",
                                cfg.policy.name()
                            )
                        },
                    )?;
                    let mut keyc: LayerCodecs = (0..n_layer)
                        .map(|_| key_pq.map(|_| Vec::new()))
                        .collect();
                    let mut valc: LayerCodecs = (0..n_layer)
                        .map(|_| value_pq.map(|_| Vec::new()))
                        .collect();
                    for ((item, mut codecs), &c) in
                        items.iter().zip(trained).zip(&choice)
                    {
                        let chosen = codecs.swap_remove(c);
                        let slot = match item.side {
                            Side::Key => {
                                keyc[item.layer].as_mut().unwrap()
                            }
                            Side::Value => {
                                valc[item.layer].as_mut().unwrap()
                            }
                        };
                        debug_assert_eq!(slot.len(), item.head);
                        slot.push(chosen);
                    }
                    (keyc, valc)
                }
                _ => {
                    let keyc = (0..n_layer)
                        .map(|layer| {
                            key_pq.map(|(m, k)| {
                                let out = calib.as_ref().unwrap();
                                (0..h)
                                    .map(|head| {
                                        train(
                                            &out.head_keys(
                                                layer, head, d_k,
                                            ),
                                            m,
                                            k,
                                            0x90,
                                        )
                                    })
                                    .collect()
                            })
                        })
                        .collect();
                    let valc = (0..n_layer)
                        .map(|layer| {
                            value_pq.map(|(m, k)| {
                                let out = calib.as_ref().unwrap();
                                (0..h)
                                    .map(|head| {
                                        train(
                                            &out.head_values(
                                                layer, head, d_k,
                                            ),
                                            m,
                                            k,
                                            0x91,
                                        )
                                    })
                                    .collect()
                            })
                        })
                        .collect();
                    (keyc, valc)
                }
            };

        // Pruning thresholds: the frac-quantile of the calibration
        // tokens' mean-head key L2 norms, per layer (the same statistic
        // KvCache::append tests at serve time).
        let thresholds: Vec<f32> = match cfg.policy {
            CompressionPolicy::Prune { frac } => {
                let out = calib.as_ref().unwrap();
                let mut tok = vec![0f32; h * d_k];
                (0..n_layer)
                    .map(|layer| {
                        let per_head: Vec<Vec<f32>> = (0..h)
                            .map(|head| out.head_keys(layer, head, d_k))
                            .collect();
                        let n_tok = per_head[0].len() / d_k;
                        let norms: Vec<f32> = (0..n_tok)
                            .map(|t| {
                                for (head, ks) in
                                    per_head.iter().enumerate()
                                {
                                    tok[head * d_k..(head + 1) * d_k]
                                        .copy_from_slice(
                                            &ks[t * d_k
                                                ..(t + 1) * d_k],
                                        );
                                }
                                crate::kvcache::mean_head_norm(
                                    &tok, h, d_k,
                                )
                            })
                            .collect();
                        prune_threshold(&norms, frac)
                    })
                    .collect()
            }
            _ => Vec::new(),
        };

        let summary = Self::build_policy_summary(
            cfg,
            &calib,
            &key_codecs,
            &val_codecs,
            &thresholds,
            h,
            d_k,
        );

        let mut caches = Vec::with_capacity(n_layer);
        for layer in 0..n_layer {
            let storage = match &key_codecs[layer] {
                Some(cs) => KeyStorage::pq(cs.clone())
                    .map_err(|e| anyhow::anyhow!("{e}"))?,
                None => KeyStorage::Fp16,
            };
            let value_storage = match &val_codecs[layer] {
                Some(cs) => ValueStorage::pq(cs.clone())
                    .map_err(|e| anyhow::anyhow!("{e}"))?,
                None => ValueStorage::Fp32,
            };
            let mut cache = KvCache::new(
                h, d_k, cfg.cache_blocks, storage, value_storage);
            if let Some(&thr) = thresholds.get(layer) {
                cache.set_prune_threshold(Some(thr));
            }
            caches.push(cache);
        }

        let kernel = Self::build_kernel(cfg)?;
        let threads = if cfg.decode_threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            cfg.decode_threads
        };

        Ok(Engine {
            model,
            backend: cfg.backend.clone(),
            value_backend: cfg.value_backend.clone(),
            caches,
            seqs: std::collections::HashMap::new(),
            swapped_meta: std::collections::HashMap::new(),
            prefix: PrefixIndex::default(),
            prefix_cache: cfg.prefix_cache,
            kernel,
            threads,
            prefill_chunk: cfg.prefill_chunk,
            pipeline: cfg.pipeline,
            timers: PhaseTimers::new(),
            metrics: Arc::new(MetricsRegistry::new()),
            last_phases: Mutex::new(PhaseTimes::default()),
            policy: cfg.policy.clone(),
            summary,
            last_pruned: AtomicU64::new(0),
            faults: cfg.faults.clone(),
        })
    }

    /// Consult the fault plan at an engine hook. Delay actions sleep in
    /// place and return `None` (the operation proceeds); `Err` is
    /// returned for the call site to convert into its native error
    /// type; `Panic` panics here (the serving loop's `catch_unwind`
    /// isolation is what's under test). Every firing is counted.
    fn injected_fault(&mut self, site: FaultSite) -> Option<FaultAction> {
        let act = self.faults.check(site)?;
        self.metrics.inc(Ctr::FaultsInjected, 1);
        match act {
            FaultAction::Delay(d) => {
                std::thread::sleep(d);
                None
            }
            FaultAction::Panic => {
                panic!("injected fault: {}", site.name())
            }
            FaultAction::Err => Some(FaultAction::Err),
        }
    }

    /// The active compression policy.
    pub fn policy(&self) -> &CompressionPolicy {
        &self.policy
    }

    /// The build-time policy record: which m each (layer, head, side)
    /// got, its estimated score fidelity (Spearman ρ on calibration
    /// probes), the per-layer prune thresholds and the total bits/token
    /// actually spent — the ablation harness's per-head rho source.
    pub fn policy_record(&self) -> &PolicySummary {
        &self.summary
    }

    /// Tokens the L2-norm pruning policy has dropped so far, summed
    /// over every layer cache (0 unless `--policy prune-<frac>`).
    pub fn pruned_tokens(&self) -> u64 {
        self.caches.iter().map(|c| c.pruned_tokens()).sum()
    }

    /// Assemble the [`PolicySummary`] at build time (pure observation;
    /// the rho estimate reuses the calibration keys as probe queries).
    #[allow(clippy::too_many_arguments)]
    fn build_policy_summary(
        cfg: &EngineConfig,
        calib: &Option<PrefillOutput>,
        key_codecs: &[Option<Vec<PqCodec>>],
        val_codecs: &[Option<Vec<PqCodec>>],
        thresholds: &[f32],
        h: usize,
        d_k: usize,
    ) -> PolicySummary {
        let mut total_bits = 0usize;
        let mut heads = Vec::with_capacity(cfg.model.n_layer * h);
        for layer in 0..cfg.model.n_layer {
            for head in 0..h {
                let kc = key_codecs[layer].as_ref().map(|cs| &cs[head]);
                let vc = val_codecs[layer].as_ref().map(|cs| &cs[head]);
                for c in [kc, vc].into_iter().flatten() {
                    total_bits +=
                        c.codebook.m * code_bits(c.codebook.k);
                }
                let rho = match (kc, calib) {
                    (Some(c), Some(out)) => estimate_rho(
                        &out.head_keys(layer, head, d_k),
                        c,
                        d_k,
                    ),
                    _ => 1.0,
                };
                heads.push(HeadPolicy {
                    layer,
                    head,
                    key_m: kc.map_or(0, |c| c.codebook.m),
                    value_m: vc.map_or(0, |c| c.codebook.m),
                    rho,
                });
            }
        }
        PolicySummary {
            policy: cfg.policy.name(),
            total_bits_per_token: total_bits,
            prune_thresholds: thresholds.to_vec(),
            heads,
        }
    }

    /// The engine's live telemetry registry. Shared (`Arc`) so the
    /// batcher, router and TCP server publish and read through it.
    pub fn metrics(&self) -> Arc<MetricsRegistry> {
        Arc::clone(&self.metrics)
    }

    /// Combined backend label for reports: the key backend's name, plus
    /// a `+vpq-<m>` suffix when values are PQ-coded (fp32 values keep
    /// the bare name, so perf trajectories stay comparable across PRs).
    /// Configs that store nibble-packed (K ≤ 16) code lanes run the
    /// SIMD shuffle scan, so their labels additionally carry the active
    /// ISA path (e.g. `lookat-8+k16/avx2`) — K = 256 labels stay bare
    /// to keep baseline series byte-stable.
    pub fn label(&self) -> String {
        let base = match &self.value_backend {
            ValueBackend::Fp32 => self.backend.name(),
            vb => format!("{}+{}", self.backend.name(), vb.name()),
        };
        if self.packed_codes() {
            format!("{base}/{}", crate::pq::simd::scan_path())
        } else {
            base
        }
    }

    /// Whether either cache side stores nibble-packed (K ≤ 16) code
    /// lanes — the configs the register-resident shuffle scan serves.
    fn packed_codes(&self) -> bool {
        let key = matches!(self.backend,
            AttentionBackend::Lookat { k, .. }
                if crate::pq::packs_nibbles(k));
        let val = matches!(self.value_backend,
            ValueBackend::Pq { k, .. } if crate::pq::packs_nibbles(k));
        key || val
    }

    /// The ADC scan path the runtime ISA detection selected ("avx2" or
    /// "scalar"; `LOOKAT_SIMD=scalar` forces the latter). Serving
    /// reports record it per run so perf series are attributable.
    pub fn scan_path(&self) -> &'static str {
        crate::pq::simd::scan_path()
    }

    /// Instantiate the backend's attention kernel. PJRT backends open
    /// the runtime here and move it into the kernel — the engine itself
    /// no longer talks to the artifact executor.
    fn build_kernel(cfg: &EngineConfig)
        -> anyhow::Result<Box<dyn AttentionKernel>>
    {
        Ok(match cfg.backend {
            AttentionBackend::Fp16Exact => Box::new(Fp16Kernel),
            AttentionBackend::Lookat { .. } => Box::new(LookatKernel),
            AttentionBackend::ScalarQuant { bits } => {
                Box::new(ScalarQuantKernel { bits })
            }
            AttentionBackend::PjrtFp16
            | AttentionBackend::PjrtLookat { .. } => {
                let runtime = Runtime::open_default().context(
                    "PJRT backend needs artifacts (run `make artifacts`)",
                )?;
                let kind = if matches!(cfg.backend,
                                       AttentionBackend::PjrtFp16) {
                    "attn_fp16"
                } else {
                    "attn_lookat"
                };
                let mut lens: Vec<usize> = runtime
                    .manifest
                    .by_kind(kind)
                    .iter()
                    .filter(|a| match cfg.backend {
                        AttentionBackend::PjrtLookat { m } => {
                            a.meta_usize("m") == Some(m)
                        }
                        _ => true,
                    })
                    .filter_map(|a| a.meta_usize("L"))
                    .collect();
                lens.sort_unstable();
                if lens.is_empty() {
                    bail!("no artifacts for backend {:?}", cfg.backend);
                }
                match cfg.backend {
                    AttentionBackend::PjrtFp16 => {
                        Box::new(PjrtFp16Kernel::new(runtime, lens))
                    }
                    AttentionBackend::PjrtLookat { m } => {
                        Box::new(PjrtLookatKernel::new(runtime, lens, m))
                    }
                    _ => unreachable!(),
                }
            }
        })
    }

    /// Calibration prefill over a mixed-genre corpus: one forward pass
    /// whose per-layer caches supply both the key and the value
    /// codebook training sets.
    fn calibration_prefill(model: &Gpt2, cfg: &EngineConfig)
        -> anyhow::Result<PrefillOutput>
    {
        let tok = crate::model::ByteTokenizer::new();
        let mut text = String::new();
        for (i, g) in Genre::ALL.iter().enumerate() {
            text.push_str(
                &Corpus::new(*g, cfg.seed ^ i as u64)
                    .generate(cfg.calib_tokens * 2),
            );
        }
        let ids = tok.encode_clamped(
            &text,
            cfg.calib_tokens.min(cfg.model.max_pos),
        );
        Ok(model.prefill(&ids))
    }

    /// Sequences currently registered.
    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Cache stats of layer 0 (all layers are symmetric).
    pub fn cache_stats(&self) -> crate::kvcache::CacheStats {
        self.caches[0].stats()
    }

    /// Whether the cache can admit a sequence of `prompt + gen` tokens.
    pub fn can_admit(&self, total_tokens: usize) -> bool {
        self.free_blocks() >= total_tokens.div_ceil(BLOCK_TOKENS)
    }

    /// Free cache blocks available right now (min across layers) — the
    /// batcher's cumulative admission budget.
    pub fn free_blocks(&self) -> usize {
        self.caches
            .iter()
            .map(|c| {
                let s = c.stats();
                s.blocks_total - s.blocks_allocated
            })
            .min()
            .unwrap_or(0)
    }

    /// Total block budget per layer.
    pub fn total_blocks(&self) -> usize {
        self.caches[0].stats().blocks_total
    }

    /// The configured prefill chunk size (0 = monolithic).
    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Whether the software-pipelined layer executor is enabled.
    pub fn pipeline_enabled(&self) -> bool {
        self.pipeline
    }

    /// Drain the per-phase timing accumulators (one serving run's
    /// breakdown: `lut_build`, `scan`, `value_decode`, `qkv`, `mlp`).
    /// Phase sums count every thread and overlapped stage, so they may
    /// exceed wall time — they locate compute, not the clock.
    pub fn take_phase_times(&self) -> PhaseTimes {
        let taken = self.timers.take();
        // Re-base the per-tick registry deltas: the accumulators just
        // reset, so the next publish must diff against zero.
        *self.last_phases.lock().unwrap() = PhaseTimes::default();
        taken
    }

    /// Tokens currently cached for a sequence (`None` if unknown).
    pub fn seq_pos(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|m| m.pos)
    }

    /// Blocks a sequence holds per layer (0 if unknown) — what a
    /// preemption would free.
    pub fn seq_blocks(&self, id: SeqId) -> usize {
        self.caches[0].seq_blocks(id).unwrap_or(0)
    }

    /// Register an empty sequence: no prefill compute, no blocks — the
    /// scheduler feeds its prompt in chunks via [`Engine::step_batch`].
    pub fn begin_seq(&mut self, id: SeqId) -> Result<(), CacheError> {
        if self.seqs.contains_key(&id) {
            return Err(CacheError::DuplicateSeq(id));
        }
        for i in 0..self.caches.len() {
            if let Err(e) = self.caches[i].create_seq(id) {
                for c in self.caches[..i].iter_mut() {
                    let _ = c.free_seq(id);
                }
                return Err(e);
            }
        }
        self.seqs.insert(
            id,
            SeqMeta { pos: 0, last_hidden: Vec::new() },
        );
        Ok(())
    }

    /// Register an empty sequence and, when the prefix cache is on,
    /// attach every leading full prompt block already resident from an
    /// earlier sequence with the same token prefix. Returns the number
    /// of prompt tokens covered by attached blocks (0 with the cache
    /// off or on a miss) — the scheduler skips prefilling them. At
    /// least the last prompt token is always left to prefill so the
    /// sequence still produces its decode hidden state.
    pub fn begin_seq_with_prefix(
        &mut self,
        id: SeqId,
        prompt: &[u32],
    ) -> Result<usize, CacheError> {
        if !self.prefix_cache {
            self.begin_seq(id)?;
            return Ok(0);
        }
        let max_blocks = prompt.len().saturating_sub(1) / BLOCK_TOKENS;
        let mut matched: Vec<(u64, Vec<BlockId>)> = Vec::new();
        let mut parent = 0u64;
        for i in 0..max_blocks {
            let toks =
                &prompt[i * BLOCK_TOKENS..(i + 1) * BLOCK_TOKENS];
            let h = chain_hash(parent, toks);
            let Some(e) = self.prefix.entries.get(&h) else { break };
            if e.tokens != toks {
                break;
            }
            let blocks = e.blocks.clone();
            let want = e.checksum;
            // integrity gate: shared blocks are immutable by the
            // copy-on-write contract, so a checksum mismatch means
            // corruption — drop the entry and re-prefill from this
            // block on instead of serving poisoned state
            if self.prefix_block_checksum(&blocks) != want {
                self.metrics.inc(Ctr::ChecksumFailures, 1);
                self.prefix.entries.remove(&h);
                break;
            }
            matched.push((h, blocks));
            parent = h;
        }
        // injected prefix-attach fault: the lookup degrades to a miss
        // (the request re-prefills; correctness is unaffected)
        if !matched.is_empty()
            && self.injected_fault(FaultSite::PrefixAttach).is_some()
        {
            matched.clear();
        }
        self.begin_seq(id)?;
        if matched.is_empty() {
            return Ok(0);
        }
        let shared = matched.len() * BLOCK_TOKENS;
        for (layer, cache) in self.caches.iter_mut().enumerate() {
            let ids_l: Vec<BlockId> =
                matched.iter().map(|(_, bs)| bs[layer]).collect();
            cache
                .attach_prefix(id, &ids_l, shared)
                .expect("attach_prefix on a just-created sequence");
        }
        for (h, _) in &matched {
            self.prefix.entries.get_mut(h).unwrap().holders += 1;
        }
        self.prefix
            .held
            .insert(id, matched.iter().map(|(h, _)| *h).collect());
        self.seqs.get_mut(&id).unwrap().pos = shared;
        Ok(shared)
    }

    /// Publish a sequence's full prompt blocks into the prefix index
    /// (called by the scheduler once the prompt has fully prefilled).
    /// Only whole blocks are registered — they are immutable from here
    /// on because appends always target a fresh block at a block
    /// boundary. Existing matching entries are left alone; a chain-hash
    /// collision with different tokens stops registration at that block.
    pub fn register_prefix(&mut self, id: SeqId, tokens: &[u32]) {
        if !self.prefix_cache || !self.seqs.contains_key(&id) {
            return;
        }
        let n_full = tokens.len() / BLOCK_TOKENS;
        let mut parent = 0u64;
        let mut fresh: Vec<(u64, Vec<u32>, Vec<BlockId>)> = Vec::new();
        for i in 0..n_full {
            let toks =
                &tokens[i * BLOCK_TOKENS..(i + 1) * BLOCK_TOKENS];
            let h = chain_hash(parent, toks);
            match self.prefix.entries.get(&h) {
                Some(e) => {
                    if e.tokens != toks {
                        break; // collision: leave the chain here
                    }
                }
                None => {
                    let blocks: Vec<BlockId> = self
                        .caches
                        .iter()
                        .map(|c| c.seq_block_ids(id).unwrap()[i])
                        .collect();
                    fresh.push((h, toks.to_vec(), blocks));
                }
            }
            parent = h;
        }
        if fresh.is_empty() {
            return;
        }
        // stamp each entry's content checksum while the blocks are
        // provably untouched (they were just prefilled)
        let fresh: Vec<(u64, Vec<u32>, Vec<BlockId>, u64)> = fresh
            .into_iter()
            .map(|(h, toks, blocks)| {
                let ck = self.prefix_block_checksum(&blocks);
                (h, toks, blocks, ck)
            })
            .collect();
        let held = self.prefix.held.entry(id).or_default();
        for (h, toks, blocks, checksum) in fresh {
            self.prefix.entries.insert(
                h,
                PrefixEntry { tokens: toks, blocks, holders: 1, checksum },
            );
            held.push(h);
        }
    }

    /// One prefix entry's content checksum: each layer's physical
    /// block chained through FNV-1a in layer order.
    fn prefix_block_checksum(&self, blocks: &[BlockId]) -> u64 {
        self.caches
            .iter()
            .zip(blocks)
            .fold(0xcbf29ce484222325, |h, (c, &b)| c.block_checksum(b, h))
    }

    /// Drop a sequence's stake in the prefix index; entries with no
    /// remaining holder are removed (their blocks may be about to go
    /// back to the pool).
    fn detach_prefix(&mut self, id: SeqId) {
        let Some(hashes) = self.prefix.held.remove(&id) else {
            return;
        };
        for h in hashes {
            if let Some(e) = self.prefix.entries.get_mut(&h) {
                e.holders -= 1;
                if e.holders == 0 {
                    self.prefix.entries.remove(&h);
                }
            }
        }
    }

    /// Shared-prefix entries currently indexed (test observability).
    pub fn prefix_entries(&self) -> usize {
        self.prefix.entries.len()
    }

    /// Move a live sequence's cache content (every layer) to the
    /// host-side spill store and free its blocks — the tiered-KV
    /// alternative to dropping a preemption victim. Its decode state is
    /// parked alongside, so [`Engine::swap_in`] resumes bit-identically.
    pub fn swap_out(&mut self, id: SeqId) -> anyhow::Result<()> {
        if self.swapped_meta.contains_key(&id) {
            bail!("sequence {id} is already swapped out");
        }
        if self.injected_fault(FaultSite::SwapOut).is_some() {
            // before any state moves: the caller's fallback (drop the
            // victim and re-prefill later) sees a clean sequence
            bail!("{}", CacheError::Injected("swap_out"));
        }
        let spill_bytes = self.seq_spill_bytes(id);
        let meta = self
            .seqs
            .remove(&id)
            .with_context(|| format!("unknown seq {id}"))?;
        self.detach_prefix(id);
        for c in self.caches.iter_mut() {
            c.swap_out(id).map_err(|e| anyhow::anyhow!("swap_out: {e}"))?;
        }
        self.swapped_meta.insert(id, meta);
        self.metrics.inc(Ctr::SwapOuts, 1);
        self.metrics.inc(Ctr::SwapBytesOut, spill_bytes as u64);
        Ok(())
    }

    /// Restore a swapped-out sequence into fresh blocks in every layer.
    /// [`CacheError::OutOfBlocks`] (spill entry kept) when it doesn't
    /// fit right now — the scheduler retries or falls back to
    /// re-prefill.
    pub fn swap_in(&mut self, id: SeqId) -> Result<(), CacheError> {
        if !self.swapped_meta.contains_key(&id) {
            return Err(CacheError::UnknownSeq(id));
        }
        if self.injected_fault(FaultSite::SwapIn).is_some() {
            // drop the parked state so the scheduler's fallback (clear
            // the swapped flag, re-prefill) leaves nothing behind
            self.purge_swapped(id);
            return Err(CacheError::Injected("swap_in"));
        }
        // max across layers: per-layer pruning thresholds can leave
        // layers with different survivor counts (hence block counts)
        let need = self
            .caches
            .iter()
            .map(|c| c.swapped_blocks(id))
            .max()
            .unwrap_or(0);
        if self.free_blocks() < need {
            return Err(CacheError::OutOfBlocks);
        }
        for layer in 0..self.caches.len() {
            if let Err(e) = self.caches[layer].swap_in(id) {
                for l in 0..layer {
                    let _ = self.caches[l].swap_out(id);
                }
                if matches!(e, CacheError::Corrupt(_)) {
                    // never restore a poisoned slab; the whole spill
                    // entry dies and the sequence re-prefills
                    self.metrics.inc(Ctr::ChecksumFailures, 1);
                    self.purge_swapped(id);
                }
                return Err(e);
            }
        }
        let meta = self.swapped_meta.remove(&id).unwrap();
        self.seqs.insert(id, meta);
        self.metrics.inc(Ctr::SwapIns, 1);
        // Same byte model (and same pos) as the matching swap-out, so
        // bytes-in totals mirror bytes-out across a spill round trip.
        self.metrics
            .inc(Ctr::SwapBytesIn, self.seq_spill_bytes(id) as u64);
        Ok(())
    }

    /// Drop every layer's spill entry and the parked decode state —
    /// the sequence must re-prefill from tokens.
    fn purge_swapped(&mut self, id: SeqId) {
        self.swapped_meta.remove(&id);
        for c in self.caches.iter_mut() {
            c.drop_swapped(id);
        }
    }

    /// Chaos-test instrumentation: corrupt the spill entries backing a
    /// swapped sequence so the next swap-in fails its checksum.
    pub fn corrupt_swapped(&mut self, id: SeqId) -> bool {
        let mut any = false;
        for c in self.caches.iter_mut() {
            any |= c.corrupt_swapped(id);
        }
        any
    }

    /// Whether a sequence currently lives in the spill store.
    pub fn is_swapped(&self, id: SeqId) -> bool {
        self.swapped_meta.contains_key(&id)
    }

    /// Blocks per layer a swapped sequence needs at swap-in (0 if not
    /// swapped; the max across layers, since per-layer pruning can
    /// leave layers holding different survivor counts).
    pub fn swapped_blocks(&self, id: SeqId) -> usize {
        self.caches
            .iter()
            .map(|c| c.swapped_blocks(id))
            .max()
            .unwrap_or(0)
    }

    /// Estimated spill-store bytes for swapping a live sequence out,
    /// under the paper's byte model (codes 1 B, raw elements 2 B) —
    /// the recompute-vs-swap cost model's copy-side input.
    pub fn seq_spill_bytes(&self, id: SeqId) -> usize {
        // per-layer lengths (not pos): pruning drops tokens per layer,
        // and the all-heads byte helpers price heterogeneous per-head m
        self.caches
            .iter()
            .map(|c| {
                c.seq_len(id).unwrap_or(0)
                    * (c.key_bytes_per_token_all_heads()
                        + c.value_bytes_per_token_all_heads())
            })
            .sum()
    }

    /// The layer-0 physical block ids backing a sequence (all layers
    /// are symmetric) — sharing observability for tests and reports.
    pub fn seq_block_ids(&self, id: SeqId) -> Vec<BlockId> {
        self.caches[0]
            .seq_block_ids(id)
            .map(|b| b.to_vec())
            .unwrap_or_default()
    }

    /// Admit a sequence with a monolithic prefill (the whole prompt as
    /// one span through the backend kernel). Rolls back cleanly on
    /// cache exhaustion so the caller can retry later.
    pub fn start_seq(&mut self, id: SeqId, prompt: &[u32])
        -> Result<(), EngineError>
    {
        assert!(!prompt.is_empty(), "empty prompt");
        self.begin_seq(id)?;
        match self.step_batch(&[TickEntry::Prefill {
            seq: id,
            tokens: prompt,
        }]) {
            Ok(_) => Ok(()),
            Err(e) => {
                // no residue: drop the registered (possibly partially
                // filled) sequence entirely
                let _ = self.release(id);
                match e.downcast_ref::<CacheError>() {
                    Some(ce) => Err(EngineError::Cache(ce.clone())),
                    // non-cache failures (position overflow, kernel
                    // faults) used to panic the serving thread here;
                    // typed, the scheduler quarantines this one
                    // sequence and keeps serving the rest
                    None => Err(EngineError::Fault {
                        seq: id,
                        msg: format!("{e:#}"),
                    }),
                }
            }
        }
    }

    /// Generate one token for a sequence (greedy): a batch of one.
    pub fn decode_one(&mut self, id: SeqId) -> anyhow::Result<u32> {
        Ok(self.decode_batch(&[id])?[0])
    }

    /// One decode tick for a batch of sequences: every sequence gets one
    /// greedy token appended to its cache (a [`Engine::step_batch`] of
    /// all-decode entries).
    pub fn decode_batch(&mut self, ids: &[SeqId])
        -> anyhow::Result<Vec<u32>>
    {
        if ids.is_empty() {
            return Ok(Vec::new());
        }
        let entries: Vec<TickEntry<'_>> =
            ids.iter().map(|&id| TickEntry::Decode(id)).collect();
        let outcomes = self.step_batch(&entries)?;
        Ok(outcomes
            .into_iter()
            .map(|o| o.token.expect("decode entry produces a token"))
            .collect())
    }

    /// One mixed serving tick: decode entries produce one greedy token
    /// each, prefill entries push their chunk's K/V into the cache and
    /// advance the sequence's hidden state. Per layer and per entry
    /// group, the tick runs four stages — batched QKV GEMM, serial
    /// cache appends, one [`DecodePlan`] through the backend kernel,
    /// and the batched attn-out/MLP GEMM tail — either serially or on
    /// the software-pipelined two-group schedule
    /// ([`EngineConfig::pipeline`]). Rows never interact, so each
    /// sequence's result is bit-identical to processing it alone — and
    /// to any other chunking of the same tokens, and to the other
    /// pipeline setting.
    pub fn step_batch(&mut self, entries: &[TickEntry<'_>])
        -> anyhow::Result<Vec<TickOutcome>>
    {
        if entries.is_empty() {
            return Ok(Vec::new());
        }
        let (h, d_k) = (self.model.n_head(), self.model.d_head());
        let max_pos = self.model.weights.config.max_pos;

        // validate the tick before touching any state
        let mut seen = std::collections::HashSet::new();
        for e in entries {
            let id = e.seq();
            if !seen.insert(id) {
                bail!("sequence {id} appears twice in one tick");
            }
            let meta = self
                .seqs
                .get(&id)
                .with_context(|| format!("unknown seq {id}"))?;
            match e {
                TickEntry::Decode(_) => {
                    if meta.last_hidden.is_empty() {
                        bail!(
                            "sequence {id} is still prefilling \
                             (no hidden state to decode from)"
                        );
                    }
                }
                TickEntry::Prefill { tokens, .. } => {
                    if tokens.is_empty() {
                        bail!("empty prefill chunk for sequence {id}");
                    }
                }
            }
            if meta.pos + e.span() > max_pos {
                bail!(
                    "sequence {id} would exceed max position {max_pos}"
                );
            }
        }

        // injected allocator failure: the same typed signal as a real
        // exhausted pool, so schedulers exercise their preempt/retry
        // path without actually shrinking the budget
        if self.injected_fault(FaultSite::Alloc).is_some() {
            return Err(anyhow::Error::new(CacheError::OutOfBlocks)
                .context("injected allocation failure"));
        }

        // pre-flight the tick's block demand so a mid-batch OutOfBlocks
        // can't leave some sequences' caches ahead of their SeqMeta.
        // The error is typed (CacheError::OutOfBlocks) so the scheduler
        // can react by preempting instead of failing the request.
        for (layer, cache) in self.caches.iter().enumerate() {
            let mut need = 0usize;
            for e in entries {
                let len = cache
                    .seq_len(e.seq())
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                need += (len + e.span()).div_ceil(BLOCK_TOKENS)
                    - len.div_ceil(BLOCK_TOKENS);
            }
            let s = cache.stats();
            if need > s.blocks_total - s.blocks_allocated {
                return Err(anyhow::Error::new(CacheError::OutOfBlocks)
                    .context(format!(
                        "tick needs {need} new cache blocks in layer \
                         {layer} (free: {})",
                        s.blocks_total - s.blocks_allocated
                    )));
            }
        }

        // Telemetry inputs, taken while positions are still pre-tick:
        // a query row at position p attends p+1 cached tokens, so the
        // tick's ADC scan traffic (key codes + value payload, every
        // layer and head) is derivable without touching the kernels —
        // the live compute-vs-memory-bound signal.
        let (mut decode_toks, mut prefill_toks) = (0u64, 0u64);
        let mut attended = 0usize;
        for e in entries {
            let pos0 = self.seqs[&e.seq()].pos;
            let s = e.span();
            attended += s * pos0 + s * (s + 1) / 2;
            match e {
                TickEntry::Decode(_) => decode_toks += 1,
                TickEntry::Prefill { .. } => prefill_toks += s as u64,
            }
        }
        // summed per cache: calibrated policies give layers different
        // bytes/token, and the all-heads helpers price per-head m.
        // Under pruning this is an upper bound (positions, not
        // survivors) — acceptable for a traffic signal.
        let scan_bytes = (attended
            * self
                .caches
                .iter()
                .map(|c| {
                    c.key_bytes_per_token_all_heads()
                        + c.value_bytes_per_token_all_heads()
                })
                .sum::<usize>()) as u64;

        // row bookkeeping: entry i owns flat rows
        // entry_row0[i] .. entry_row0[i] + span_i
        let spans: Vec<usize> = entries.iter().map(|e| e.span()).collect();
        let total_rows: usize = spans.iter().sum();
        let mut entry_row0 = Vec::with_capacity(entries.len());
        let mut acc_rows = 0usize;
        for &s in &spans {
            entry_row0.push(acc_rows);
            acc_rows += s;
        }

        // greedy next-token picks + embeddings per entry
        let model = &self.model;
        let seqs = &self.seqs;
        let picks: Vec<(Option<u32>, Vec<Vec<f32>>)> =
            parallel_map(entries.len(), self.threads, |i| {
                match &entries[i] {
                    TickEntry::Decode(id) => {
                        let meta = &seqs[id];
                        let tok = model.greedy_next(&meta.last_hidden);
                        (Some(tok), vec![model.embed(tok, meta.pos)])
                    }
                    TickEntry::Prefill { seq, tokens } => {
                        let meta = &seqs[seq];
                        let embeds = tokens
                            .iter()
                            .enumerate()
                            .map(|(r, &t)| model.embed(t, meta.pos + r))
                            .collect();
                        (None, embeds)
                    }
                }
            });
        let mut picked_tokens = Vec::with_capacity(entries.len());
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(total_rows);
        for (tok, embeds) in picks {
            picked_tokens.push(tok);
            xs.extend(embeds);
        }

        // ---- layer execution: serial, or software-pipelined over two
        // entry groups. Per-row math is identical either way (and
        // identical to the pre-pipeline engine): the stages run the
        // same float ops per row regardless of grouping, and appends
        // land in entry order per layer.
        let n_layer = self.model.n_layer();
        let model = &self.model;
        let caches = &mut self.caches;
        let kernel = &mut self.kernel;
        let timers = &self.timers;
        let threads = self.threads;
        let pool = threadpool::global();
        let sp = scratch();

        let use_pipeline =
            self.pipeline && threads > 1 && entries.len() >= 2;
        if use_pipeline {
            // contiguous split balanced by row count (prefill chunks
            // are heavy); A = first entries, so per-layer append order
            // (A then B) matches the serial path exactly
            let mut mid = entries.len() / 2;
            let mut seen = 0usize;
            for (i, &s) in spans.iter().enumerate() {
                seen += s;
                if seen * 2 >= total_rows {
                    mid = (i + 1).min(entries.len() - 1);
                    break;
                }
            }
            let mid = mid.max(1);
            let (ents_a, ents_b) = (&entries[..mid], &entries[mid..]);
            let (spans_a, spans_b) = spans.split_at(mid);
            let rows_a: usize = spans_a.iter().sum();
            let mut xs_b = xs.split_off(rows_a);

            // prologue: group A's layer-0 projections + appends
            let mut qkv_a = stage_qkv(model, timers, 0, &xs, threads);
            let mut pfx_a = stage_append(
                &mut caches[0], ents_a, spans_a, &qkv_a, h * d_k)?;
            for layer in 0..n_layer {
                // overlap 1: A attends layer l ∥ B projects layer l
                let (res_a, qkv_b) = pool.overlap(
                    || stage_qkv(model, timers, layer, &xs_b, threads),
                    || {
                        stage_attend(
                            &mut **kernel, &caches[layer], timers,
                            ents_a, spans_a, &pfx_a, &qkv_a, threads,
                            h, d_k,
                        )
                    },
                );
                let outs_a = res_a?;
                sp.put_f32(std::mem::take(&mut qkv_a));
                // overlap 2: A's MLP tail ∥ B's serial cache appends
                let pfx_b;
                {
                    let xs_a = &mut xs;
                    let (append_res, ()) = pool.overlap(
                        move || {
                            stage_tail(
                                model, timers, layer, spans_a, outs_a,
                                xs_a, threads, h, d_k,
                            )
                        },
                        || {
                            stage_append(
                                &mut caches[layer], ents_b, spans_b,
                                &qkv_b, h * d_k,
                            )
                        },
                    );
                    pfx_b = append_res?;
                }
                if layer + 1 < n_layer {
                    // overlap 3: B attends layer l ∥ A projects l+1
                    let (res_b, q_next) = pool.overlap(
                        || {
                            stage_qkv(
                                model, timers, layer + 1, &xs, threads,
                            )
                        },
                        || {
                            stage_attend(
                                &mut **kernel, &caches[layer], timers,
                                ents_b, spans_b, &pfx_b, &qkv_b,
                                threads, h, d_k,
                            )
                        },
                    );
                    let outs_b = res_b?;
                    qkv_a = q_next;
                    // overlap 4: B's MLP tail ∥ A's appends for l+1
                    let xs_b_ref = &mut xs_b;
                    let (append_res, ()) = pool.overlap(
                        move || {
                            stage_tail(
                                model, timers, layer, spans_b, outs_b,
                                xs_b_ref, threads, h, d_k,
                            )
                        },
                        || {
                            stage_append(
                                &mut caches[layer + 1], ents_a,
                                spans_a, &qkv_a, h * d_k,
                            )
                        },
                    );
                    pfx_a = append_res?;
                } else {
                    let outs_b = stage_attend(
                        &mut **kernel, &caches[layer], timers, ents_b,
                        spans_b, &pfx_b, &qkv_b, threads, h, d_k,
                    )?;
                    stage_tail(
                        model, timers, layer, spans_b, outs_b,
                        &mut xs_b, threads, h, d_k,
                    );
                }
                sp.put_f32(qkv_b);
            }
            sp.put_f32(qkv_a);
            xs.append(&mut xs_b);
        } else {
            for layer in 0..n_layer {
                let qkv = stage_qkv(model, timers, layer, &xs, threads);
                let pfx = stage_append(
                    &mut caches[layer], entries, &spans, &qkv, h * d_k,
                )?;
                let outs = stage_attend(
                    &mut **kernel, &caches[layer], timers, entries,
                    &spans, &pfx, &qkv, threads, h, d_k,
                )?;
                stage_tail(
                    model, timers, layer, &spans, outs, &mut xs,
                    threads, h, d_k,
                );
                sp.put_f32(qkv);
            }
        }

        for (i, e) in entries.iter().enumerate() {
            let meta = self.seqs.get_mut(&e.seq()).unwrap();
            meta.pos += spans[i];
            let last = entry_row0[i] + spans[i] - 1;
            let old = std::mem::replace(
                &mut meta.last_hidden,
                std::mem::take(&mut xs[last]),
            );
            sp.put_f32(old);
        }
        // recycle the non-last hidden rows too — a prefill chunk
        // leaves spans-1 pooled buffers per entry (the taken last rows
        // are empty and skipped by put_f32)
        for x in xs {
            sp.put_f32(x);
        }
        self.publish_tick(decode_toks, prefill_toks, scan_bytes);
        Ok(entries
            .iter()
            .enumerate()
            .map(|(i, e)| TickOutcome {
                seq: e.seq(),
                token: picked_tokens[i],
            })
            .collect())
    }

    /// Release a finished (or preempted) sequence's cache — live blocks
    /// or spill-store entry, whichever it holds. The storage codecs are
    /// untouched — a preempted sequence later re-prefills by re-encoding
    /// codes only. Shared prefix blocks merely lose this holder.
    pub fn release(&mut self, id: SeqId) -> anyhow::Result<()> {
        self.detach_prefix(id);
        if self.seqs.remove(&id).is_none() {
            if self.swapped_meta.remove(&id).is_some() {
                for c in self.caches.iter_mut() {
                    c.drop_swapped(id);
                }
                return Ok(());
            }
            bail!("unknown seq {id}");
        }
        for c in self.caches.iter_mut() {
            c.free_seq(id).map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        Ok(())
    }

    /// End-of-tick registry publish: token/scan counters, phase-timer
    /// deltas, and cache/swap/arena pressure gauges. Pure observation —
    /// relaxed atomics plus one uncontended mutex, no allocation.
    fn publish_tick(
        &self,
        decode_tokens: u64,
        prefill_tokens: u64,
        scan_bytes: u64,
    ) {
        let m = &self.metrics;
        m.inc(Ctr::Ticks, 1);
        m.inc(Ctr::DecodeTokens, decode_tokens);
        m.inc(Ctr::PrefillTokens, prefill_tokens);
        m.inc(Ctr::ScanBytes, scan_bytes);

        // pruning-policy drops since the last publish (all layers)
        let pruned = self.pruned_tokens();
        let prev = self.last_pruned.swap(pruned, Ordering::Relaxed);
        m.inc(Ctr::PrunedTokens, pruned.saturating_sub(prev));

        // Phase work since the previous publish. A concurrent
        // `take_phase_times` resets both the accumulators and the
        // baseline, so deltas are clamped at zero rather than wrapping.
        let snap = self.timers.snapshot();
        {
            let mut last = self.last_phases.lock().unwrap();
            let d = |now: f64, prev: f64| ((now - prev).max(0.0) * 1e9) as u64;
            m.inc(Ctr::PhaseLutBuildNs, d(snap.lut_build_s, last.lut_build_s));
            m.inc(Ctr::PhaseScanNs, d(snap.scan_s, last.scan_s));
            m.inc(
                Ctr::PhaseValueDecodeNs,
                d(snap.value_decode_s, last.value_decode_s),
            );
            m.inc(Ctr::PhaseQkvNs, d(snap.qkv_s, last.qkv_s));
            m.inc(Ctr::PhaseMlpNs, d(snap.mlp_s, last.mlp_s));
            *last = snap;
        }

        // Cache pressure (layer 0; all layers are symmetric).
        let s = self.caches[0].stats();
        m.set(Gauge::BlocksTotal, s.blocks_total as u64);
        m.set(Gauge::BlocksUsed, s.blocks_allocated as u64);
        m.set(
            Gauge::BlocksFree,
            (s.blocks_total - s.blocks_allocated) as u64,
        );
        m.set(Gauge::SharedBlocks, s.shared_blocks as u64);
        m.set(Gauge::KeyCacheBytes, s.key_bytes as u64);
        m.set(Gauge::ValueCacheBytes, s.value_bytes as u64);
        m.set(Gauge::SwappedSeqs, self.swapped_meta.len() as u64);
        let swap_resident: usize =
            self.caches.iter().map(|c| c.swap_bytes()).sum();
        m.set(Gauge::SwapResidentBytes, swap_resident as u64);

        // Scratch arena (the process-wide pool the tick stages lease
        // from) — makes a broken zero-allocation steady state visible.
        let a = scratch().arena_stats();
        m.set(Gauge::ScratchLeases, a.leases as u64);
        m.set(Gauge::ScratchFresh, a.fresh as u64);
        m.set(Gauge::ScratchZeroed, a.zeroed as u64);
        m.set(Gauge::ScratchHeldBytes, a.held_bytes as u64);
        m.set(Gauge::ScratchPeakBytes, a.peak_bytes as u64);
    }
}

// ---- policy resolution helpers -----------------------------------------

/// Candidate subspace counts for the calibrated policy: halve, keep or
/// double the backend's base m, clipped to divisors of d_k. The 3-wide
/// ladder bounds codebook training at 3× the uniform cost while still
/// letting sensitive heads take bits from insensitive ones.
fn candidate_ms(d_k: usize, base: usize) -> Vec<usize> {
    [base / 2, base, base * 2]
        .into_iter()
        .filter(|&m| m >= 1 && m <= d_k && d_k % m == 0)
        .collect()
}

/// Bits per stored code for a K-centroid codebook (⌈log2 K⌉).
fn code_bits(k: usize) -> usize {
    (usize::BITS - (k - 1).leading_zeros()) as usize
}

/// Spearman-ρ estimate of one head's key-score fidelity: calibration
/// keys double as probe queries, scored exactly and through the
/// codec's reconstruction against up to 128 calibration keys. A cheap
/// build-time proxy for the paper's serving-path rho (reported per
/// (layer, head) in [`PolicySummary`]), not a replacement for the
/// paper_fidelity suite.
fn estimate_rho(keys: &[f32], codec: &PqCodec, d_k: usize) -> f64 {
    let n = (keys.len() / d_k).min(128);
    if n < 8 {
        return 1.0;
    }
    let recon: Vec<Vec<f32>> = (0..n)
        .map(|t| {
            let k = &keys[t * d_k..(t + 1) * d_k];
            codec.decode(&codec.encode(k))
        })
        .collect();
    let probes = [0, n / 3, (2 * n) / 3, n - 1];
    let mut sum = 0.0f64;
    for &p in &probes {
        let q = &keys[p * d_k..(p + 1) * d_k];
        let exact: Vec<f64> = (0..n)
            .map(|t| {
                crate::tensor::dot(q, &keys[t * d_k..(t + 1) * d_k])
                    as f64
            })
            .collect();
        let approx: Vec<f64> = recon
            .iter()
            .map(|r| crate::tensor::dot(q, r) as f64)
            .collect();
        sum += crate::metrics::spearman_rho(&exact, &approx);
    }
    sum / probes.len() as f64
}

// ---- tick stages -------------------------------------------------------
//
// One serving tick decomposes, per layer and per entry group, into
// three stages with fixed data flow:
//
//   qkv(g, l)        pure compute: LN1 + batched QKV GEMM over the
//                    group's rows (weights stream once per row chunk,
//                    not once per row — the batched-GEMM refactor)
//   append(g, l)     serial cache mutation, entry order within group
//   attend+tail(g,l) kernel plan over the group's (seq, head) items,
//                    then batched attn-out/MLP GEMMs -> next hidden
//
// The pipelined executor interleaves two groups with a one-stage skew
// (A attends l while B projects l; B attends l while A projects l+1);
// the serial executor is the single-group degenerate case. Rows never
// interact inside any stage, so grouping cannot change results.

/// LN1 + batched QKV projection for one group — the `qkv` phase.
fn stage_qkv(
    model: &Gpt2,
    timers: &PhaseTimers,
    layer: usize,
    xs: &[Vec<f32>],
    threads: usize,
) -> Vec<f32> {
    timed(Some(timers), Phase::Qkv, || {
        model.qkv_rows(layer, xs, threads)
    })
}

/// Append one group's K/V rows to a layer cache, entry order then row
/// order — identical append order to the pre-pipeline engine. Returns
/// each row's causal prefix (the sequence's length right after its
/// append attempt), flat in group row order: with pruning off this
/// equals the classic `seq_len - rows + r + 1` derivation; with
/// pruning on, skipped appends leave the length unchanged and the
/// attention stage must score against the survivor counts instead.
fn stage_append(
    cache: &mut KvCache,
    entries: &[TickEntry<'_>],
    spans: &[usize],
    qkv: &[f32],
    d: usize,
) -> anyhow::Result<Vec<usize>> {
    let mut prefixes =
        Vec::with_capacity(spans.iter().sum::<usize>());
    let mut r = 0usize;
    for (e, &s) in entries.iter().zip(spans) {
        let id = e.seq();
        for _ in 0..s {
            let base = r * 3 * d;
            cache
                .append(
                    id,
                    &qkv[base + d..base + 2 * d],
                    &qkv[base + 2 * d..base + 3 * d],
                )
                .map_err(|e| anyhow::anyhow!("cache append: {e}"))?;
            prefixes.push(
                cache
                    .seq_len(id)
                    .map_err(|e| anyhow::anyhow!("cache append: {e}"))?,
            );
            r += 1;
        }
    }
    Ok(prefixes)
}

/// Attention for one group and layer: build the (seq, head) span plan
/// from the group's query rows and run the backend kernel. Returns the
/// kernel's per-(item, row) outputs; query staging cycles through the
/// arena.
#[allow(clippy::too_many_arguments)]
fn stage_attend(
    kernel: &mut dyn AttentionKernel,
    cache: &KvCache,
    timers: &PhaseTimers,
    entries: &[TickEntry<'_>],
    spans: &[usize],
    prefixes: &[usize],
    qkv: &[f32],
    threads: usize,
    h: usize,
    d_k: usize,
) -> anyhow::Result<Vec<AttnOutput>> {
    let d = h * d_k;
    let pool = scratch();
    let group_rows: usize = spans.iter().sum();
    // span query buffers, head-major per entry so each item's rows are
    // contiguous: (H, span, d_k)
    let mut qbufs: Vec<Vec<f32>> = Vec::with_capacity(entries.len());
    let mut r0 = 0usize;
    for &s in spans {
        let mut buf = pool.take_f32_any(h * s * d_k);
        for r in 0..s {
            let q = &qkv[(r0 + r) * 3 * d..(r0 + r) * 3 * d + d];
            for head in 0..h {
                let dst = (head * s + r) * d_k;
                buf[dst..dst + d_k]
                    .copy_from_slice(&q[head * d_k..(head + 1) * d_k]);
            }
        }
        qbufs.push(buf);
        r0 += s;
    }
    // the group's plan: (seq, head) span items, seq-major with
    // ascending heads (the kernel contract); each item carries its
    // rows' append-time prefixes so pruned tokens are never scored
    let mut items = Vec::with_capacity(entries.len() * h);
    let mut e_r0 = 0usize;
    for (i, e) in entries.iter().enumerate() {
        let s = spans[i];
        for head in 0..h {
            items.push(WorkItem {
                seq: e.seq(),
                head,
                q: &qbufs[i][head * s * d_k..(head + 1) * s * d_k],
                rows: s,
                prefixes: Some(&prefixes[e_r0..e_r0 + s]),
            });
        }
        e_r0 += s;
    }
    let plan = DecodePlan {
        cache,
        d_k,
        threads,
        timers: Some(timers),
        items,
    };
    let outs = kernel.decode_batch(&plan)?;
    drop(plan);
    for b in qbufs {
        pool.put_f32(b);
    }
    if outs.len() != group_rows * h {
        bail!(
            "kernel returned {} outputs for {} work rows",
            outs.len(),
            group_rows * h
        );
    }
    Ok(outs)
}

/// Head-concat + batched residual/MLP tail for one group — the `mlp`
/// phase. Replaces each row of `xs` with its next-layer hidden state;
/// the kernel outputs and all staging cycle back through the arena.
#[allow(clippy::too_many_arguments)]
fn stage_tail(
    model: &Gpt2,
    timers: &PhaseTimers,
    layer: usize,
    spans: &[usize],
    outs: Vec<AttnOutput>,
    xs: &mut Vec<Vec<f32>>,
    threads: usize,
    h: usize,
    d_k: usize,
) {
    let d = h * d_k;
    let pool = scratch();
    let group_rows: usize = spans.iter().sum();
    // per-entry offset into the item-major output stream
    let mut out_base = Vec::with_capacity(spans.len());
    let mut acc = 0usize;
    for &s in spans {
        out_base.push(acc);
        acc += h * s;
    }
    // concat heads into a (rows × d_model) staging buffer
    let mut attn = pool.take_f32_any(group_rows * d);
    let mut r = 0usize;
    for (i, &s) in spans.iter().enumerate() {
        for local in 0..s {
            let arow = &mut attn[r * d..(r + 1) * d];
            for head in 0..h {
                arow[head * d_k..(head + 1) * d_k].copy_from_slice(
                    &outs[out_base[i] + head * s + local].out,
                );
            }
            r += 1;
        }
    }
    // recycle the kernel's pooled output buffers
    for o in outs {
        pool.put_f32(o.out);
        pool.put_f32(o.weights);
    }
    let next = timed(Some(timers), Phase::Mlp, || {
        model.finish_block_rows(layer, xs, &attn, threads)
    });
    pool.put_f32(attn);
    for old in std::mem::replace(xs, next) {
        pool.put_f32(old);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ByteTokenizer;

    fn tiny_cfg(backend: AttentionBackend) -> EngineConfig {
        EngineConfig {
            model: ModelConfig::test_tiny(),
            backend,
            value_backend: ValueBackend::Fp32,
            seed: 1,
            cache_blocks: 32,
            calib_tokens: 96,
            decode_threads: 2,
            prefill_chunk: 0,
            pipeline: true,
            prefix_cache: false,
            policy: CompressionPolicy::Uniform,
            faults: Default::default(),
        }
    }

    #[test]
    fn fp16_engine_generates_deterministically() {
        let mut e = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        let ids = ByteTokenizer::new().encode("hello engine");
        e.start_seq(1, &ids).unwrap();
        let toks: Vec<u32> =
            (0..8).map(|_| e.decode_one(1).unwrap()).collect();

        let mut e2 = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        e2.start_seq(9, &ids).unwrap();
        let toks2: Vec<u32> =
            (0..8).map(|_| e2.decode_one(9).unwrap()).collect();
        assert_eq!(toks, toks2);
    }

    #[test]
    fn engine_decode_matches_reference_model() {
        // Engine Fp16Exact must reproduce Gpt2::decode_step exactly —
        // including its prefill, which now rides the fp16 kernel but
        // performs the identical float ops in the identical order
        let cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        let mut e = Engine::build(&cfg).unwrap();
        let ids = ByteTokenizer::new().encode("reference check");
        e.start_seq(1, &ids).unwrap();

        // reference: raw decode over Tensor2 caches
        let weights = Weights::random(&cfg.model, cfg.seed);
        let model = Gpt2::new(weights);
        let pre = model.prefill(&ids);
        let mut caches = pre.caches;
        let mut hidden = pre.last_hidden;
        let mut pos = ids.len();

        for _ in 0..5 {
            let tok_engine = e.decode_one(1).unwrap();
            let tok_ref = model.greedy_next(&hidden);
            assert_eq!(tok_engine, tok_ref);
            hidden = model.decode_step(tok_ref, pos, &mut caches);
            pos += 1;
        }
    }

    #[test]
    fn lookat_engine_decodes_deterministically() {
        // prefill rides the ADC kernel now, so the lookat engine's
        // whole trajectory (prefill included) is a pure function of
        // (seed, prompt) — two builds must agree bit for bit
        let ids = ByteTokenizer::new().encode(
            "the quick brown fox jumps over the lazy dog again and again");
        let cfg = tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 });
        let mut a = Engine::build(&cfg).unwrap();
        a.start_seq(1, &ids).unwrap();
        let t_a: Vec<u32> =
            (0..6).map(|_| a.decode_one(1).unwrap()).collect();
        let mut b = Engine::build(&cfg).unwrap();
        b.start_seq(2, &ids).unwrap();
        let t_b: Vec<u32> =
            (0..6).map(|_| b.decode_one(2).unwrap()).collect();
        assert_eq!(t_a, t_b);
    }

    // batched-vs-serial and chunked-vs-monolithic bit-parity per
    // backend live in tests/decode_parity.rs (they need full engine
    // builds per backend; no point paying for them twice in CI)

    #[test]
    fn mixed_tick_advances_decode_and_prefill_together() {
        // one tick carrying a decode entry and a prefill chunk must
        // advance both, and the interleaving must not change the
        // decoding sequence's tokens
        let cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        let tok = ByteTokenizer::new();
        let ids_a = tok.encode("sequence that decodes");
        let ids_b = tok.encode("sequence that prefills in chunks");

        let mut alone = Engine::build(&cfg).unwrap();
        alone.start_seq(1, &ids_a).unwrap();
        let alone_toks: Vec<u32> =
            (0..4).map(|_| alone.decode_one(1).unwrap()).collect();

        let mut mixed = Engine::build(&cfg).unwrap();
        mixed.start_seq(1, &ids_a).unwrap();
        mixed.begin_seq(2).unwrap();
        let mut toks = Vec::new();
        let mut off = 0usize;
        for _ in 0..4 {
            let mut entries = vec![TickEntry::Decode(1)];
            if off < ids_b.len() {
                let end = (off + 4).min(ids_b.len());
                entries.push(TickEntry::Prefill {
                    seq: 2,
                    tokens: &ids_b[off..end],
                });
                off = end;
            }
            let outs = mixed.step_batch(&entries).unwrap();
            toks.push(outs[0].token.unwrap());
            assert_eq!(outs[0].seq, 1);
            if outs.len() > 1 {
                assert!(outs[1].token.is_none());
            }
        }
        assert_eq!(alone_toks, toks);
        assert_eq!(mixed.seq_pos(2), Some(off));
    }

    #[test]
    fn decode_before_prefill_is_an_error() {
        let cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        let mut e = Engine::build(&cfg).unwrap();
        e.begin_seq(1).unwrap();
        let err = e.decode_batch(&[1]).unwrap_err().to_string();
        assert!(err.contains("prefilling"), "{err}");
    }

    #[test]
    fn admission_and_release_cycle() {
        let mut e = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        let ids = ByteTokenizer::new().encode("abc");
        assert!(e.can_admit(ids.len() + 4));
        e.start_seq(5, &ids).unwrap();
        assert_eq!(e.active_seqs(), 1);
        let _ = e.decode_one(5).unwrap();
        assert!(e.cache_stats().tokens > 0);
        assert!(e.seq_blocks(5) >= 1);
        e.release(5).unwrap();
        assert_eq!(e.active_seqs(), 0);
        assert_eq!(e.cache_stats().tokens, 0);
        assert_eq!(e.seq_blocks(5), 0);
    }

    #[test]
    fn cache_exhaustion_rolls_back_cleanly() {
        let mut cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        cfg.cache_blocks = 1; // 32 tokens only
        let mut e = Engine::build(&cfg).unwrap();
        let long: Vec<u32> = (0..100).map(|i| (i % 200) as u32).collect();
        assert!(e.start_seq(1, &long).is_err());
        // rollback: no partial residue
        assert_eq!(e.cache_stats().tokens, 0);
        assert_eq!(e.cache_stats().blocks_allocated, 0);
        // a short sequence still fits afterwards
        e.start_seq(2, &long[..16]).unwrap();
        assert_eq!(e.cache_stats().tokens, 16);
    }

    #[test]
    fn out_of_blocks_is_downcastable_from_step_batch() {
        // the scheduler's preemption trigger: a tick that outgrows the
        // block budget surfaces a typed CacheError, not a stringly one
        let mut cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        cfg.cache_blocks = 1;
        let mut e = Engine::build(&cfg).unwrap();
        e.begin_seq(1).unwrap();
        let long: Vec<u32> = (0..40).map(|i| i as u32).collect();
        let err = e
            .step_batch(&[TickEntry::Prefill { seq: 1, tokens: &long }])
            .unwrap_err();
        assert_eq!(
            err.downcast_ref::<CacheError>(),
            Some(&CacheError::OutOfBlocks)
        );
    }

    #[test]
    fn unknown_seq_errors() {
        let mut e = Engine::build(&tiny_cfg(AttentionBackend::Fp16Exact))
            .unwrap();
        assert!(e.decode_one(42).is_err());
        assert!(e.decode_batch(&[1, 42]).is_err());
        assert!(e.release(42).is_err());
        assert!(e.decode_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn scalar_quant_backend_runs() {
        let mut e = Engine::build(&tiny_cfg(
            AttentionBackend::ScalarQuant { bits: 8 })).unwrap();
        let ids = ByteTokenizer::new().encode("int8 path");
        e.start_seq(1, &ids).unwrap();
        for _ in 0..3 {
            e.decode_one(1).unwrap();
        }
    }

    #[test]
    fn backend_names() {
        assert_eq!(AttentionBackend::Fp16Exact.name(), "fp16");
        assert_eq!(AttentionBackend::Lookat { m: 4, k: 256 }.name(),
                   "lookat-4");
        assert_eq!(AttentionBackend::Lookat { m: 8, k: 16 }.name(),
                   "lookat-8+k16");
        assert_eq!(AttentionBackend::Lookat { m: 4, k: 64 }.name(),
                   "lookat-4+k64");
        assert_eq!(AttentionBackend::ScalarQuant { bits: 4 }.name(), "int4");
        assert_eq!(AttentionBackend::PjrtLookat { m: 2 }.name(),
                   "pjrt-lookat-2");
        assert_eq!(ValueBackend::Fp32.name(), "fp32");
        assert_eq!(ValueBackend::Pq { m: 8, k: 256 }.name(), "vpq-8");
        assert_eq!(ValueBackend::Pq { m: 8, k: 16 }.name(), "vpq-8+k16");
    }

    #[test]
    fn lookat_kv_engine_generates_and_compresses_values() {
        let mut cfg = tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 });
        cfg.value_backend = ValueBackend::Pq { m: 4, k: 64 };
        let mut e = Engine::build(&cfg).unwrap();
        assert_eq!(e.label(), "lookat-4+k64+vpq-4+k64");
        let ids = ByteTokenizer::new().encode("fully compressed serve");
        e.start_seq(1, &ids).unwrap();
        for _ in 0..4 {
            e.decode_one(1).unwrap();
        }
        let s = e.cache_stats();
        // value accounting reflects the PQ mode: m_v B/token/head
        assert_eq!(s.value_bytes, s.tokens * cfg.model.n_head * 4);
        e.release(1).unwrap();
    }

    #[test]
    fn pjrt_backend_rejects_pq_values() {
        let mut cfg = tiny_cfg(AttentionBackend::PjrtFp16);
        cfg.value_backend = ValueBackend::Pq { m: 4, k: 64 };
        let err = Engine::build(&cfg).unwrap_err().to_string();
        assert!(err.contains("PQ value storage"), "{err}");
    }

    #[test]
    fn pipelined_executor_bit_identical_to_serial_executor() {
        // --pipeline on|off is an A/B switch, never a semantic one: a
        // multi-sequence batch must decode identical tokens either way
        // (per-row math and per-layer append order are unchanged; only
        // stage scheduling differs)
        let tok = ByteTokenizer::new();
        let prompts =
            ["pipeline parity one", "two", "a third, longer prompt",
             "and four"];
        for backend in [
            AttentionBackend::Fp16Exact,
            AttentionBackend::Lookat { m: 4, k: 64 },
        ] {
            let mut on_cfg = tiny_cfg(backend.clone());
            on_cfg.pipeline = true;
            let mut off_cfg = tiny_cfg(backend);
            off_cfg.pipeline = false;
            let mut on = Engine::build(&on_cfg).unwrap();
            let mut off = Engine::build(&off_cfg).unwrap();
            assert!(on.pipeline_enabled());
            assert!(!off.pipeline_enabled());
            for (i, p) in prompts.iter().enumerate() {
                on.start_seq(i as u64, &tok.encode(p)).unwrap();
                off.start_seq(i as u64, &tok.encode(p)).unwrap();
            }
            let ids: Vec<u64> = (0..4).collect();
            for step in 0..5 {
                let a = on.decode_batch(&ids).unwrap();
                let b = off.decode_batch(&ids).unwrap();
                assert_eq!(a, b, "diverged at step {step}");
            }
        }
    }

    #[test]
    fn phase_times_cover_engine_and_kernel_stages() {
        let mut e = Engine::build(&tiny_cfg(
            AttentionBackend::Lookat { m: 4, k: 64 })).unwrap();
        let ids = ByteTokenizer::new().encode("phase probe prompt");
        e.start_seq(1, &ids).unwrap();
        e.start_seq(2, &ids).unwrap();
        let _ = e.take_phase_times(); // drop prefill's contribution
        for _ in 0..3 {
            e.decode_batch(&[1, 2]).unwrap();
        }
        let t = e.take_phase_times();
        assert!(t.qkv_s > 0.0, "qkv phase not booked");
        assert!(t.mlp_s > 0.0, "mlp phase not booked");
        assert!(t.lut_build_s > 0.0, "lut_build phase not booked");
        assert!(t.scan_s > 0.0, "scan phase not booked");
        assert!(t.value_decode_s > 0.0, "value_decode phase not booked");
        // drained: a second take reports a fresh window
        assert_eq!(e.take_phase_times().total_s(), 0.0);
    }

    #[test]
    fn swap_roundtrip_is_invisible_in_decode() {
        // park a decoding sequence in the spill store, churn the freed
        // blocks with another sequence, restore — the trajectory must
        // match an uninterrupted run bit for bit
        let cfg = tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 });
        let ids =
            ByteTokenizer::new().encode("swap roundtrip probe prompt");
        let mut plain = Engine::build(&cfg).unwrap();
        plain.start_seq(1, &ids).unwrap();
        let want: Vec<u32> =
            (0..6).map(|_| plain.decode_one(1).unwrap()).collect();

        let mut e = Engine::build(&cfg).unwrap();
        e.start_seq(1, &ids).unwrap();
        let mut got: Vec<u32> =
            (0..3).map(|_| e.decode_one(1).unwrap()).collect();
        e.swap_out(1).unwrap();
        assert!(e.is_swapped(1));
        assert!(e.swapped_blocks(1) > 0);
        assert_eq!(e.cache_stats().blocks_allocated, 0);
        assert!(e.decode_one(1).is_err(), "swapped seq can't decode");
        e.start_seq(2, &ids).unwrap();
        e.decode_one(2).unwrap();
        e.release(2).unwrap();
        e.swap_in(1).unwrap();
        assert!(!e.is_swapped(1));
        got.extend((0..3).map(|_| e.decode_one(1).unwrap()));
        assert_eq!(want, got);
        // releasing a swapped sequence drops the spill entry
        e.swap_out(1).unwrap();
        e.release(1).unwrap();
        assert!(!e.is_swapped(1));
        assert!(e.swap_in(1).is_err());
    }

    #[test]
    fn prefix_cache_shares_blocks_and_keeps_tokens_identical() {
        let tok = ByteTokenizer::new();
        let prefix = "shared system prompt ".repeat(5); // 105 tokens
        let p1 = tok.encode(&format!("{prefix}tail one"));
        let p2 = tok.encode(&format!("{prefix}tail two"));

        let mut cfg = tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 });
        cfg.prefix_cache = true;
        let mut e = Engine::build(&cfg).unwrap();
        assert_eq!(
            e.begin_seq_with_prefix(1, &p1).unwrap(),
            0,
            "cold index shares nothing"
        );
        e.step_batch(&[TickEntry::Prefill { seq: 1, tokens: &p1 }])
            .unwrap();
        e.register_prefix(1, &p1);
        assert_eq!(e.prefix_entries(), p1.len() / BLOCK_TOKENS);

        // second sequence with the same 105-token system prefix: its 3
        // leading full blocks attach instead of recomputing
        let shared = e.begin_seq_with_prefix(2, &p2).unwrap();
        assert_eq!(shared, 3 * BLOCK_TOKENS);
        assert_eq!(
            e.seq_block_ids(2)[..3],
            e.seq_block_ids(1)[..3],
            "physical blocks are shared"
        );
        assert_eq!(e.cache_stats().shared_blocks, 3);
        e.step_batch(&[TickEntry::Prefill {
            seq: 2,
            tokens: &p2[shared..],
        }])
        .unwrap();
        let got: Vec<u32> =
            (0..4).map(|_| e.decode_one(2).unwrap()).collect();

        // reference: the same prompt served without sharing
        let mut r = Engine::build(&cfg).unwrap();
        r.start_seq(2, &p2).unwrap();
        let want: Vec<u32> =
            (0..4).map(|_| r.decode_one(2).unwrap()).collect();
        assert_eq!(want, got, "shared-prefix decode diverged");

        // no leaks once every holder is gone
        e.release(1).unwrap();
        assert!(
            e.decode_one(2).is_ok(),
            "survivor keeps the shared blocks alive"
        );
        e.release(2).unwrap();
        assert_eq!(e.cache_stats().blocks_allocated, 0);
        assert_eq!(e.cache_stats().shared_blocks, 0);
        assert_eq!(e.prefix_entries(), 0);
    }

    #[test]
    fn value_pq_engine_is_deterministic_end_to_end() {
        // values-as-codes now shape the prefill output too (the fused
        // weighted decode serves prefill rows); the whole trajectory
        // must still be a pure function of (seed, prompt)
        let ids = ByteTokenizer::new().encode("value invariance probe");
        let mut cfg = tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 });
        cfg.value_backend = ValueBackend::Pq { m: 8, k: 64 };
        let mut a = Engine::build(&cfg).unwrap();
        a.start_seq(1, &ids).unwrap();
        let t_a: Vec<u32> =
            (0..5).map(|_| a.decode_one(1).unwrap()).collect();
        let mut b = Engine::build(&cfg).unwrap();
        b.start_seq(7, &ids).unwrap();
        let t_b: Vec<u32> =
            (0..5).map(|_| b.decode_one(7).unwrap()).collect();
        assert_eq!(t_a, t_b);
    }

    #[test]
    fn calibrated_policy_fits_budget_and_serves_heterogeneous_m() {
        // test_tiny: 2 layers × 4 heads = 8 key slots, d_k = 16, so
        // the m ∈ {2,4,8} ladder at 6 bits/code spans 96..384
        // bits/token. 150 forces a mixed assignment: uniform-4 (192)
        // does not fit, uniform-2 (96) leaves bits on the table.
        let mut cfg = tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 });
        cfg.policy = CompressionPolicy::Calibrated { bits: 150 };
        let mut e = Engine::build(&cfg).unwrap();

        let rec = e.policy_record().clone();
        assert_eq!(rec.policy, "calibrated-150");
        assert!(
            rec.total_bits_per_token <= 150,
            "spent {} bits over the 150-bit budget",
            rec.total_bits_per_token
        );
        assert_eq!(rec.heads.len(), 8);
        let ms: Vec<usize> = rec.heads.iter().map(|h| h.key_m).collect();
        for h in &rec.heads {
            assert!([2, 4, 8].contains(&h.key_m), "key_m {}", h.key_m);
            assert_eq!(h.value_m, 0, "fp32 values stay raw");
            assert!(
                h.rho.is_finite() && h.rho <= 1.0 + 1e-9,
                "rho {} out of range",
                h.rho
            );
        }
        assert!(
            ms.iter().any(|&m| m != ms[0]),
            "budget 150 should split heads across m tiers, got {ms:?}"
        );
        assert!(rec.min_rho() <= 1.0 + 1e-9);

        // serves end-to-end, and the whole resolution is deterministic
        let ids = ByteTokenizer::new().encode("calibrated serve probe");
        e.start_seq(1, &ids).unwrap();
        let t_a: Vec<u32> =
            (0..5).map(|_| e.decode_one(1).unwrap()).collect();
        let mut b = Engine::build(&cfg).unwrap();
        let ms_b: Vec<usize> = b
            .policy_record()
            .heads
            .iter()
            .map(|h| h.key_m)
            .collect();
        assert_eq!(ms, ms_b, "allocation not deterministic");
        b.start_seq(2, &ids).unwrap();
        let t_b: Vec<u32> =
            (0..5).map(|_| b.decode_one(2).unwrap()).collect();
        assert_eq!(t_a, t_b);
    }

    #[test]
    fn prune_policy_drops_low_norm_tokens_and_reports_them() {
        let mut cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        cfg.policy = CompressionPolicy::Prune { frac: 0.5 };
        let mut e = Engine::build(&cfg).unwrap();
        let rec = e.policy_record().clone();
        assert_eq!(rec.policy, "prune-0.5");
        assert_eq!(
            rec.prune_thresholds.len(),
            2,
            "one threshold per layer"
        );
        assert!(rec.prune_thresholds.iter().all(|t| *t > 0.0));

        let ids = ByteTokenizer::new().encode(
            "a long enough prompt that the median-norm threshold must \
             drop a healthy fraction of its tokens from the cache",
        );
        e.start_seq(1, &ids).unwrap();
        let t_a: Vec<u32> =
            (0..4).map(|_| e.decode_one(1).unwrap()).collect();
        let pruned = e.pruned_tokens();
        assert!(pruned > 0, "median threshold pruned nothing");
        // every pruned token is one the cache never stored:
        // cache_stats reports layer 0, which saw ids.len()+4 appends
        let stats = e.cache_stats();
        assert!(stats.tokens < ids.len() + 4);
        assert!(stats.tokens >= 1, "first token is never pruned");
        // the delta-published counter catches up to the live total
        assert_eq!(e.metrics().counter(Ctr::PrunedTokens), pruned);

        // pruning is part of the (seed, prompt) trajectory: rebuilds
        // agree on both the tokens and the drop count
        let mut b = Engine::build(&cfg).unwrap();
        b.start_seq(9, &ids).unwrap();
        let t_b: Vec<u32> =
            (0..4).map(|_| b.decode_one(9).unwrap()).collect();
        assert_eq!(t_a, t_b);
        assert_eq!(b.pruned_tokens(), pruned);
    }

    #[test]
    fn policy_validation_rejects_unsupported_combinations() {
        // calibrated with nothing to budget
        let mut cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        cfg.policy = CompressionPolicy::Calibrated { bits: 256 };
        let err = Engine::build(&cfg).unwrap_err().to_string();
        assert!(err.contains("needs a PQ side"), "{err}");

        // budget below the minimal assignment
        let mut cfg = tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 });
        cfg.policy = CompressionPolicy::Calibrated { bits: 1 };
        let err = Engine::build(&cfg).unwrap_err().to_string();
        assert!(err.contains("budget"), "{err}");

        // pruning breaks block-aligned prefix sharing
        let mut cfg = tiny_cfg(AttentionBackend::Fp16Exact);
        cfg.policy = CompressionPolicy::Prune { frac: 0.25 };
        cfg.prefix_cache = true;
        let err = Engine::build(&cfg).unwrap_err().to_string();
        assert!(err.contains("prefix"), "{err}");

        // PJRT artifacts bake in one global m — bail before any
        // artifact loading happens
        let mut cfg = tiny_cfg(AttentionBackend::PjrtFp16);
        cfg.policy = CompressionPolicy::Prune { frac: 0.25 };
        let err = Engine::build(&cfg).unwrap_err().to_string();
        assert!(err.contains("PJRT"), "{err}");
    }

    #[test]
    fn swap_and_prefix_cache_survive_calibrated_policy() {
        // the PR-6 subsystems must keep working when per-head codec
        // geometry is non-uniform: swap snapshots carry per-layer code
        // widths, prefix sharing reuses whole heterogeneous blocks
        let mut cfg = tiny_cfg(AttentionBackend::Lookat { m: 4, k: 64 });
        cfg.policy = CompressionPolicy::Calibrated { bits: 150 };
        let ids =
            ByteTokenizer::new().encode("swap under calibrated policy");
        let mut plain = Engine::build(&cfg).unwrap();
        plain.start_seq(1, &ids).unwrap();
        let want: Vec<u32> =
            (0..6).map(|_| plain.decode_one(1).unwrap()).collect();

        let mut e = Engine::build(&cfg).unwrap();
        e.start_seq(1, &ids).unwrap();
        let mut got: Vec<u32> =
            (0..3).map(|_| e.decode_one(1).unwrap()).collect();
        e.swap_out(1).unwrap();
        assert!(e.swapped_blocks(1) > 0);
        e.start_seq(2, &ids).unwrap();
        e.decode_one(2).unwrap();
        e.release(2).unwrap();
        e.swap_in(1).unwrap();
        got.extend((0..3).map(|_| e.decode_one(1).unwrap()));
        assert_eq!(want, got, "swap roundtrip diverged under policy");

        // prefix sharing under the same calibrated geometry
        let tok = ByteTokenizer::new();
        let prefix = "shared calibrated prefix ".repeat(4); // 100 tokens
        let p1 = tok.encode(&format!("{prefix}tail one"));
        let p2 = tok.encode(&format!("{prefix}tail two"));
        cfg.prefix_cache = true;
        let mut e = Engine::build(&cfg).unwrap();
        assert_eq!(e.begin_seq_with_prefix(1, &p1).unwrap(), 0);
        e.step_batch(&[TickEntry::Prefill { seq: 1, tokens: &p1 }])
            .unwrap();
        e.register_prefix(1, &p1);
        let shared = e.begin_seq_with_prefix(2, &p2).unwrap();
        assert_eq!(shared, 3 * BLOCK_TOKENS);
        e.step_batch(&[TickEntry::Prefill {
            seq: 2,
            tokens: &p2[shared..],
        }])
        .unwrap();
        let got: Vec<u32> =
            (0..4).map(|_| e.decode_one(2).unwrap()).collect();
        let mut r = Engine::build(&cfg).unwrap();
        r.start_seq(2, &p2).unwrap();
        let want: Vec<u32> =
            (0..4).map(|_| r.decode_one(2).unwrap()).collect();
        assert_eq!(want, got, "shared-prefix decode diverged");
    }
}
