//! Paged KV-cache manager with PQ-compressed key storage.
//!
//! The serving engine's cache: values stay full-precision (paper §3.1:
//! value access is compute-bound), keys are stored either raw (FP16
//! baseline) or as `m` uint8 PQ codes per token (LOOKAT). Storage is
//! paged vLLM-style so sequences grow without reallocation and memory
//! accounting is exact. Blocks are head-major, so one head's codes or
//! values inside a block are contiguous and the decode kernels scan
//! them in place via [`KvCache::blocks`] — the LOOKAT hot path never
//! copies key codes out of the cache.

mod block;
mod manager;

pub use block::{BlockAllocator, BlockId, BlockView, BLOCK_TOKENS};
pub use manager::{
    BlockIter, CacheError, CacheStats, KeyStorage, KvCache, SeqId,
};
