//! Paged KV-cache manager with PQ-compressed key storage.
//!
//! The serving engine's cache: values stay full-precision (paper §3.1:
//! value access is compute-bound), keys are stored either raw (FP16
//! baseline) or as `m` uint8 PQ codes per token (LOOKAT). Storage is
//! paged vLLM-style so sequences grow without reallocation and memory
//! accounting is exact.

mod block;
mod manager;

pub use block::{BlockAllocator, BlockId, BLOCK_TOKENS};
pub use manager::{CacheError, CacheStats, KeyStorage, KvCache, SeqId};
