//! Paged KV-cache manager with PQ-compressed key *and* value storage.
//!
//! The serving engine's cache: keys are stored either raw (FP16
//! baseline) or as `m` uint8 PQ codes per token (LOOKAT); values are
//! stored raw ([`ValueStorage::Fp32`]) or as `m_v` codes per token
//! ([`ValueStorage::Pq`], the paper's §5.2 extension in the serving
//! path). Storage is paged vLLM-style so sequences grow without
//! reallocation and memory accounting is exact. Blocks are head-major,
//! so one head's codes or values inside a block are contiguous and the
//! decode kernels scan them in place via [`KvCache::blocks`] — the
//! LOOKAT hot path never copies key codes out of the cache, and the
//! fused weighted decode never copies (or dequantizes) value codes.
//!
//! # Invariants
//!
//! - **Block geometry**: every block holds [`BLOCK_TOKENS`] token
//!   slots for all `h` heads, head-major. Float lanes are token-major
//!   `(H, BLOCK_TOKENS, d_k)`; code lanes are subspace-major
//!   `(m_head, BLOCK_TOKENS)` per head (nibble-packed to
//!   `(m_head, BLOCK_TOKENS/2)` at K ≤ 16, low nibble = even slot).
//! - **Heterogeneous m, uniform K**: each head may carry its own
//!   subspace count (set by a resolved
//!   [`crate::coordinator::CompressionPolicy`]); lane addressing goes
//!   through per-head byte-offset tables. The centroid count K — and
//!   therefore the packing mode — is uniform within one cache side
//!   ([`CacheError::MixedCodecs`] otherwise).
//! - **Swap tier**: swap-out copies whole per-block slabs (every head,
//!   every slot, stale bytes included), so restore is bit-identical
//!   under any lane geometry.
//! - **Prefix sharing**: only whole immutable blocks are shared;
//!   appends write private blocks, making sharing copy-on-write by
//!   construction. Sharing requires identical codecs (same engine
//!   build), so geometry always matches.
//! - **Pruning**: with a norm threshold armed, low-norm tokens are
//!   never appended ([`KvCache::append`] returns `Ok(false)`); the
//!   cache length then counts *surviving* tokens only, and attention
//!   runs over exactly that set.

mod block;
mod manager;

pub use block::{BlockAllocator, BlockId, BlockView, BLOCK_TOKENS};
pub(crate) use manager::mean_head_norm;
pub use manager::{
    BlockIter, CacheError, CacheStats, KeyStorage, KvCache, SeqId,
    ValueStorage,
};
