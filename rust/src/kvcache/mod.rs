//! Paged KV-cache manager with PQ-compressed key *and* value storage.
//!
//! The serving engine's cache: keys are stored either raw (FP16
//! baseline) or as `m` uint8 PQ codes per token (LOOKAT); values are
//! stored raw ([`ValueStorage::Fp32`]) or as `m_v` codes per token
//! ([`ValueStorage::Pq`], the paper's §5.2 extension in the serving
//! path). Storage is paged vLLM-style so sequences grow without
//! reallocation and memory accounting is exact. Blocks are head-major,
//! so one head's codes or values inside a block are contiguous and the
//! decode kernels scan them in place via [`KvCache::blocks`] — the
//! LOOKAT hot path never copies key codes out of the cache, and the
//! fused weighted decode never copies (or dequantizes) value codes.

mod block;
mod manager;

pub use block::{BlockAllocator, BlockId, BlockView, BLOCK_TOKENS};
pub use manager::{
    BlockIter, CacheError, CacheStats, KeyStorage, KvCache, SeqId,
    ValueStorage,
};
