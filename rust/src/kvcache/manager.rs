//! The KV-cache manager: per-sequence paged storage of (compressed) keys
//! and (compressed or full-precision) values for all heads of one layer.

use std::collections::HashMap;
use std::sync::Arc;

use super::block::{BlockAllocator, BlockId, BlockView, BLOCK_TOKENS};
use crate::pq::PqCodec;
use crate::util::fault;

/// Sequence identifier (one per serving request).
pub type SeqId = u64;

/// How keys are stored in the cache.
#[derive(Clone)]
pub enum KeyStorage {
    /// Raw keys ("FP16" storage model: accounted 2 B/element).
    Fp16,
    /// LOOKAT: keys live only as PQ codes, one codec per head.
    /// Build via [`KeyStorage::pq`], which validates the codec set.
    Pq { codecs: Arc<Vec<PqCodec>> },
}

impl KeyStorage {
    /// Validated PQ storage: one codec per head, at least one head,
    /// every head sharing one centroid count (K decides the lane
    /// packing, which must be uniform). Subspace counts may differ per
    /// head — a [`crate::coordinator::CompressionPolicy`] assigns each
    /// head its own `m`, and block lanes are strided by the per-head
    /// offset tables the cache precomputes.
    pub fn pq(codecs: Vec<PqCodec>) -> Result<KeyStorage, CacheError> {
        uniform_codecs(&codecs)?;
        Ok(KeyStorage::Pq { codecs: Arc::new(codecs) })
    }

    /// Largest per-head subspace count (0 for FP16 storage) — sizes
    /// the shared encode scratch.
    fn max_m(&self) -> usize {
        match self {
            KeyStorage::Fp16 => 0,
            KeyStorage::Pq { codecs } => {
                codecs.iter().map(|c| c.codebook.m).max().unwrap_or(0)
            }
        }
    }

    /// Codes per token for one head (0 for FP16 storage).
    fn head_m(&self, head: usize) -> usize {
        match self {
            KeyStorage::Fp16 => 0,
            KeyStorage::Pq { codecs } => codecs[head].codebook.m,
        }
    }

    /// Whether codes are nibble-packed (K ≤ 16: two per byte).
    /// Uniform across heads — `uniform_codecs` enforces one K per side.
    fn packed(&self) -> bool {
        match self {
            KeyStorage::Fp16 => false,
            KeyStorage::Pq { codecs } => {
                codecs.first().is_some_and(|c| c.packed())
            }
        }
    }

    /// Bytes of one subspace row within a block's per-head code lane:
    /// `BLOCK_TOKENS` byte codes, or half that nibble-packed.
    fn code_row_bytes(&self) -> usize {
        if self.packed() { BLOCK_TOKENS / 2 } else { BLOCK_TOKENS }
    }
}

/// How values are stored in the cache — the §5.2 extension mirrored onto
/// the key side's storage contract: under `Pq`, values exist only as
/// codes and are re-materialized solely through the fused weighted
/// decode (`pq::values::weighted_decode_lanes`), never per token.
#[derive(Clone)]
pub enum ValueStorage {
    /// Raw values ("FP16" storage model: accounted 2 B/element).
    Fp32,
    /// PQ-coded values, one codec per head.
    /// Build via [`ValueStorage::pq`], which validates the codec set.
    Pq { codecs: Arc<Vec<PqCodec>> },
}

impl ValueStorage {
    /// Validated PQ value storage: same contract as [`KeyStorage::pq`]
    /// (non-empty, one uniform centroid count; per-head subspace counts
    /// may differ).
    pub fn pq(codecs: Vec<PqCodec>) -> Result<ValueStorage, CacheError> {
        uniform_codecs(&codecs)?;
        Ok(ValueStorage::Pq { codecs: Arc::new(codecs) })
    }

    /// Largest per-head subspace count (0 for FP32 storage).
    fn max_m(&self) -> usize {
        match self {
            ValueStorage::Fp32 => 0,
            ValueStorage::Pq { codecs } => {
                codecs.iter().map(|c| c.codebook.m).max().unwrap_or(0)
            }
        }
    }

    /// Codes per token for one head (0 for FP32 storage).
    fn head_m(&self, head: usize) -> usize {
        match self {
            ValueStorage::Fp32 => 0,
            ValueStorage::Pq { codecs } => codecs[head].codebook.m,
        }
    }

    /// Whether value codes are nibble-packed (K ≤ 16).
    fn packed(&self) -> bool {
        match self {
            ValueStorage::Fp32 => false,
            ValueStorage::Pq { codecs } => {
                codecs.first().is_some_and(|c| c.packed())
            }
        }
    }

    /// Bytes of one subspace row of a block's per-head value-code lane.
    fn code_row_bytes(&self) -> usize {
        if self.packed() { BLOCK_TOKENS / 2 } else { BLOCK_TOKENS }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum CacheError {
    OutOfBlocks,
    UnknownSeq(SeqId),
    DuplicateSeq(SeqId),
    /// PQ storage was constructed with an empty codec set.
    NoCodecs,
    /// PQ storage was constructed with per-head codecs whose centroid
    /// counts differ — K decides nibble packing, which must be uniform
    /// within one cache side. (Per-head subspace counts are fine: a
    /// `CompressionPolicy` assigns each head its own `m`.)
    MixedCodecs,
    /// A swapped slab failed its FNV-1a integrity check at restore.
    /// The spill entry is discarded — the scheduler re-prefills rather
    /// than serving corrupt state.
    Corrupt(SeqId),
    /// A configured fault plan injected a failure at this hook point
    /// (chaos testing — see [`crate::util::fault::FaultPlan`]).
    Injected(&'static str),
}

/// Shared validation for the PQ storage constructors. Only the
/// centroid count must be uniform: K decides nibble packing, and one
/// side's lanes share one packing mode. Subspace counts vary freely
/// per head — the cache precomputes per-head lane offsets.
fn uniform_codecs(codecs: &[PqCodec]) -> Result<(), CacheError> {
    let Some(first) = codecs.first() else {
        return Err(CacheError::NoCodecs);
    };
    if codecs.iter().any(|c| c.codebook.k != first.codebook.k) {
        return Err(CacheError::MixedCodecs);
    }
    Ok(())
}

impl std::fmt::Display for CacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheError::OutOfBlocks => {
                write!(f, "out of cache blocks (budget exhausted)")
            }
            CacheError::UnknownSeq(id) => {
                write!(f, "unknown sequence {id}")
            }
            CacheError::DuplicateSeq(id) => {
                write!(f, "sequence {id} already exists")
            }
            CacheError::NoCodecs => {
                write!(f, "PQ storage needs at least one codec")
            }
            CacheError::MixedCodecs => {
                write!(
                    f,
                    "PQ storage needs one centroid count across heads \
                     (K decides lane packing; per-head m is fine)"
                )
            }
            CacheError::Corrupt(id) => {
                write!(
                    f,
                    "sequence {id}'s swapped state failed checksum \
                     verification (discarded; re-prefill required)"
                )
            }
            CacheError::Injected(site) => {
                write!(f, "injected fault ({site})")
            }
        }
    }
}

impl std::error::Error for CacheError {}

/// Exact memory accounting, in bytes, under the paper's storage model
/// (FP16 = 2 B per stored element; PQ codes = 1 B each).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub seqs: usize,
    pub tokens: usize,
    pub key_bytes: usize,
    pub value_bytes: usize,
    pub codebook_bytes: usize,
    pub blocks_allocated: usize,
    pub blocks_total: usize,
    /// physical blocks saved by prefix sharing: extra holders beyond
    /// the first, summed over live blocks
    pub shared_blocks: usize,
}

impl CacheStats {
    pub fn total_bytes(&self) -> usize {
        self.key_bytes + self.value_bytes + self.codebook_bytes
    }
}

struct SeqState {
    blocks: Vec<BlockId>,
    len: usize,
}

/// A swapped-out sequence's cache content: the full per-block slabs
/// (all heads, all `BLOCK_TOKENS` slots — including the stale region of
/// a trailing partial block) concatenated in block order. Restoring the
/// whole slab byte-for-byte makes swap-in bit-identical to never having
/// been evicted; `len` bounds which slots the kernels read.
struct SwappedSeq {
    len: usize,
    keys_raw: Vec<f32>,
    codes: Vec<u8>,
    values: Vec<f32>,
    value_codes: Vec<u8>,
    /// FNV-1a over all four slabs, stamped at swap-out and verified at
    /// swap-in — host-side spill memory is outside the paged arena's
    /// invariants, so restores prove integrity before serving
    checksum: u64,
}

impl SwappedSeq {
    /// Host-side bytes held by this spill entry.
    fn bytes(&self) -> usize {
        self.keys_raw.len() * 4
            + self.codes.len()
            + self.values.len() * 4
            + self.value_codes.len()
    }

    /// FNV-1a over the slabs (chained in a fixed order).
    fn compute_checksum(&self) -> u64 {
        let mut h = fault::fnv1a(&[]);
        for x in &self.keys_raw {
            h = fault::fnv1a_extend(h, &x.to_le_bytes());
        }
        h = fault::fnv1a_extend(h, &self.codes);
        for x in &self.values {
            h = fault::fnv1a_extend(h, &x.to_le_bytes());
        }
        fault::fnv1a_extend(h, &self.value_codes)
    }
}

/// Paged KV-cache for one transformer layer (all `h` heads).
///
/// Block layout (per block, `BLOCK_TOKENS` token slots) is head-major,
/// so one head's run of tokens within a block is contiguous and the
/// decode kernels can scan it in place ([`KvCache::blocks`]). Float
/// lanes are token-major; code lanes are **subspace-major interleaved**
/// (fast-scan layout — see [`BlockView`]):
///   values:      (H, BLOCK_TOKENS, d_k) f32 when value storage is Fp32
///   value codes: (H, m_v, BLOCK_TOKENS) u8  when value storage is Pq
///   keys:        (H, BLOCK_TOKENS, d_k) f32 when Fp16
///   key codes:   (H, m, BLOCK_TOKENS)   u8  when Pq
///
/// For K ≤ 16 codecs the code lanes are **nibble-packed**: each
/// subspace row holds `BLOCK_TOKENS/2` bytes, two 4-bit codes per byte
/// (low nibble = even token slot, high nibble = odd) — shape
/// `(H, m, BLOCK_TOKENS/2)`. Packing is decided per storage side by
/// its codec K ([`crate::pq::packs_nibbles`]), so keys and values can
/// mix packed and byte lanes freely.
///
/// **Heterogeneous `m`:** each head may carry its own subspace count
/// (a calibrated [`crate::coordinator::CompressionPolicy`] assigns
/// per-(layer, head) budgets), so a block's code region is laid out by
/// the precomputed per-head byte-offset tables `key_lane_off` /
/// `val_lane_off` rather than a single `h · m · row` stride. K (and
/// therefore packing) stays uniform within one side. Swap slabs copy
/// the whole per-block code region, so the tier is geometry-agnostic.
///
/// **Pruning:** with a prune threshold set
/// ([`KvCache::set_prune_threshold`]), appends whose mean per-head key
/// L2 norm falls below the threshold are skipped entirely — no codes
/// written, no block allocated, `append` returns `Ok(false)` — and
/// attention runs over the surviving set. The first token of a
/// sequence is never pruned.
pub struct KvCache {
    pub h: usize,
    pub d_k: usize,
    storage: KeyStorage,
    value_storage: ValueStorage,
    alloc: BlockAllocator,
    seqs: HashMap<SeqId, SeqState>,
    /// swap-out tier: preempted sequences' cache content, held host-side
    /// instead of recomputed (tiered-KV — see [`KvCache::swap_out`])
    swapped: HashMap<SeqId, SwappedSeq>,
    values: Vec<f32>,
    value_codes: Vec<u8>,
    keys_raw: Vec<f32>,
    codes: Vec<u8>,
    /// append-time encode buffer (max over heads of max(m, m_v) bytes)
    /// — the hot path encodes into it and scatters strided,
    /// allocation-free
    code_scratch: Vec<u8>,
    /// append-time per-subspace dot scratch for the encoders — owned
    /// so the serial append stage never touches the shared arena mutex
    dots_scratch: Vec<f32>,
    /// per-head byte offsets of the key-code lanes within one block's
    /// code region (len h+1; `[h]` is the whole region's stride) —
    /// supports heterogeneous per-head m
    key_lane_off: Vec<usize>,
    /// per-head byte offsets of the value-code lanes (len h+1)
    val_lane_off: Vec<usize>,
    /// L2-norm token-pruning threshold (None = keep everything)
    prune_threshold: Option<f32>,
    /// tokens skipped by the pruning policy since construction
    pruned: u64,
}

impl KvCache {
    /// Build a cache with a budget of `max_blocks` blocks.
    pub fn new(h: usize, d_k: usize, max_blocks: usize,
               storage: KeyStorage, value_storage: ValueStorage) -> Self {
        if let KeyStorage::Pq { codecs } = &storage {
            assert_eq!(codecs.len(), h, "one codec per head");
            for c in codecs.iter() {
                assert_eq!(c.codebook.d_k(), d_k);
            }
        }
        if let ValueStorage::Pq { codecs } = &value_storage {
            assert_eq!(codecs.len(), h, "one value codec per head");
            for c in codecs.iter() {
                assert_eq!(c.codebook.d_k(), d_k);
            }
        }
        let slot = BLOCK_TOKENS * h;
        // per-head lane offsets: lanes are laid out head-major within a
        // block's code region, each head contributing m_head · row bytes
        let lane_offsets =
            |row: usize, head_m: &dyn Fn(usize) -> usize| -> Vec<usize> {
                let mut off = Vec::with_capacity(h + 1);
                let mut acc = 0usize;
                off.push(0);
                for head in 0..h {
                    acc += head_m(head) * row;
                    off.push(acc);
                }
                off
            };
        let key_lane_off = lane_offsets(storage.code_row_bytes(), &|head| {
            storage.head_m(head)
        });
        let val_lane_off =
            lane_offsets(value_storage.code_row_bytes(), &|head| {
                value_storage.head_m(head)
            });
        let m = storage.max_m();
        let (keys_raw, codes) = match &storage {
            KeyStorage::Fp16 => (vec![0.0; max_blocks * slot * d_k], vec![]),
            KeyStorage::Pq { .. } => {
                (vec![], vec![0u8; max_blocks * key_lane_off[h]])
            }
        };
        let m_v = value_storage.max_m();
        let (values, value_codes) = match &value_storage {
            ValueStorage::Fp32 => {
                (vec![0.0; max_blocks * slot * d_k], vec![])
            }
            ValueStorage::Pq { .. } => {
                (vec![], vec![0u8; max_blocks * val_lane_off[h]])
            }
        };
        Self {
            h,
            d_k,
            storage,
            value_storage,
            alloc: BlockAllocator::new(max_blocks),
            seqs: HashMap::new(),
            swapped: HashMap::new(),
            values,
            value_codes,
            keys_raw,
            codes,
            code_scratch: vec![0u8; m.max(m_v)],
            dots_scratch: Vec::new(),
            key_lane_off,
            val_lane_off,
            prune_threshold: None,
            pruned: 0,
        }
    }

    /// Arm (or disarm) L2-norm token pruning: appends whose mean
    /// per-head key norm falls below `thr` are skipped (see
    /// [`KvCache::append`]). Resolved once at engine build by the
    /// pruning [`crate::coordinator::CompressionPolicy`] from the
    /// calibration norm distribution.
    pub fn set_prune_threshold(&mut self, thr: Option<f32>) {
        self.prune_threshold = thr;
    }

    /// Tokens dropped by the pruning policy since construction.
    pub fn pruned_tokens(&self) -> u64 {
        self.pruned
    }

    pub fn is_pq(&self) -> bool {
        matches!(self.storage, KeyStorage::Pq { .. })
    }

    pub fn is_value_pq(&self) -> bool {
        matches!(self.value_storage, ValueStorage::Pq { .. })
    }

    pub fn codecs(&self) -> Option<&Arc<Vec<PqCodec>>> {
        match &self.storage {
            KeyStorage::Pq { codecs } => Some(codecs),
            KeyStorage::Fp16 => None,
        }
    }

    pub fn value_codecs(&self) -> Option<&Arc<Vec<PqCodec>>> {
        match &self.value_storage {
            ValueStorage::Pq { codecs } => Some(codecs),
            ValueStorage::Fp32 => None,
        }
    }

    /// Register a new (empty) sequence.
    pub fn create_seq(&mut self, seq: SeqId) -> Result<(), CacheError> {
        if self.seqs.contains_key(&seq) {
            return Err(CacheError::DuplicateSeq(seq));
        }
        self.seqs.insert(seq, SeqState { blocks: Vec::new(), len: 0 });
        Ok(())
    }

    /// Tokens currently cached for a sequence.
    pub fn seq_len(&self, seq: SeqId) -> Result<usize, CacheError> {
        Ok(self.seqs.get(&seq).ok_or(CacheError::UnknownSeq(seq))?.len)
    }

    /// Whether another `n`-token append can be admitted right now.
    pub fn can_append(&self, seq: SeqId, n: usize) -> bool {
        match self.seqs.get(&seq) {
            None => false,
            Some(st) => {
                let need = (st.len + n).div_ceil(BLOCK_TOKENS)
                    - st.blocks.len();
                need <= self.alloc.available()
            }
        }
    }

    /// Append one token's K/V for all heads.
    ///
    /// `keys`/`values` are (H × d_k). In PQ mode the key (and, under
    /// `ValueStorage::Pq`, the value) is immediately encoded to that
    /// head's `m` codes and the raw vector is dropped — this is the
    /// paper's storage contract (compressed tensors never exist
    /// uncompressed in the cache).
    ///
    /// Returns `Ok(true)` if the token was stored, `Ok(false)` if the
    /// pruning policy dropped it (mean per-head key L2 norm below the
    /// armed threshold; nothing is written and no block is allocated).
    /// The first token of a sequence is always stored so attention
    /// never runs over an empty set.
    pub fn append(
        &mut self,
        seq: SeqId,
        keys: &[f32],
        values: &[f32],
    ) -> Result<bool, CacheError> {
        assert_eq!(keys.len(), self.h * self.d_k);
        assert_eq!(values.len(), self.h * self.d_k);
        let st = self
            .seqs
            .get_mut(&seq)
            .ok_or(CacheError::UnknownSeq(seq))?;
        if let Some(thr) = self.prune_threshold {
            if st.len > 0 && mean_head_norm(keys, self.h, self.d_k) < thr {
                self.pruned += 1;
                return Ok(false);
            }
        }
        let off = st.len % BLOCK_TOKENS;
        if off == 0 {
            let b = self.alloc.alloc().ok_or(CacheError::OutOfBlocks)?;
            st.blocks.push(b);
        }
        let block = *st.blocks.last().unwrap() as usize;
        let h = self.h;
        let d_k = self.d_k;
        // values: one strided write (or encode) per head (head-major
        // block layout; code lanes are subspace-major within the block,
        // strided by the per-head offset table — heads can carry
        // different m)
        match &self.value_storage {
            ValueStorage::Fp32 => {
                for head in 0..h {
                    let vbase =
                        ((block * h + head) * BLOCK_TOKENS + off) * d_k;
                    self.values[vbase..vbase + d_k].copy_from_slice(
                        &values[head * d_k..(head + 1) * d_k]);
                }
            }
            ValueStorage::Pq { codecs } => {
                let packed = codecs[0].packed();
                let row =
                    if packed { BLOCK_TOKENS / 2 } else { BLOCK_TOKENS };
                let stride = self.val_lane_off[h];
                for head in 0..h {
                    let m_v = codecs[head].codebook.m;
                    let code = &mut self.code_scratch[..m_v];
                    codecs[head].encode_into_with(
                        &values[head * d_k..(head + 1) * d_k],
                        code,
                        &mut self.dots_scratch,
                    );
                    let lane = block * stride + self.val_lane_off[head];
                    for (i, &c) in code.iter().enumerate() {
                        if packed {
                            let b = &mut self.value_codes
                                [lane + i * row + off / 2];
                            // even slot writes the whole byte, clearing
                            // any stale high nibble from a freed block
                            *b = if off % 2 == 0 {
                                c
                            } else {
                                (*b & 0x0F) | (c << 4)
                            };
                        } else {
                            self.value_codes[lane + i * row + off] = c;
                        }
                    }
                }
            }
        }
        // keys
        match &self.storage {
            KeyStorage::Fp16 => {
                for head in 0..h {
                    let kbase =
                        ((block * h + head) * BLOCK_TOKENS + off) * d_k;
                    self.keys_raw[kbase..kbase + d_k].copy_from_slice(
                        &keys[head * d_k..(head + 1) * d_k]);
                }
            }
            KeyStorage::Pq { codecs } => {
                let packed = codecs[0].packed();
                let row =
                    if packed { BLOCK_TOKENS / 2 } else { BLOCK_TOKENS };
                let stride = self.key_lane_off[h];
                for head in 0..h {
                    let m = codecs[head].codebook.m;
                    let code = &mut self.code_scratch[..m];
                    codecs[head].encode_into_with(
                        &keys[head * d_k..(head + 1) * d_k],
                        code,
                        &mut self.dots_scratch,
                    );
                    let lane = block * stride + self.key_lane_off[head];
                    for (i, &c) in code.iter().enumerate() {
                        if packed {
                            let b = &mut self.codes
                                [lane + i * row + off / 2];
                            *b = if off % 2 == 0 {
                                c
                            } else {
                                (*b & 0x0F) | (c << 4)
                            };
                        } else {
                            self.codes[lane + i * row + off] = c;
                        }
                    }
                }
            }
        }
        st.len += 1;
        Ok(true)
    }

    /// Blocks currently held by one sequence — the preemptive
    /// scheduler's victim-accounting signal (how much a preemption
    /// would free).
    pub fn seq_blocks(&self, seq: SeqId) -> Result<usize, CacheError> {
        Ok(self
            .seqs
            .get(&seq)
            .ok_or(CacheError::UnknownSeq(seq))?
            .blocks
            .len())
    }

    /// Drop a sequence and return its blocks to the pool. The storage
    /// codecs are untouched: a preempted sequence's blocks can be freed
    /// and later reallocated (code-level re-prefill) without any codec
    /// teardown or retraining.
    pub fn free_seq(&mut self, seq: SeqId) -> Result<(), CacheError> {
        let st = self.seqs.remove(&seq).ok_or(CacheError::UnknownSeq(seq))?;
        for b in st.blocks {
            self.alloc.release(b);
        }
        Ok(())
    }

    /// Swap a sequence out to the host-side spill store: copy its block
    /// slabs (whole blocks, all heads) out of the paged arena and return
    /// the blocks to the pool. Works on shared (prefix-attached) blocks
    /// too — content is copied and this sequence's reference dropped, so
    /// other holders are unaffected. [`KvCache::swap_in`] restores the
    /// slabs byte-for-byte into fresh blocks.
    pub fn swap_out(&mut self, seq: SeqId) -> Result<(), CacheError> {
        if self.swapped.contains_key(&seq) {
            return Err(CacheError::DuplicateSeq(seq));
        }
        let st =
            self.seqs.remove(&seq).ok_or(CacheError::UnknownSeq(seq))?;
        let slot = BLOCK_TOKENS * self.h;
        let (kf, kc) = (slot * self.d_k, self.key_lane_off[self.h]);
        let (vf, vc) = (slot * self.d_k, self.val_lane_off[self.h]);
        let mut sw = SwappedSeq {
            len: st.len,
            keys_raw: Vec::new(),
            codes: Vec::new(),
            values: Vec::new(),
            value_codes: Vec::new(),
            checksum: 0,
        };
        for &b in &st.blocks {
            let b = b as usize;
            match &self.storage {
                KeyStorage::Fp16 => sw
                    .keys_raw
                    .extend_from_slice(&self.keys_raw[b * kf..(b + 1) * kf]),
                KeyStorage::Pq { .. } => sw
                    .codes
                    .extend_from_slice(&self.codes[b * kc..(b + 1) * kc]),
            }
            match &self.value_storage {
                ValueStorage::Fp32 => sw
                    .values
                    .extend_from_slice(&self.values[b * vf..(b + 1) * vf]),
                ValueStorage::Pq { .. } => sw.value_codes.extend_from_slice(
                    &self.value_codes[b * vc..(b + 1) * vc],
                ),
            }
        }
        for b in st.blocks {
            self.alloc.release(b);
        }
        sw.checksum = sw.compute_checksum();
        self.swapped.insert(seq, sw);
        Ok(())
    }

    /// Restore a swapped-out sequence into freshly allocated blocks.
    /// Fails with [`CacheError::OutOfBlocks`] (entry kept for a later
    /// retry) if the pool can't hold it right now, or with
    /// [`CacheError::Corrupt`] (entry discarded) if the slabs no longer
    /// match their swap-out checksum — corrupt state is never restored;
    /// the scheduler re-prefills instead.
    pub fn swap_in(&mut self, seq: SeqId) -> Result<(), CacheError> {
        if self.seqs.contains_key(&seq) {
            return Err(CacheError::DuplicateSeq(seq));
        }
        let entry =
            self.swapped.get(&seq).ok_or(CacheError::UnknownSeq(seq))?;
        let need = entry.len.div_ceil(BLOCK_TOKENS);
        if self.alloc.available() < need {
            return Err(CacheError::OutOfBlocks);
        }
        if entry.compute_checksum() != entry.checksum {
            self.swapped.remove(&seq);
            return Err(CacheError::Corrupt(seq));
        }
        let sw = self.swapped.remove(&seq).unwrap();
        let blocks: Vec<BlockId> =
            (0..need).map(|_| self.alloc.alloc().unwrap()).collect();
        let slot = BLOCK_TOKENS * self.h;
        let (kf, kc) = (slot * self.d_k, self.key_lane_off[self.h]);
        let (vf, vc) = (slot * self.d_k, self.val_lane_off[self.h]);
        for (i, &b) in blocks.iter().enumerate() {
            let b = b as usize;
            match &self.storage {
                KeyStorage::Fp16 => self.keys_raw[b * kf..(b + 1) * kf]
                    .copy_from_slice(&sw.keys_raw[i * kf..(i + 1) * kf]),
                KeyStorage::Pq { .. } => self.codes[b * kc..(b + 1) * kc]
                    .copy_from_slice(&sw.codes[i * kc..(i + 1) * kc]),
            }
            match &self.value_storage {
                ValueStorage::Fp32 => self.values[b * vf..(b + 1) * vf]
                    .copy_from_slice(&sw.values[i * vf..(i + 1) * vf]),
                ValueStorage::Pq { .. } => self.value_codes
                    [b * vc..(b + 1) * vc]
                    .copy_from_slice(&sw.value_codes[i * vc..(i + 1) * vc]),
            }
        }
        self.seqs.insert(seq, SeqState { blocks, len: sw.len });
        Ok(())
    }

    /// Whether a sequence currently lives in the spill store.
    pub fn is_swapped(&self, seq: SeqId) -> bool {
        self.swapped.contains_key(&seq)
    }

    /// Blocks a swapped sequence will need at swap-in (0 if not swapped).
    pub fn swapped_blocks(&self, seq: SeqId) -> usize {
        self.swapped
            .get(&seq)
            .map_or(0, |sw| sw.len.div_ceil(BLOCK_TOKENS))
    }

    /// Discard a spill entry (the sequence will re-prefill instead).
    pub fn drop_swapped(&mut self, seq: SeqId) {
        self.swapped.remove(&seq);
    }

    /// Total host-side bytes held by the spill store.
    pub fn swap_bytes(&self) -> usize {
        self.swapped.values().map(|sw| sw.bytes()).sum()
    }

    /// Attach shared prefix blocks to a freshly created (still empty)
    /// sequence: each block gains a holder and the sequence starts at
    /// `tokens` cached tokens. Only whole immutable blocks may be
    /// shared — appends always write a private block (a new one is
    /// allocated the moment `len` crosses a block boundary), so shared
    /// content is copy-on-write by construction.
    pub fn attach_prefix(
        &mut self,
        seq: SeqId,
        blocks: &[BlockId],
        tokens: usize,
    ) -> Result<(), CacheError> {
        {
            let st =
                self.seqs.get(&seq).ok_or(CacheError::UnknownSeq(seq))?;
            assert!(
                st.len == 0 && st.blocks.is_empty(),
                "attach_prefix requires an empty sequence"
            );
        }
        assert_eq!(
            tokens,
            blocks.len() * BLOCK_TOKENS,
            "prefix must cover whole blocks"
        );
        for &b in blocks {
            self.alloc.retain(b);
        }
        let st = self.seqs.get_mut(&seq).unwrap();
        st.blocks.extend_from_slice(blocks);
        st.len = tokens;
        Ok(())
    }

    /// The physical block ids backing a sequence, in token order — the
    /// prefix cache registers these for sharing, and tests verify
    /// sharing through them.
    pub fn seq_block_ids(&self, seq: SeqId) -> Result<&[BlockId], CacheError> {
        Ok(&self.seqs.get(&seq).ok_or(CacheError::UnknownSeq(seq))?.blocks)
    }

    /// FNV-1a over one block's live slabs, chained onto `state`. The
    /// prefix cache stamps registered blocks with this and re-verifies
    /// before attaching them to a new sequence — shared blocks are
    /// immutable by the copy-on-write contract, so any drift is
    /// corruption, and the attach falls back to a re-prefill.
    pub fn block_checksum(&self, b: BlockId, state: u64) -> u64 {
        let slot = BLOCK_TOKENS * self.h;
        let (kf, kc) = (slot * self.d_k, self.key_lane_off[self.h]);
        let (vf, vc) = (slot * self.d_k, self.val_lane_off[self.h]);
        let b = b as usize;
        let mut h = state;
        match &self.storage {
            KeyStorage::Fp16 => {
                for x in &self.keys_raw[b * kf..(b + 1) * kf] {
                    h = fault::fnv1a_extend(h, &x.to_le_bytes());
                }
            }
            KeyStorage::Pq { .. } => {
                h = fault::fnv1a_extend(h, &self.codes[b * kc..(b + 1) * kc]);
            }
        }
        match &self.value_storage {
            ValueStorage::Fp32 => {
                for x in &self.values[b * vf..(b + 1) * vf] {
                    h = fault::fnv1a_extend(h, &x.to_le_bytes());
                }
            }
            ValueStorage::Pq { .. } => {
                h = fault::fnv1a_extend(
                    h,
                    &self.value_codes[b * vc..(b + 1) * vc],
                );
            }
        }
        h
    }

    /// Flip one byte of a spill entry's slabs — chaos-test
    /// instrumentation that forces the swap-in checksum to fail.
    /// Returns `false` when the sequence has no spill entry.
    pub fn corrupt_swapped(&mut self, seq: SeqId) -> bool {
        let Some(sw) = self.swapped.get_mut(&seq) else {
            return false;
        };
        if let Some(c) = sw.codes.first_mut() {
            *c ^= 0xff;
        } else if let Some(x) = sw.keys_raw.first_mut() {
            *x = f32::from_bits(x.to_bits() ^ 1);
        } else if let Some(c) = sw.value_codes.first_mut() {
            *c ^= 0xff;
        } else if let Some(x) = sw.values.first_mut() {
            *x = f32::from_bits(x.to_bits() ^ 1);
        } else {
            return false;
        }
        true
    }

    /// Zero-copy iteration over one head's cache blocks, in token order.
    ///
    /// This is the batched-decode hot path: the LOOKAT kernel scans the
    /// codes and accumulates α·V straight out of these views; the
    /// gather-based paths below exist for backends that need one
    /// contiguous tensor (FP16 scoring, scalar-quant round-trips, PJRT
    /// artifact packing).
    pub fn blocks(
        &self,
        seq: SeqId,
        head: usize,
    ) -> Result<BlockIter<'_>, CacheError> {
        assert!(head < self.h, "head {head} out of range (H={})", self.h);
        let st = self.seqs.get(&seq).ok_or(CacheError::UnknownSeq(seq))?;
        Ok(BlockIter {
            cache: self,
            blocks: &st.blocks,
            head,
            remaining: st.len,
            idx: 0,
        })
    }

    /// Copy one head's raw keys into `out` (FP16 mode only).
    /// Returns the sequence length.
    pub fn gather_keys_into(
        &self,
        seq: SeqId,
        head: usize,
        out: &mut Vec<f32>,
    ) -> Result<usize, CacheError> {
        assert!(!self.is_pq(), "gather_keys_into is for FP16 caches");
        let len = self.seq_len(seq)?;
        out.clear();
        out.reserve(len * self.d_k);
        for blk in self.blocks(seq, head)? {
            out.extend_from_slice(blk.keys);
        }
        Ok(len)
    }

    /// Copy one head's PQ codes into `out` (PQ mode only),
    /// de-interleaved from the blocks' subspace-major lanes back to
    /// token-major (n × m) — the layout PJRT packing, experiments and
    /// the attention primitives expect.
    pub fn gather_codes_into(
        &self,
        seq: SeqId,
        head: usize,
        out: &mut Vec<u8>,
    ) -> Result<usize, CacheError> {
        let m = self.storage.head_m(head);
        assert!(m > 0, "gather_codes_into is for PQ caches");
        let len = self.seq_len(seq)?;
        out.clear();
        out.reserve(len * m);
        let packed = self.storage.packed();
        for blk in self.blocks(seq, head)? {
            deinterleave_lane(blk.codes, blk.len, m, packed, out);
        }
        Ok(len)
    }

    /// Copy one head's raw values into `out` (FP32 value mode only).
    pub fn gather_values_into(
        &self,
        seq: SeqId,
        head: usize,
        out: &mut Vec<f32>,
    ) -> Result<usize, CacheError> {
        assert!(
            !self.is_value_pq(),
            "gather_values_into is for FP32 value caches"
        );
        let len = self.seq_len(seq)?;
        out.clear();
        out.reserve(len * self.d_k);
        for blk in self.blocks(seq, head)? {
            out.extend_from_slice(blk.values);
        }
        Ok(len)
    }

    /// Copy one head's PQ value codes into `out` (PQ value mode only),
    /// de-interleaved to token-major (n × m_v) like
    /// [`KvCache::gather_codes_into`].
    pub fn gather_value_codes_into(
        &self,
        seq: SeqId,
        head: usize,
        out: &mut Vec<u8>,
    ) -> Result<usize, CacheError> {
        let m_v = self.value_storage.head_m(head);
        assert!(m_v > 0, "gather_value_codes_into is for PQ value caches");
        let len = self.seq_len(seq)?;
        out.clear();
        out.reserve(len * m_v);
        let packed = self.value_storage.packed();
        for blk in self.blocks(seq, head)? {
            deinterleave_lane(blk.value_codes, blk.len, m_v, packed, out);
        }
        Ok(len)
    }

    /// Exact storage accounting under the paper's byte model. Both sides
    /// reflect the *active* storage mode: PQ-coded tensors cost their
    /// codes (1 B each, or ½ B nibble-packed at K ≤ 16) plus their
    /// codebooks (FP16 entries), raw tensors cost 2 B/element.
    pub fn stats(&self) -> CacheStats {
        let tokens: usize = self.seqs.values().map(|s| s.len).sum();
        let key_bytes = tokens * self.key_bytes_per_token_all_heads();
        let value_bytes = tokens * self.value_bytes_per_token_all_heads();
        let mut codebook_bytes: usize = match &self.storage {
            KeyStorage::Fp16 => 0,
            KeyStorage::Pq { codecs } => {
                codecs.iter().map(|c| c.codebook.size_bytes_fp16()).sum()
            }
        };
        if let ValueStorage::Pq { codecs } = &self.value_storage {
            codebook_bytes += codecs
                .iter()
                .map(|c| c.codebook.size_bytes_fp16())
                .sum::<usize>();
        }
        CacheStats {
            seqs: self.seqs.len(),
            tokens,
            key_bytes,
            value_bytes,
            codebook_bytes,
            blocks_allocated: self.alloc.allocated(),
            blocks_total: self.alloc.total(),
            shared_blocks: self.alloc.shared_refs(),
        }
    }

    /// Bytes of key storage per token for head 0 (the paper's "Mem."
    /// column under a uniform policy) — ⌈m/2⌉ for nibble-packed K ≤ 16
    /// codes. Under a calibrated policy heads differ; use
    /// [`KvCache::key_bytes_per_token_all_heads`] for exact accounting.
    pub fn key_bytes_per_token_per_head(&self) -> usize {
        match &self.storage {
            KeyStorage::Fp16 => self.d_k * 2,
            KeyStorage::Pq { codecs } => {
                codecs.first().map_or(0, |c| c.bytes_per_token())
            }
        }
    }

    /// Bytes of value storage per token for head 0 (uniform-policy
    /// "Mem." column value axis).
    pub fn value_bytes_per_token_per_head(&self) -> usize {
        match &self.value_storage {
            ValueStorage::Fp32 => self.d_k * 2,
            ValueStorage::Pq { codecs } => {
                codecs.first().map_or(0, |c| c.bytes_per_token())
            }
        }
    }

    /// Exact key bytes per token summed over all heads — correct under
    /// heterogeneous per-head m.
    pub fn key_bytes_per_token_all_heads(&self) -> usize {
        match &self.storage {
            KeyStorage::Fp16 => self.h * self.d_k * 2,
            KeyStorage::Pq { codecs } => {
                codecs.iter().map(|c| c.bytes_per_token()).sum()
            }
        }
    }

    /// Exact value bytes per token summed over all heads.
    pub fn value_bytes_per_token_all_heads(&self) -> usize {
        match &self.value_storage {
            ValueStorage::Fp32 => self.h * self.d_k * 2,
            ValueStorage::Pq { codecs } => {
                codecs.iter().map(|c| c.bytes_per_token()).sum()
            }
        }
    }

    /// Per-head key subspace counts (empty for FP16 storage) — the
    /// telemetry/report surface for the resolved policy.
    pub fn key_ms(&self) -> Vec<usize> {
        match &self.storage {
            KeyStorage::Fp16 => Vec::new(),
            KeyStorage::Pq { codecs } => {
                codecs.iter().map(|c| c.codebook.m).collect()
            }
        }
    }
}

/// Mean over heads of the per-head key L2 norm — the pruning policy's
/// per-token signal. Head-averaged because block slots are shared
/// across heads: a token is either resident for every head or none.
pub(crate) fn mean_head_norm(keys: &[f32], h: usize, d_k: usize) -> f32 {
    let mut acc = 0.0f32;
    for head in 0..h {
        let k = &keys[head * d_k..(head + 1) * d_k];
        acc += k.iter().map(|x| x * x).sum::<f32>().sqrt();
    }
    acc / h as f32
}

/// De-interleave one block's subspace-major `(m × BLOCK_TOKENS)` code
/// lane (or its `(m × BLOCK_TOKENS/2)` nibble-packed sibling) back to
/// token-major `(len × m)` byte codes, appending to `out` — the
/// single home of the lane-layout inverse (the forward scatter lives
/// in [`KvCache::append`], the test-side packers in
/// `testkit::fixtures::interleave_lanes{,_packed}`).
fn deinterleave_lane(
    lane: &[u8],
    len: usize,
    m: usize,
    packed: bool,
    out: &mut Vec<u8>,
) {
    let row = if packed { BLOCK_TOKENS / 2 } else { BLOCK_TOKENS };
    debug_assert_eq!(lane.len(), m * row);
    for t in 0..len {
        for i in 0..m {
            out.push(if packed {
                crate::pq::simd::nibble(&lane[i * row..(i + 1) * row], t)
            } else {
                lane[i * row + t]
            });
        }
    }
}

/// Iterator over one head's [`BlockView`]s (see [`KvCache::blocks`]).
pub struct BlockIter<'a> {
    cache: &'a KvCache,
    blocks: &'a [BlockId],
    head: usize,
    remaining: usize,
    idx: usize,
}

impl<'a> Iterator for BlockIter<'a> {
    type Item = BlockView<'a>;

    fn next(&mut self) -> Option<BlockView<'a>> {
        if self.remaining == 0 || self.idx >= self.blocks.len() {
            return None;
        }
        let b = self.blocks[self.idx] as usize;
        self.idx += 1;
        let take = self.remaining.min(BLOCK_TOKENS);
        self.remaining -= take;
        let c = self.cache;
        let (h, d_k) = (c.h, c.d_k);
        let fbase = (b * h + self.head) * BLOCK_TOKENS * d_k;
        // code lanes are subspace-major: expose the block's FULL
        // (m × BLOCK_TOKENS) lane — `len` bounds the valid prefix of
        // each subspace row (the scan kernels slice per row)
        let (values, value_codes): (&[f32], &[u8]) = match &c.value_storage
        {
            ValueStorage::Fp32 => {
                (&c.values[fbase..fbase + take * d_k], &[][..])
            }
            ValueStorage::Pq { .. } => {
                // per-head lane: heads may carry different m, so slice
                // by the precomputed offset table
                let lane =
                    b * c.val_lane_off[h] + c.val_lane_off[self.head];
                let lb = c.val_lane_off[self.head + 1]
                    - c.val_lane_off[self.head];
                (&[][..], &c.value_codes[lane..lane + lb])
            }
        };
        let (keys, codes): (&[f32], &[u8]) = match &c.storage {
            KeyStorage::Fp16 => {
                (&c.keys_raw[fbase..fbase + take * d_k], &[][..])
            }
            KeyStorage::Pq { .. } => {
                let lane =
                    b * c.key_lane_off[h] + c.key_lane_off[self.head];
                let lb = c.key_lane_off[self.head + 1]
                    - c.key_lane_off[self.head];
                (&[][..], &c.codes[lane..lane + lb])
            }
        };
        Some(BlockView { len: take, keys, codes, values, value_codes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::TrainOpts;
    use crate::util::rng::Pcg32;

    const H: usize = 2;
    const DK: usize = 16;

    /// K=16 codecs — nibble-packed lanes, the 4-bit fast-scan mode.
    fn pq_storage(m: usize) -> KeyStorage {
        pq_storage_k(m, 16)
    }

    fn pq_storage_k(m: usize, k: usize) -> KeyStorage {
        let mut rng = Pcg32::seed(5);
        let calib: Vec<f32> =
            (0..128 * DK).map(|_| rng.next_f32_std()).collect();
        let codecs: Vec<PqCodec> = (0..H)
            .map(|_| PqCodec::train(&calib, DK, m, k, &TrainOpts::default()))
            .collect();
        KeyStorage::pq(codecs).unwrap()
    }

    fn token(seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::seed(seed);
        let k = (0..H * DK).map(|_| rng.next_f32_std()).collect();
        let v = (0..H * DK).map(|_| rng.next_f32_std()).collect();
        (k, v)
    }

    #[test]
    fn fp16_roundtrip_preserves_keys_and_values() {
        let mut c = KvCache::new(H, DK, 8, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        let mut all_k = Vec::new();
        let mut all_v = Vec::new();
        for t in 0..70 {
            // spans 3 blocks
            let (k, v) = token(t);
            all_k.push(k.clone());
            all_v.push(v.clone());
            c.append(1, &k, &v).unwrap();
        }
        assert_eq!(c.seq_len(1).unwrap(), 70);
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        for head in 0..H {
            let n = c.gather_keys_into(1, head, &mut keys).unwrap();
            assert_eq!(n, 70);
            c.gather_values_into(1, head, &mut vals).unwrap();
            for t in 0..70 {
                assert_eq!(
                    &keys[t * DK..(t + 1) * DK],
                    &all_k[t][head * DK..(head + 1) * DK]
                );
                assert_eq!(
                    &vals[t * DK..(t + 1) * DK],
                    &all_v[t][head * DK..(head + 1) * DK]
                );
            }
        }
    }

    #[test]
    fn pq_mode_stores_codes_matching_direct_encode() {
        let storage = pq_storage(4);
        let codecs = match &storage {
            KeyStorage::Pq { codecs } => codecs.clone(),
            _ => unreachable!(),
        };
        let mut c = KvCache::new(H, DK, 8, storage, ValueStorage::Fp32);
        c.create_seq(9).unwrap();
        let mut expected: Vec<Vec<u8>> = vec![Vec::new(); H];
        for t in 0..40 {
            let (k, v) = token(100 + t);
            for head in 0..H {
                expected[head].extend(
                    codecs[head].encode(&k[head * DK..(head + 1) * DK]),
                );
            }
            c.append(9, &k, &v).unwrap();
        }
        let mut codes = Vec::new();
        for head in 0..H {
            let n = c.gather_codes_into(9, head, &mut codes).unwrap();
            assert_eq!(n, 40);
            assert_eq!(codes, expected[head]);
        }
    }

    #[test]
    fn out_of_blocks_is_reported_not_panicked() {
        let mut c = KvCache::new(H, DK, 1, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        let (k, v) = token(0);
        for _ in 0..BLOCK_TOKENS {
            c.append(1, &k, &v).unwrap();
        }
        assert_eq!(c.append(1, &k, &v), Err(CacheError::OutOfBlocks));
        assert!(!c.can_append(1, 1));
    }

    #[test]
    fn free_seq_releases_blocks_for_reuse() {
        let mut c = KvCache::new(H, DK, 2, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        let (k, v) = token(0);
        for _ in 0..2 * BLOCK_TOKENS {
            c.append(1, &k, &v).unwrap();
        }
        assert_eq!(c.stats().blocks_allocated, 2);
        c.free_seq(1).unwrap();
        assert_eq!(c.stats().blocks_allocated, 0);
        c.create_seq(2).unwrap();
        for _ in 0..2 * BLOCK_TOKENS {
            c.append(2, &k, &v).unwrap();
        }
        assert_eq!(c.seq_len(2).unwrap(), 2 * BLOCK_TOKENS);
    }

    #[test]
    fn empty_codec_set_is_an_error_not_a_panic() {
        assert!(matches!(
            KeyStorage::pq(Vec::new()),
            Err(CacheError::NoCodecs)
        ));
        assert!(KeyStorage::pq(match pq_storage(4) {
            KeyStorage::Pq { codecs } =>
                codecs.as_ref().clone(),
            _ => unreachable!(),
        })
        .is_ok());
    }

    #[test]
    fn block_views_match_gathers_fp16_and_pq() {
        // K=16 -> nibble-packed lanes, K=32 -> byte lanes
        for storage in
            [KeyStorage::Fp16, pq_storage(4), pq_storage_k(4, 32)]
        {
            let is_pq = matches!(storage, KeyStorage::Pq { .. });
            let packed = storage.packed();
            let mut c = KvCache::new(H, DK, 8, storage, ValueStorage::Fp32);
            c.create_seq(1).unwrap();
            for t in 0..70 {
                // 3 blocks, last one partial
                let (k, v) = token(500 + t);
                c.append(1, &k, &v).unwrap();
            }
            for head in 0..H {
                let mut vals = Vec::new();
                c.gather_values_into(1, head, &mut vals).unwrap();
                let mut from_blocks = Vec::new();
                let mut total = 0;
                for blk in c.blocks(1, head).unwrap() {
                    assert!(blk.len <= BLOCK_TOKENS);
                    assert_eq!(blk.values.len(), blk.len * DK);
                    from_blocks.extend_from_slice(blk.values);
                    total += blk.len;
                }
                assert_eq!(total, 70);
                assert_eq!(from_blocks, vals);
                if is_pq {
                    let mut codes = Vec::new();
                    c.gather_codes_into(1, head, &mut codes).unwrap();
                    // block lanes are subspace-major (m × row bytes);
                    // de-interleaving them must reproduce the token-
                    // major gather exactly
                    let m = 4usize;
                    let row =
                        if packed { BLOCK_TOKENS / 2 } else { BLOCK_TOKENS };
                    let mut tok = 0usize;
                    for b in c.blocks(1, head).unwrap() {
                        assert_eq!(b.codes.len(), m * row);
                        for t in 0..b.len {
                            for i in 0..m {
                                let got = if packed {
                                    (b.codes[i * row + t / 2]
                                        >> ((t % 2) * 4))
                                        & 0x0F
                                } else {
                                    b.codes[i * row + t]
                                };
                                assert_eq!(
                                    got,
                                    codes[(tok + t) * m + i],
                                    "head {head} tok {t} sub {i}"
                                );
                            }
                        }
                        tok += b.len;
                    }
                    assert_eq!(tok, 70);
                    assert!(c
                        .blocks(1, head)
                        .unwrap()
                        .all(|b| b.keys.is_empty()));
                } else {
                    let mut keys = Vec::new();
                    c.gather_keys_into(1, head, &mut keys).unwrap();
                    let concat: Vec<f32> = c
                        .blocks(1, head)
                        .unwrap()
                        .flat_map(|b| b.keys.iter().copied())
                        .collect();
                    assert_eq!(concat, keys);
                    assert!(c
                        .blocks(1, head)
                        .unwrap()
                        .all(|b| b.codes.is_empty()));
                }
            }
        }
    }

    #[test]
    fn blocks_unknown_seq_errors() {
        let c = KvCache::new(H, DK, 2, KeyStorage::Fp16, ValueStorage::Fp32);
        assert!(matches!(
            c.blocks(3, 0),
            Err(CacheError::UnknownSeq(3))
        ));
    }

    #[test]
    fn unknown_and_duplicate_seq_errors() {
        let mut c = KvCache::new(H, DK, 2, KeyStorage::Fp16, ValueStorage::Fp32);
        assert_eq!(c.seq_len(7), Err(CacheError::UnknownSeq(7)));
        c.create_seq(7).unwrap();
        assert_eq!(c.create_seq(7), Err(CacheError::DuplicateSeq(7)));
        assert_eq!(c.free_seq(8), Err(CacheError::UnknownSeq(8)));
    }

    #[test]
    fn stats_byte_accounting_fp16_vs_pq() {
        let (k, v) = token(3);
        let mut fp = KvCache::new(H, DK, 4, KeyStorage::Fp16, ValueStorage::Fp32);
        fp.create_seq(1).unwrap();
        for _ in 0..10 {
            fp.append(1, &k, &v).unwrap();
        }
        let s = fp.stats();
        assert_eq!(s.tokens, 10);
        assert_eq!(s.key_bytes, 10 * H * DK * 2);
        assert_eq!(s.value_bytes, 10 * H * DK * 2);
        assert_eq!(s.codebook_bytes, 0);

        let mut pq = KvCache::new(H, DK, 4, pq_storage(4), ValueStorage::Fp32);
        pq.create_seq(1).unwrap();
        for _ in 0..10 {
            pq.append(1, &k, &v).unwrap();
        }
        let s2 = pq.stats();
        // K=16 codes are nibble-packed: ⌈m/2⌉ = 2 bytes per token/head
        assert_eq!(s2.key_bytes, 10 * H * 2);
        assert_eq!(s2.value_bytes, s.value_bytes);
        assert!(s2.codebook_bytes > 0);
        // packed keys: d_k·2 / (m/2) = 16x here
        assert_eq!(
            fp.key_bytes_per_token_per_head()
                / pq.key_bytes_per_token_per_head(),
            16
        );

        // byte-coded K=32 keeps the unpacked m bytes per token per head
        let mut pq32 =
            KvCache::new(H, DK, 4, pq_storage_k(4, 32), ValueStorage::Fp32);
        pq32.create_seq(1).unwrap();
        for _ in 0..10 {
            pq32.append(1, &k, &v).unwrap();
        }
        assert_eq!(pq32.stats().key_bytes, 10 * H * 4);
        assert_eq!(pq32.key_bytes_per_token_per_head(), 4);
    }

    #[test]
    fn multi_seq_interleaving_isolated() {
        let mut c = KvCache::new(H, DK, 8, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        c.create_seq(2).unwrap();
        for t in 0..20 {
            let (k1, v1) = token(1000 + t);
            let (k2, v2) = token(2000 + t);
            c.append(1, &k1, &v1).unwrap();
            c.append(2, &k2, &v2).unwrap();
        }
        let mut k = Vec::new();
        c.gather_keys_into(1, 0, &mut k).unwrap();
        let (k1_0, _) = token(1000);
        assert_eq!(&k[0..DK], &k1_0[0..DK]);
        c.gather_keys_into(2, 0, &mut k).unwrap();
        let (k2_0, _) = token(2000);
        assert_eq!(&k[0..DK], &k2_0[0..DK]);
    }

    #[test]
    fn seq_blocks_tracks_per_seq_allocation() {
        let mut c =
            KvCache::new(H, DK, 8, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        c.create_seq(2).unwrap();
        assert_eq!(c.seq_blocks(1).unwrap(), 0);
        let (k, v) = token(9);
        for _ in 0..BLOCK_TOKENS + 1 {
            c.append(1, &k, &v).unwrap();
        }
        c.append(2, &k, &v).unwrap();
        assert_eq!(c.seq_blocks(1).unwrap(), 2);
        assert_eq!(c.seq_blocks(2).unwrap(), 1);
        assert!(matches!(
            c.seq_blocks(99),
            Err(CacheError::UnknownSeq(99))
        ));
        // free-and-reallocate keeps per-seq accounting consistent
        c.free_seq(1).unwrap();
        assert!(c.seq_blocks(1).is_err());
        assert_eq!(c.stats().blocks_allocated, 1);
    }

    #[test]
    fn free_and_reallocate_keeps_codecs_hot() {
        // preemption contract: freeing a PQ sequence must not tear down
        // the codecs — a re-admitted sequence re-encodes straight away
        let mut c = KvCache::new(
            H, DK, 4, pq_storage(4), pq_value_storage(4));
        c.create_seq(1).unwrap();
        let (k, v) = token(31);
        for _ in 0..BLOCK_TOKENS {
            c.append(1, &k, &v).unwrap();
        }
        let mut before = Vec::new();
        c.gather_codes_into(1, 0, &mut before).unwrap();
        c.free_seq(1).unwrap();
        assert!(c.codecs().is_some(), "key codecs survive free_seq");
        assert!(c.value_codecs().is_some(), "value codecs survive");
        // re-admit: identical tokens re-encode to identical codes
        c.create_seq(1).unwrap();
        for _ in 0..BLOCK_TOKENS {
            c.append(1, &k, &v).unwrap();
        }
        let mut after = Vec::new();
        c.gather_codes_into(1, 0, &mut after).unwrap();
        assert_eq!(before, after);
    }

    #[test]
    fn packed_block_reuse_is_clean_after_free() {
        // a freed block's packed lane holds stale nibbles; the next
        // sequence's even-slot whole-byte writes must not let them leak
        let mut c =
            KvCache::new(H, DK, 2, pq_storage(4), ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        for t in 0..5 {
            let (k, v) = token(50 + t);
            c.append(1, &k, &v).unwrap();
        }
        c.free_seq(1).unwrap();
        let codecs = c.codecs().unwrap().clone();
        c.create_seq(2).unwrap();
        let mut expected = Vec::new();
        for t in 0..3 {
            let (k, v) = token(80 + t);
            expected.extend(codecs[0].encode(&k[..DK]));
            c.append(2, &k, &v).unwrap();
        }
        let mut got = Vec::new();
        c.gather_codes_into(2, 0, &mut got).unwrap();
        assert_eq!(got, expected);
    }

    #[test]
    fn can_append_predicts_admission() {
        let mut c = KvCache::new(H, DK, 2, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        assert!(c.can_append(1, 2 * BLOCK_TOKENS));
        assert!(!c.can_append(1, 2 * BLOCK_TOKENS + 1));
        assert!(!c.can_append(99, 1), "unknown seq can't append");
    }

    fn pq_value_storage(m: usize) -> ValueStorage {
        let mut rng = Pcg32::seed(17);
        let calib: Vec<f32> =
            (0..128 * DK).map(|_| rng.next_f32_std()).collect();
        let codecs: Vec<PqCodec> = (0..H)
            .map(|_| PqCodec::train(&calib, DK, m, 16, &TrainOpts::default()))
            .collect();
        ValueStorage::pq(codecs).unwrap()
    }

    #[test]
    fn value_pq_mode_stores_codes_matching_direct_encode() {
        let vstore = pq_value_storage(4);
        let vcodecs = match &vstore {
            ValueStorage::Pq { codecs } => codecs.clone(),
            _ => unreachable!(),
        };
        let mut c = KvCache::new(H, DK, 8, KeyStorage::Fp16, vstore);
        c.create_seq(3).unwrap();
        let mut expected: Vec<Vec<u8>> = vec![Vec::new(); H];
        for t in 0..70 {
            // 3 blocks, last partial
            let (k, v) = token(300 + t);
            for head in 0..H {
                expected[head].extend(
                    vcodecs[head].encode(&v[head * DK..(head + 1) * DK]),
                );
            }
            c.append(3, &k, &v).unwrap();
        }
        assert!(c.is_value_pq());
        assert!(c.value_codecs().is_some());
        let mut codes = Vec::new();
        for head in 0..H {
            let n = c.gather_value_codes_into(3, head, &mut codes).unwrap();
            assert_eq!(n, 70);
            assert_eq!(codes, expected[head]);
            // block views expose subspace-major nibble-packed value-code
            // lanes (K=16) and no raw values
            let row = BLOCK_TOKENS / 2;
            let mut tok = 0usize;
            for b in c.blocks(3, head).unwrap() {
                assert_eq!(b.value_codes.len(), 4 * row);
                for t in 0..b.len {
                    for i in 0..4 {
                        assert_eq!(
                            (b.value_codes[i * row + t / 2]
                                >> ((t % 2) * 4))
                                & 0x0F,
                            codes[(tok + t) * 4 + i]
                        );
                    }
                }
                tok += b.len;
            }
            assert_eq!(tok, 70);
            assert!(c.blocks(3, head).unwrap().all(|b| b.values.is_empty()));
        }
    }

    #[test]
    #[should_panic(expected = "FP32 value caches")]
    fn gather_values_rejects_pq_value_mode() {
        let mut c =
            KvCache::new(H, DK, 4, KeyStorage::Fp16, pq_value_storage(4));
        c.create_seq(1).unwrap();
        let (k, v) = token(0);
        c.append(1, &k, &v).unwrap();
        let mut out = Vec::new();
        let _ = c.gather_values_into(1, 0, &mut out);
    }

    #[test]
    fn stats_value_accounting_reflects_active_mode() {
        let (k, v) = token(5);
        let mut fp = KvCache::new(
            H, DK, 4, KeyStorage::Fp16, ValueStorage::Fp32);
        let mut pq = KvCache::new(
            H, DK, 4, pq_storage(4), pq_value_storage(4));
        for c in [&mut fp, &mut pq] {
            c.create_seq(1).unwrap();
            for _ in 0..10 {
                c.append(1, &k, &v).unwrap();
            }
        }
        let s_fp = fp.stats();
        assert_eq!(s_fp.value_bytes, 10 * H * DK * 2);
        assert_eq!(fp.value_bytes_per_token_per_head(), DK * 2);

        // PQ values at K=16: nibble-packed ⌈m_v/2⌉ B/token/head + both
        // codebooks
        let s_pq = pq.stats();
        assert_eq!(s_pq.value_bytes, 10 * H * 2);
        assert_eq!(pq.value_bytes_per_token_per_head(), 2);
        let one_codebook: usize = pq
            .codecs()
            .unwrap()
            .iter()
            .map(|c| c.codebook.size_bytes_fp16())
            .sum();
        let value_codebook: usize = pq
            .value_codecs()
            .unwrap()
            .iter()
            .map(|c| c.codebook.size_bytes_fp16())
            .sum();
        assert_eq!(s_pq.codebook_bytes, one_codebook + value_codebook);
        assert!(s_pq.total_bytes() < s_fp.total_bytes());
    }

    #[test]
    fn empty_value_codec_set_is_an_error_not_a_panic() {
        assert!(matches!(
            ValueStorage::pq(Vec::new()),
            Err(CacheError::NoCodecs)
        ));
    }

    #[test]
    fn mixed_subspace_codecs_are_allowed_mixed_k_is_not() {
        let mut rng = Pcg32::seed(23);
        let calib: Vec<f32> =
            (0..128 * DK).map(|_| rng.next_f32_std()).collect();
        // per-head m is the calibrated-policy contract: legal
        let mixed_m = vec![
            PqCodec::train(&calib, DK, 4, 16, &TrainOpts::default()),
            PqCodec::train(&calib, DK, 8, 16, &TrainOpts::default()),
        ];
        assert!(KeyStorage::pq(mixed_m.clone()).is_ok());
        assert!(ValueStorage::pq(mixed_m).is_ok());
        // mismatched K is invalid: K decides the lane packing, which
        // must be uniform across heads
        let mixed_k = vec![
            PqCodec::train(&calib, DK, 4, 16, &TrainOpts::default()),
            PqCodec::train(&calib, DK, 4, 32, &TrainOpts::default()),
        ];
        assert!(matches!(
            KeyStorage::pq(mixed_k.clone()),
            Err(CacheError::MixedCodecs)
        ));
        assert!(matches!(
            ValueStorage::pq(mixed_k),
            Err(CacheError::MixedCodecs)
        ));
    }

    /// Per-head m (K=16 packed and K=32 byte lanes): codes land in the
    /// right per-head lanes and round-trip both through the gathers and
    /// through the swap tier.
    #[test]
    fn heterogeneous_m_lanes_roundtrip_and_swap() {
        for k in [16usize, 32] {
            let mut rng = Pcg32::seed(29);
            let calib: Vec<f32> =
                (0..128 * DK).map(|_| rng.next_f32_std()).collect();
            let het = |ms: [usize; H]| -> Vec<PqCodec> {
                ms.iter()
                    .map(|&m| {
                        PqCodec::train(
                            &calib, DK, m, k, &TrainOpts::default())
                    })
                    .collect()
            };
            let kcodecs = het([2, 8]);
            let vcodecs = het([8, 4]);
            let mut c = KvCache::new(
                H,
                DK,
                8,
                KeyStorage::pq(kcodecs.clone()).unwrap(),
                ValueStorage::pq(vcodecs.clone()).unwrap(),
            );
            assert_eq!(c.key_ms(), vec![2, 8]);
            assert_eq!(
                c.key_bytes_per_token_all_heads(),
                kcodecs.iter().map(|cc| cc.bytes_per_token()).sum()
            );
            c.create_seq(1).unwrap();
            let mut want_k: Vec<Vec<u8>> = vec![Vec::new(); H];
            let mut want_v: Vec<Vec<u8>> = vec![Vec::new(); H];
            for t in 0..70 {
                // 3 blocks, last partial
                let (kk, vv) = token(600 + t);
                for head in 0..H {
                    want_k[head].extend(
                        kcodecs[head].encode(&kk[head * DK..(head + 1) * DK]),
                    );
                    want_v[head].extend(
                        vcodecs[head].encode(&vv[head * DK..(head + 1) * DK]),
                    );
                }
                assert!(c.append(1, &kk, &vv).unwrap());
            }
            let mut got = Vec::new();
            for head in 0..H {
                c.gather_codes_into(1, head, &mut got).unwrap();
                assert_eq!(got, want_k[head], "keys head {head} k {k}");
                c.gather_value_codes_into(1, head, &mut got).unwrap();
                assert_eq!(got, want_v[head], "values head {head} k {k}");
                // block views expose exactly this head's m·row lane
                let row = if k <= 16 {
                    BLOCK_TOKENS / 2
                } else {
                    BLOCK_TOKENS
                };
                for b in c.blocks(1, head).unwrap() {
                    assert_eq!(
                        b.codes.len(),
                        kcodecs[head].codebook.m * row
                    );
                    assert_eq!(
                        b.value_codes.len(),
                        vcodecs[head].codebook.m * row
                    );
                }
            }
            // swap the non-uniform slabs out and back: bit-identical
            c.swap_out(1).unwrap();
            c.swap_in(1).unwrap();
            for head in 0..H {
                c.gather_codes_into(1, head, &mut got).unwrap();
                assert_eq!(got, want_k[head], "post-swap keys {head}");
                c.gather_value_codes_into(1, head, &mut got).unwrap();
                assert_eq!(got, want_v[head], "post-swap values {head}");
            }
        }
    }

    #[test]
    fn prune_threshold_skips_low_norm_tokens() {
        let mut c = KvCache::new(
            H, DK, 8, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        let (k, v) = token(11);
        let tiny_k = vec![1e-6f32; H * DK];
        // first token is never pruned, even below threshold
        c.set_prune_threshold(Some(1e-3));
        assert!(c.append(1, &tiny_k, &v).unwrap());
        assert_eq!(c.pruned_tokens(), 0);
        // normal-norm tokens survive, low-norm ones are dropped
        assert!(c.append(1, &k, &v).unwrap());
        assert!(!c.append(1, &tiny_k, &v).unwrap());
        assert!(!c.append(1, &tiny_k, &v).unwrap());
        assert_eq!(c.pruned_tokens(), 2);
        assert_eq!(c.seq_len(1).unwrap(), 2);
        // pruned appends never allocate blocks
        assert_eq!(c.seq_blocks(1).unwrap(), 1);
        // gathers see only the surviving set
        let mut keys = Vec::new();
        c.gather_keys_into(1, 0, &mut keys).unwrap();
        assert_eq!(keys.len(), 2 * DK);
        assert_eq!(&keys[DK..], &k[..DK]);
        // disarming restores store-everything behavior
        c.set_prune_threshold(None);
        assert!(c.append(1, &tiny_k, &v).unwrap());
        assert_eq!(c.seq_len(1).unwrap(), 3);
    }

    #[test]
    fn swap_roundtrip_restores_codes_bit_for_bit() {
        // PQ keys + PQ values (K=16, so both sides are nibble-packed):
        // swap out, let another sequence dirty the freed blocks, swap
        // back in — gathered codes must be identical (slabs are copied
        // whole, packed bytes included)
        let mut c =
            KvCache::new(H, DK, 4, pq_storage(4), pq_value_storage(4));
        c.create_seq(1).unwrap();
        for t in 0..70 {
            // 3 blocks, last partial
            let (k, v) = token(700 + t);
            c.append(1, &k, &v).unwrap();
        }
        let mut before_k = Vec::new();
        let mut before_v = Vec::new();
        c.gather_codes_into(1, 1, &mut before_k).unwrap();
        c.gather_value_codes_into(1, 1, &mut before_v).unwrap();

        c.swap_out(1).unwrap();
        assert!(c.is_swapped(1));
        assert_eq!(c.swapped_blocks(1), 3);
        assert!(c.swap_bytes() > 0);
        assert_eq!(c.stats().blocks_allocated, 0);
        assert!(matches!(c.seq_len(1), Err(CacheError::UnknownSeq(1))));

        // scribble over the whole pool with different content
        c.create_seq(2).unwrap();
        for t in 0..4 * BLOCK_TOKENS {
            let (k, v) = token(9000 + t as u64);
            c.append(2, &k, &v).unwrap();
        }
        assert_eq!(c.swap_in(1), Err(CacheError::OutOfBlocks));
        assert!(c.is_swapped(1), "failed swap-in keeps the spill entry");
        c.free_seq(2).unwrap();

        c.swap_in(1).unwrap();
        assert!(!c.is_swapped(1));
        assert_eq!(c.seq_len(1).unwrap(), 70);
        let mut after_k = Vec::new();
        let mut after_v = Vec::new();
        c.gather_codes_into(1, 1, &mut after_k).unwrap();
        c.gather_value_codes_into(1, 1, &mut after_v).unwrap();
        assert_eq!(before_k, after_k);
        assert_eq!(before_v, after_v);
    }

    #[test]
    fn swap_roundtrip_restores_byte_coded_lanes_too() {
        // unpacked K=32 key storage through the swap tier
        let mut c = KvCache::new(
            H, DK, 4, pq_storage_k(4, 32), ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        for t in 0..40 {
            let (k, v) = token(4000 + t);
            c.append(1, &k, &v).unwrap();
        }
        let mut before = Vec::new();
        c.gather_codes_into(1, 0, &mut before).unwrap();
        c.swap_out(1).unwrap();
        c.swap_in(1).unwrap();
        let mut after = Vec::new();
        c.gather_codes_into(1, 0, &mut after).unwrap();
        assert_eq!(before, after);
    }

    #[test]
    fn swap_roundtrip_restores_raw_tensors_fp16_path() {
        let mut c =
            KvCache::new(H, DK, 4, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(5).unwrap();
        for t in 0..40 {
            let (k, v) = token(40 + t);
            c.append(5, &k, &v).unwrap();
        }
        let mut before = Vec::new();
        c.gather_keys_into(5, 0, &mut before).unwrap();
        c.swap_out(5).unwrap();
        c.swap_in(5).unwrap();
        let mut after = Vec::new();
        c.gather_keys_into(5, 0, &mut after).unwrap();
        assert_eq!(before, after);
        // and the sequence keeps growing from where it left off
        let (k, v) = token(99);
        c.append(5, &k, &v).unwrap();
        assert_eq!(c.seq_len(5).unwrap(), 41);
    }

    #[test]
    fn swap_error_paths() {
        let mut c =
            KvCache::new(H, DK, 2, KeyStorage::Fp16, ValueStorage::Fp32);
        assert!(matches!(
            c.swap_out(1),
            Err(CacheError::UnknownSeq(1))
        ));
        assert!(matches!(c.swap_in(1), Err(CacheError::UnknownSeq(1))));
        c.create_seq(1).unwrap();
        let (k, v) = token(0);
        c.append(1, &k, &v).unwrap();
        c.swap_out(1).unwrap();
        // a live duplicate blocks swap-in
        c.create_seq(1).unwrap();
        assert!(matches!(
            c.swap_in(1),
            Err(CacheError::DuplicateSeq(1))
        ));
        assert!(matches!(
            c.swap_out(1),
            Err(CacheError::DuplicateSeq(1))
        ));
        c.free_seq(1).unwrap();
        c.drop_swapped(1);
        assert!(matches!(c.swap_in(1), Err(CacheError::UnknownSeq(1))));
        assert_eq!(c.swap_bytes(), 0);
    }

    #[test]
    fn corrupted_swap_entry_is_rejected_and_discarded() {
        // PQ keys (code slab) and the FP16 raw-slab path both verify
        let mut c =
            KvCache::new(H, DK, 4, pq_storage(4), ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        for t in 0..40 {
            let (k, v) = token(60 + t);
            c.append(1, &k, &v).unwrap();
        }
        c.swap_out(1).unwrap();
        assert!(c.corrupt_swapped(1));
        assert_eq!(c.swap_in(1), Err(CacheError::Corrupt(1)));
        assert!(
            !c.is_swapped(1),
            "poisoned spill entry must be discarded"
        );
        assert_eq!(
            c.stats().blocks_allocated,
            0,
            "rejected restore must not leak blocks"
        );

        let mut f =
            KvCache::new(H, DK, 4, KeyStorage::Fp16, ValueStorage::Fp32);
        f.create_seq(2).unwrap();
        let (k, v) = token(0);
        f.append(2, &k, &v).unwrap();
        f.swap_out(2).unwrap();
        assert!(f.corrupt_swapped(2));
        assert_eq!(f.swap_in(2), Err(CacheError::Corrupt(2)));
        assert!(!f.corrupt_swapped(2), "entry is gone");
    }

    #[test]
    fn block_checksum_is_stable_and_content_sensitive() {
        let mut c =
            KvCache::new(H, DK, 4, pq_storage(4), pq_value_storage(4));
        c.create_seq(1).unwrap();
        for t in 0..2 * BLOCK_TOKENS {
            let (k, v) = token(t as u64);
            c.append(1, &k, &v).unwrap();
        }
        let ids = c.seq_block_ids(1).unwrap().to_vec();
        let h0 = c.block_checksum(ids[0], 0xcbf29ce484222325);
        let h1 = c.block_checksum(ids[1], 0xcbf29ce484222325);
        assert_ne!(h0, h1, "different content, different checksum");
        assert_eq!(
            h0,
            c.block_checksum(ids[0], 0xcbf29ce484222325),
            "re-hashing untouched content is stable"
        );
        // chaining is order-sensitive
        assert_ne!(
            c.block_checksum(ids[1], h0),
            c.block_checksum(ids[0], h1)
        );
    }

    #[test]
    fn attach_prefix_shares_blocks_copy_on_write() {
        let mut c =
            KvCache::new(H, DK, 6, KeyStorage::Fp16, ValueStorage::Fp32);
        c.create_seq(1).unwrap();
        for t in 0..2 * BLOCK_TOKENS + 3 {
            let (k, v) = token(t as u64);
            c.append(1, &k, &v).unwrap();
        }
        // share seq 1's two full blocks with a new sequence
        let shared: Vec<BlockId> =
            c.seq_block_ids(1).unwrap()[..2].to_vec();
        c.create_seq(2).unwrap();
        c.attach_prefix(2, &shared, 2 * BLOCK_TOKENS).unwrap();
        assert_eq!(c.seq_len(2).unwrap(), 2 * BLOCK_TOKENS);
        assert_eq!(
            &c.seq_block_ids(2).unwrap()[..2],
            &shared[..],
            "physical blocks are shared"
        );
        let s = c.stats();
        assert_eq!(s.shared_blocks, 2);
        // seq 1 used 3 blocks; seq 2 added none yet
        assert_eq!(s.blocks_allocated, 3);

        // COW divergence: appending to seq 2 allocates a private block
        // and never touches the shared ones
        let mut k1_before = Vec::new();
        c.gather_keys_into(1, 0, &mut k1_before).unwrap();
        let (k, v) = token(555);
        c.append(2, &k, &v).unwrap();
        assert_ne!(
            c.seq_block_ids(2).unwrap()[2],
            c.seq_block_ids(1).unwrap()[2],
            "divergent tail is private"
        );
        let mut k1_after = Vec::new();
        c.gather_keys_into(1, 0, &mut k1_after).unwrap();
        assert_eq!(k1_before, k1_after, "sharer's append is invisible");

        // freeing the original keeps the shared blocks alive for seq 2
        c.free_seq(1).unwrap();
        assert_eq!(c.stats().shared_blocks, 0);
        let mut k2 = Vec::new();
        c.gather_keys_into(2, 0, &mut k2).unwrap();
        assert_eq!(&k2[..DK], &k1_after[..DK]);
        // and freeing the last holder returns everything
        c.free_seq(2).unwrap();
        assert_eq!(c.stats().blocks_allocated, 0);
    }

    #[test]
    fn cache_accounting_property() {
        // property: token count in stats always equals sum of seq lens,
        // and blocks are conserved
        let mut c = KvCache::new(H, DK, 16, KeyStorage::Fp16, ValueStorage::Fp32);
        let mut lens: HashMap<SeqId, usize> = HashMap::new();
        let mut next_id: SeqId = 0;
        crate::prop_assert!("cache-accounting", 300, |g| {
            match g.usize_in(0, 2) {
                0 => {
                    let id = next_id;
                    next_id += 1;
                    c.create_seq(id).unwrap();
                    lens.insert(id, 0);
                }
                1 => {
                    if let Some((&id, _)) =
                        lens.iter().nth(g.usize_in(0, lens.len().max(1) - 1))
                    {
                        let (k, v) = token(id * 31 + 7);
                        if c.append(id, &k, &v).is_ok() {
                            *lens.get_mut(&id).unwrap() += 1;
                        }
                    }
                }
                _ => {
                    if let Some((&id, _)) =
                        lens.iter().nth(g.usize_in(0, lens.len().max(1) - 1))
                    {
                        c.free_seq(id).unwrap();
                        lens.remove(&id);
                    }
                }
            }
            let s = c.stats();
            let want: usize = lens.values().sum();
            if s.tokens != want {
                return Err(format!("tokens {} != {}", s.tokens, want));
            }
            if s.blocks_allocated + c.alloc.available() != s.blocks_total {
                return Err("block leak".into());
            }
            Ok(())
        });
    }
}
