//! Fixed-size block allocator for the paged KV-cache.

/// Tokens per cache block (vLLM uses 16; 32 keeps per-seq overhead low
/// for the paper's L ≤ 1024 regime while exercising multi-block paths).
pub const BLOCK_TOKENS: usize = 32;

/// Opaque block handle.
pub type BlockId = u32;

/// Zero-copy view of one head's slice of one cache block, in the order
/// the sequence's tokens were appended. Produced by `KvCache::blocks`;
/// the batched decode kernels scan these in place instead of gathering
/// the paged cache into contiguous scratch.
///
/// Code lanes are **subspace-major** (the vector-database "fast scan"
/// layout): a lane is the full `(m × BLOCK_TOKENS)` row-major matrix
/// of the block — row `i` holds subspace `i`'s codes for every token
/// slot — and only the first [`BlockView::len`] entries of each row
/// are valid. For K ≤ 16 codecs the lane is **nibble-packed**,
/// `(m × BLOCK_TOKENS/2)` bytes with two 4-bit codes per byte (low
/// nibble = even token slot). The ADC scans
/// (`LookupTable::scores_lanes{,_packed}`) and the fused value decodes
/// (`pq::values::weighted_decode_lanes{,_packed}`) consume
/// `(lane, len)` pairs directly, keeping one LUT/accumulator row hot
/// while a block's codes stream. Float lanes (keys/values) stay
/// token-major — their consumers walk whole `d_k` rows.
#[derive(Clone, Copy, Debug)]
pub struct BlockView<'a> {
    /// valid tokens in this block (≤ [`BLOCK_TOKENS`]; only the last
    /// block of a sequence is partial)
    pub len: usize,
    /// this head's raw keys, (len × d_k) row-major — empty in PQ mode
    pub keys: &'a [f32],
    /// this head's PQ key-code lane, subspace-major
    /// (m × [`BLOCK_TOKENS`]), or (m × [`BLOCK_TOKENS`]/2) when the
    /// key codec nibble-packs (K ≤ 16), with the first `len` tokens of
    /// each row valid — empty in FP16 mode
    pub codes: &'a [u8],
    /// this head's raw values, (len × d_k) row-major — empty when values
    /// are PQ-coded (`ValueStorage::Pq`)
    pub values: &'a [f32],
    /// this head's PQ value-code lane, subspace-major
    /// (m_v × [`BLOCK_TOKENS`]) or its packed sibling, with the first
    /// `len` tokens of each row valid — empty when values are raw
    /// (`ValueStorage::Fp32`)
    pub value_codes: &'a [u8],
}

/// Free-list block allocator over a fixed budget of blocks, with
/// per-block reference counts so immutable prefix blocks can be shared
/// copy-on-write across sequences: `alloc` hands out a block at
/// refcount 1, `retain` adds a holder, and `release` only returns the
/// block to the pool once the last holder lets go.
#[derive(Debug)]
pub struct BlockAllocator {
    total: usize,
    free: Vec<BlockId>,
    /// unique live blocks (each counted once however many holders)
    allocated: usize,
    /// per-block holder count; 0 = on the free list
    refs: Vec<u32>,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize) -> Self {
        assert!(total_blocks > 0);
        Self {
            total: total_blocks,
            // LIFO free list: hot blocks are reused while still cached
            free: (0..total_blocks as BlockId).rev().collect(),
            allocated: 0,
            refs: vec![0; total_blocks],
        }
    }

    /// Allocate one block; `None` when the budget is exhausted
    /// (the scheduler's admission-control signal).
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        self.allocated += 1;
        self.refs[id as usize] = 1;
        Some(id)
    }

    /// Add a holder to a live block (prefix-cache sharing).
    pub fn retain(&mut self, id: BlockId) {
        let r = &mut self.refs[id as usize];
        debug_assert!(*r > 0, "retain of free block {id}");
        *r += 1;
    }

    /// Drop one holder; the block returns to the pool when the last
    /// holder releases it.
    pub fn release(&mut self, id: BlockId) {
        let r = &mut self.refs[id as usize];
        debug_assert!(*r > 0, "double free of block {id}");
        if *r == 0 {
            return; // release-side tolerance in release builds
        }
        *r -= 1;
        if *r == 0 {
            self.free.push(id);
            self.allocated -= 1;
        }
    }

    /// Current holder count of a block (0 = free).
    pub fn ref_count(&self, id: BlockId) -> u32 {
        self.refs[id as usize]
    }

    /// Extra holders beyond the first across all live blocks — the
    /// number of physical blocks saved by prefix sharing.
    pub fn shared_refs(&self) -> usize {
        self.refs.iter().map(|&r| r.saturating_sub(1) as usize).sum()
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn allocated(&self) -> usize {
        self.allocated
    }

    pub fn total(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_until_exhaustion() {
        let mut a = BlockAllocator::new(3);
        let b1 = a.alloc().unwrap();
        let b2 = a.alloc().unwrap();
        let b3 = a.alloc().unwrap();
        assert_eq!(a.alloc(), None);
        assert_eq!(a.allocated(), 3);
        assert_eq!(a.available(), 0);
        // ids are distinct
        assert!(b1 != b2 && b2 != b3 && b1 != b3);
    }

    #[test]
    fn release_recycles() {
        let mut a = BlockAllocator::new(2);
        let b1 = a.alloc().unwrap();
        let _b2 = a.alloc().unwrap();
        a.release(b1);
        assert_eq!(a.available(), 1);
        let b3 = a.alloc().unwrap();
        assert_eq!(b3, b1, "LIFO reuse of the hot block");
    }

    #[test]
    #[should_panic(expected = "double free")]
    #[cfg(debug_assertions)] // debug_assert! is compiled out in release
    fn double_free_caught_in_debug() {
        let mut a = BlockAllocator::new(2);
        let b = a.alloc().unwrap();
        a.release(b);
        a.release(b);
    }

    #[test]
    fn retain_keeps_shared_block_alive() {
        let mut a = BlockAllocator::new(2);
        let b = a.alloc().unwrap();
        a.retain(b); // second holder
        assert_eq!(a.ref_count(b), 2);
        assert_eq!(a.shared_refs(), 1);
        a.release(b); // first holder lets go: still live
        assert_eq!(a.ref_count(b), 1);
        assert_eq!(a.allocated(), 1);
        assert_eq!(a.available(), 1);
        assert_eq!(a.shared_refs(), 0);
        a.release(b); // last holder: back to the pool
        assert_eq!(a.ref_count(b), 0);
        assert_eq!(a.allocated(), 0);
        assert_eq!(a.available(), 2);
    }

    #[test]
    #[should_panic(expected = "retain of free block")]
    #[cfg(debug_assertions)]
    fn retain_of_free_block_caught_in_debug() {
        let mut a = BlockAllocator::new(1);
        let b = a.alloc().unwrap();
        a.release(b);
        a.retain(b);
    }

    #[test]
    fn conservation_property() {
        // allocated + available == total at every step
        let mut a = BlockAllocator::new(16);
        let mut held = Vec::new();
        crate::prop_assert!("block-conservation", 200, |g| {
            if g.bool() {
                if let Some(b) = a.alloc() {
                    held.push(b);
                }
            } else if !held.is_empty() {
                let i = g.usize_in(0, held.len() - 1);
                a.release(held.swap_remove(i));
            }
            if a.allocated() + a.available() == a.total() {
                Ok(())
            } else {
                Err(format!(
                    "leak: {} + {} != {}",
                    a.allocated(),
                    a.available(),
                    a.total()
                ))
            }
        });
    }
}
