//! Fixed-size block allocator for the paged KV-cache.

/// Tokens per cache block (vLLM uses 16; 32 keeps per-seq overhead low
/// for the paper's L ≤ 1024 regime while exercising multi-block paths).
pub const BLOCK_TOKENS: usize = 32;

/// Opaque block handle.
pub type BlockId = u32;

/// Zero-copy view of one head's slice of one cache block, in the order
/// the sequence's tokens were appended. Produced by `KvCache::blocks`;
/// the batched decode kernels scan these in place instead of gathering
/// the paged cache into contiguous scratch.
///
/// Code lanes are **subspace-major** (the vector-database "fast scan"
/// layout): a lane is the full `(m × BLOCK_TOKENS)` row-major matrix
/// of the block — row `i` holds subspace `i`'s codes for every token
/// slot — and only the first [`BlockView::len`] entries of each row
/// are valid. The ADC scan (`LookupTable::scores_lanes`) and the fused
/// value decode (`pq::values::weighted_decode_lanes`) consume
/// `(lane, len)` pairs directly, keeping one LUT/accumulator row hot
/// while a block's codes stream. Float lanes (keys/values) stay
/// token-major — their consumers walk whole `d_k` rows.
#[derive(Clone, Copy, Debug)]
pub struct BlockView<'a> {
    /// valid tokens in this block (≤ [`BLOCK_TOKENS`]; only the last
    /// block of a sequence is partial)
    pub len: usize,
    /// this head's raw keys, (len × d_k) row-major — empty in PQ mode
    pub keys: &'a [f32],
    /// this head's PQ key-code lane, subspace-major
    /// (m × [`BLOCK_TOKENS`]) with the first `len` of each row valid —
    /// empty in FP16 mode
    pub codes: &'a [u8],
    /// this head's raw values, (len × d_k) row-major — empty when values
    /// are PQ-coded (`ValueStorage::Pq`)
    pub values: &'a [f32],
    /// this head's PQ value-code lane, subspace-major
    /// (m_v × [`BLOCK_TOKENS`]) with the first `len` of each row valid
    /// — empty when values are raw (`ValueStorage::Fp32`)
    pub value_codes: &'a [u8],
}

/// Free-list block allocator over a fixed budget of blocks.
#[derive(Debug)]
pub struct BlockAllocator {
    total: usize,
    free: Vec<BlockId>,
    allocated: usize,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize) -> Self {
        assert!(total_blocks > 0);
        Self {
            total: total_blocks,
            // LIFO free list: hot blocks are reused while still cached
            free: (0..total_blocks as BlockId).rev().collect(),
            allocated: 0,
        }
    }

    /// Allocate one block; `None` when the budget is exhausted
    /// (the scheduler's admission-control signal).
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        self.allocated += 1;
        Some(id)
    }

    /// Return a block to the pool.
    pub fn release(&mut self, id: BlockId) {
        debug_assert!(
            !self.free.contains(&id),
            "double free of block {id}"
        );
        self.free.push(id);
        self.allocated -= 1;
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn allocated(&self) -> usize {
        self.allocated
    }

    pub fn total(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_until_exhaustion() {
        let mut a = BlockAllocator::new(3);
        let b1 = a.alloc().unwrap();
        let b2 = a.alloc().unwrap();
        let b3 = a.alloc().unwrap();
        assert_eq!(a.alloc(), None);
        assert_eq!(a.allocated(), 3);
        assert_eq!(a.available(), 0);
        // ids are distinct
        assert!(b1 != b2 && b2 != b3 && b1 != b3);
    }

    #[test]
    fn release_recycles() {
        let mut a = BlockAllocator::new(2);
        let b1 = a.alloc().unwrap();
        let _b2 = a.alloc().unwrap();
        a.release(b1);
        assert_eq!(a.available(), 1);
        let b3 = a.alloc().unwrap();
        assert_eq!(b3, b1, "LIFO reuse of the hot block");
    }

    #[test]
    #[should_panic(expected = "double free")]
    #[cfg(debug_assertions)] // debug_assert! is compiled out in release
    fn double_free_caught_in_debug() {
        let mut a = BlockAllocator::new(2);
        let b = a.alloc().unwrap();
        a.release(b);
        a.release(b);
    }

    #[test]
    fn conservation_property() {
        // allocated + available == total at every step
        let mut a = BlockAllocator::new(16);
        let mut held = Vec::new();
        crate::prop_assert!("block-conservation", 200, |g| {
            if g.bool() {
                if let Some(b) = a.alloc() {
                    held.push(b);
                }
            } else if !held.is_empty() {
                let i = g.usize_in(0, held.len() - 1);
                a.release(held.swap_remove(i));
            }
            if a.allocated() + a.available() == a.total() {
                Ok(())
            } else {
                Err(format!(
                    "leak: {} + {} != {}",
                    a.allocated(),
                    a.available(),
                    a.total()
                ))
            }
        });
    }
}
