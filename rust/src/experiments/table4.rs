//! Table 4 — head-to-head comparison at equivalent memory budgets.
//!
//! For each bytes/token budget, which methods fit and what cosine do
//! they achieve. Under exact byte accounting (see quant tests), scalar
//! methods occupy the 64/32 B budgets while only LOOKAT can serve
//! ≤ 16 B/token — which *strengthens* the paper's qualitative claim
//! (scalar quantization is infeasible in the high-compression regime).

use super::eval::Method;
use super::report::{MdTable, Report};
use super::table1::{self, Row as T1Row};
use crate::util::json::Json;

pub struct BudgetRow {
    pub budget_bytes: usize,
    pub entries: Vec<(Method, f64, f64)>, // (method, compression, cosine)
}

/// Derive the budget table from Table-1 rows.
pub fn compute(rows: &[T1Row]) -> Vec<BudgetRow> {
    let budgets = [64usize, 32, 16, 8, 4, 2];
    budgets
        .iter()
        .map(|&b| {
            let entries = rows
                .iter()
                .filter(|r| {
                    r.method != Method::Fp16
                        && r.bytes_per_token as usize == b
                })
                .map(|r| (r.method, r.compression, r.agg.cosine.0))
                .collect();
            BudgetRow { budget_bytes: b, entries }
        })
        .collect()
}

pub fn render(rows: &[BudgetRow]) -> Report {
    let mut t =
        MdTable::new(&["Memory Budget", "Method", "Compression",
                       "Cosine Sim"]);
    let mut arr = Vec::new();
    for r in rows {
        if r.entries.is_empty() {
            t.row(vec![
                format!("{} B/token", r.budget_bytes),
                "—".into(),
                "—".into(),
                "—".into(),
            ]);
        }
        for (m, comp, cos) in &r.entries {
            t.row(vec![
                format!("{} B/token", r.budget_bytes),
                m.name(),
                format!("{comp:.0}×"),
                format!("{cos:.3}"),
            ]);
            let mut o = Json::obj();
            o.set("budget_bytes", Json::Num(r.budget_bytes as f64));
            o.set("method", Json::Str(m.name()));
            o.set("compression", Json::Num(*comp));
            o.set("cosine", Json::Num(*cos));
            arr.push(o);
        }
    }
    let markdown = format!(
        "Exact byte accounting (d_k=64 keys): INT8 = 64 B, INT4 = 32 B, \
         LOOKAT-m = m B. Scalar quantization cannot enter the ≤16 B \
         regime at all — only LOOKAT serves those budgets.\n\n{}",
        t.render()
    );
    Report {
        id: "table4".into(),
        title: "Equal-memory head-to-head (paper Table 4)".into(),
        markdown,
        json: Json::Arr(arr),
        csv: t.to_csv(),
    }
}

pub fn run(quick: bool) -> anyhow::Result<Vec<BudgetRow>> {
    let (len, stride) = if quick { (96, 16) } else { (512, 8) };
    let t1 = table1::compute(len, stride, 0xA11CE);
    let rows = compute(&t1);
    render(&rows).emit()?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_partition_methods_correctly() {
        let t1 = table1::compute(64, 16, 3);
        let rows = compute(&t1);
        let find = |b: usize| rows.iter().find(|r| r.budget_bytes == b)
            .unwrap();
        // 64 B: INT8 only
        assert_eq!(find(64).entries.len(), 1);
        assert_eq!(find(64).entries[0].0.name(), "INT8");
        // 32 B: INT4 only
        assert_eq!(find(32).entries[0].0.name(), "INT4");
        // 16/8/4/2 B: LOOKAT only
        for (b, name) in
            [(16, "LOOKAT-16"), (8, "LOOKAT-8"), (4, "LOOKAT-4"),
             (2, "LOOKAT-2")]
        {
            let r = find(b);
            assert_eq!(r.entries.len(), 1, "budget {b}");
            assert_eq!(r.entries[0].0.name(), name);
        }
    }

    #[test]
    fn lookat_holds_quality_in_exclusive_regime() {
        let t1 = table1::compute(64, 16, 3);
        let rows = compute(&t1);
        for r in rows.iter().filter(|r| r.budget_bytes <= 16) {
            for (_, _, cos) in &r.entries {
                assert!(*cos > 0.8, "budget {}: cosine {}", r.budget_bytes,
                        cos);
            }
        }
    }
}
