//! The experiment harness: regenerates every table and figure of the
//! paper's evaluation section (§4). See DESIGN.md's experiment index.
//!
//! | id | paper artifact | module |
//! |----|----------------|--------|
//! | T1 | Table 1 compression–quality | [`table1`] |
//! | T2 | Table 2 subspace ablation   | [`table2`] |
//! | T3 | Table 3 long-context        | [`table3`] |
//! | T4 | Table 4 memory budgets      | [`table4`] |
//! | F3 | Figure 3 four-panel + Pareto| [`figure3`] |
//! | F4 | Figure 4 attention maps     | [`figure4`] |
//! | E1 | §4.7 efficiency analysis    | [`efficiency`] |
//!
//! Every experiment prints the paper-shaped table and writes
//! `artifacts/reports/<id>.{md,json,csv}` via [`report`].

pub mod ablation_calibration;
pub mod ablation_centroids;
pub mod ablation_values;
pub mod efficiency;
pub mod eval;
pub mod figure3;
pub mod figure4;
pub mod report;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;

pub use eval::{EvalContext, Method};

/// Run an experiment by id ("table1", ..., "figure4", "efficiency",
/// "all"). `quick` shrinks sample sizes for CI.
pub fn run(id: &str, quick: bool) -> anyhow::Result<()> {
    match id {
        "table1" => table1::run(quick).map(|_| ()),
        "table2" => table2::run(quick).map(|_| ()),
        "table3" => table3::run(quick).map(|_| ()),
        "table4" => table4::run(quick).map(|_| ()),
        "figure3" => figure3::run(quick).map(|_| ()),
        "figure4" => figure4::run(quick).map(|_| ()),
        "efficiency" => efficiency::run(quick).map(|_| ()),
        "ablation-values" => ablation_values::run(quick).map(|_| ()),
        "ablation-centroids" => ablation_centroids::run(quick).map(|_| ()),
        "ablation-calibration" => {
            ablation_calibration::run(quick).map(|_| ())
        }
        "all" => {
            table1::run(quick)?;
            table2::run(quick)?;
            table3::run(quick)?;
            table4::run(quick)?;
            figure3::run(quick)?;
            figure4::run(quick)?;
            efficiency::run(quick)?;
            ablation_values::run(quick)?;
            ablation_centroids::run(quick)?;
            ablation_calibration::run(quick)?;
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (table1..4, figure3, figure4, \
             efficiency, ablation-values, ablation-centroids, \
             ablation-calibration, all)"
        ),
    }
}
