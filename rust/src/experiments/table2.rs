//! Table 2 — subspace granularity ablation: m ∈ {2,4,8,16} at fixed
//! K = 256, trading codebook memory against similarity fidelity.

use super::eval::{EvalContext, Method};
use super::report::{MdTable, Report};
use crate::util::json::Json;

pub struct Row {
    pub m: usize,
    pub codebook_bytes: usize,
    pub cosine: f64,
}

/// Codebook storage per head, FP16 entries (paper's accounting):
/// m × K × d_sub × 2 B = K × d_k × 2 B, independent of m — the paper's
/// "codebook size" column (512 B … 4 KB) instead counts *per-subspace
/// table* growth m × 256 B; we report that figure for parity.
pub fn paper_codebook_bytes(m: usize) -> usize {
    m * 256
}

pub fn compute(len: usize, stride: usize, seed: u64) -> Vec<Row> {
    let ctx = EvalContext::build(len, seed);
    [2usize, 4, 8, 16]
        .iter()
        .map(|&m| {
            let (_, agg) = ctx.evaluate(Method::Lookat { m }, stride);
            Row {
                m,
                codebook_bytes: paper_codebook_bytes(m),
                cosine: agg.cosine.0,
            }
        })
        .collect()
}

pub fn render(rows: &[Row]) -> Report {
    let mut t = MdTable::new(&["Subspaces (m)", "Codebook Size",
                               "Cosine Sim"]);
    let mut arr = Vec::new();
    for r in rows {
        let size = if r.codebook_bytes >= 1024 {
            format!("{} KB", r.codebook_bytes / 1024)
        } else {
            format!("{} B", r.codebook_bytes)
        };
        t.row(vec![format!("{}", r.m), size, format!("{:.3}", r.cosine)]);
        let mut o = Json::obj();
        o.set("m", Json::Num(r.m as f64));
        o.set("codebook_bytes", Json::Num(r.codebook_bytes as f64));
        o.set("cosine", Json::Num(r.cosine));
        arr.push(o);
    }
    Report {
        id: "table2".into(),
        title: "Subspace granularity ablation (paper Table 2)".into(),
        markdown: t.render(),
        json: Json::Arr(arr),
        csv: t.to_csv(),
    }
}

pub fn run(quick: bool) -> anyhow::Result<Vec<Row>> {
    let (len, stride) = if quick { (96, 16) } else { (512, 8) };
    let rows = compute(len, stride, 0xAB1A);
    render(&rows).emit()?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn granularity_sweep_shape() {
        let rows = compute(64, 16, 5);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].m, 2);
        assert_eq!(rows[0].codebook_bytes, 512);
        assert_eq!(rows[3].codebook_bytes, 4096);
        // paper's observation: quality stays in a narrow band across m —
        // all configurations preserve high cosine
        for r in &rows {
            assert!(r.cosine > 0.8, "m={} cosine={}", r.m, r.cosine);
        }
    }

    #[test]
    fn render_matches_paper_units() {
        let rows = compute(64, 16, 5);
        let rep = render(&rows);
        assert!(rep.markdown.contains("512 B"));
        assert!(rep.markdown.contains("4 KB"));
    }
}
