//! Shared evaluation machinery for all tables/figures.
//!
//! Faithful to paper §4.1: KV caches are extracted from the model's
//! *first attention layer* over three text genres; every compression
//! method is evaluated decode-style — for each query position `t`, the
//! attention distribution over the causal prefix `[0, t]` and the
//! resulting output vector are compared against the FP16 oracle.

use crate::metrics::{AggregateFidelity, FidelityReport};
use crate::model::{ByteTokenizer, Gpt2, ModelConfig, Weights};
use crate::pq::{LookupTable, PqCodec, TrainOpts};
use crate::quant;
use crate::tensor::softmax_inplace;
use crate::workload::{Corpus, Genre};

/// Compression method under evaluation (rows of Tables 1 & 4).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    Fp16,
    Int8,
    Int4,
    Lookat { m: usize },
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Fp16 => "FP16 (Baseline)".into(),
            Method::Int8 => "INT8".into(),
            Method::Int4 => "INT4".into(),
            Method::Lookat { m } => format!("LOOKAT-{m}"),
        }
    }

    /// Key-storage bytes per token per head (exact accounting; see
    /// quant::tests for the paper-discrepancy note).
    pub fn bytes_per_token(&self, d_k: usize) -> f64 {
        match self {
            Method::Fp16 => (d_k * 2) as f64,
            Method::Int8 => d_k as f64,
            Method::Int4 => d_k as f64 / 2.0,
            Method::Lookat { m } => *m as f64,
        }
    }

    /// Compression ratio vs FP16 keys.
    pub fn compression(&self, d_k: usize) -> f64 {
        (d_k * 2) as f64 / self.bytes_per_token(d_k)
    }
}

/// One extracted sample: layer-0 K/V/Q for every head.
pub struct Sample {
    pub genre: Genre,
    pub len: usize,
    pub d_k: usize,
    /// per head: (len × d_k)
    pub keys: Vec<Vec<f32>>,
    pub values: Vec<Vec<f32>>,
    pub queries: Vec<Vec<f32>>,
    /// per head: calibration keys from a *different* text of the same
    /// genre. Training codebooks on the evaluated cache itself would let
    /// K-Means memorize it (K=256 ≈ L), reporting spuriously-perfect
    /// fidelity; deployment trains on calibration data (paper §5.1).
    pub calib_keys: Vec<Vec<f32>>,
    /// per head: calibration values (for the §5.2 value-PQ extension)
    pub calib_values: Vec<Vec<f32>>,
}

/// Evaluation context: the model + extracted samples.
pub struct EvalContext {
    pub model_cfg: ModelConfig,
    pub samples: Vec<Sample>,
    pub seed: u64,
}

impl EvalContext {
    /// Build the paper's setting: one sample per genre at length `len`,
    /// KV from layer 0 of the anisotropic-init GPT-2-geometry model.
    pub fn build(len: usize, seed: u64) -> EvalContext {
        Self::build_with(ModelConfig::gpt2_layer0(), len, seed)
    }

    pub fn build_with(model_cfg: ModelConfig, len: usize, seed: u64)
        -> EvalContext
    {
        Self::build_with_calib(model_cfg, len, len, seed)
    }

    /// Build with an explicit calibration-set length (the seq-length
    /// sweep pins this so that L is the *only* variable — otherwise a
    /// longer L also means a larger calibration set, confounding the
    /// trend).
    pub fn build_with_calib(
        model_cfg: ModelConfig,
        len: usize,
        calib_len: usize,
        seed: u64,
    ) -> EvalContext {
        assert!(len <= model_cfg.max_pos, "len > max_pos");
        let model = Gpt2::new(Weights::random(&model_cfg, seed));
        let tok = ByteTokenizer::new();
        let samples = Genre::ALL
            .iter()
            .enumerate()
            .map(|(i, &genre)| {
                let text = Corpus::new(genre, seed ^ (i as u64) << 8)
                    .generate(len * 4);
                let ids = tok.encode_clamped(&text, len);
                let out = model.prefill(&ids);
                // calibration: same genre, different text
                let calib_text =
                    Corpus::new(genre, seed ^ 0xCA11B ^ (i as u64) << 8)
                        .generate(calib_len * 4);
                let calib_ids = tok.encode_clamped(&calib_text, calib_len);
                let calib_out = model.prefill(&calib_ids);
                let d_k = model_cfg.d_head;
                let heads = |f: &dyn Fn(usize) -> Vec<f32>| {
                    (0..model_cfg.n_head).map(f).collect::<Vec<_>>()
                };
                Sample {
                    genre,
                    len: ids.len(),
                    d_k,
                    keys: heads(&|h| out.head_keys(0, h, d_k)),
                    values: heads(&|h| out.head_values(0, h, d_k)),
                    queries: heads(&|h| out.head_queries(0, h, d_k)),
                    calib_keys: heads(&|h| calib_out.head_keys(0, h, d_k)),
                    calib_values: heads(
                        &|h| calib_out.head_values(0, h, d_k)),
                }
            })
            .collect();
        EvalContext { model_cfg, samples, seed }
    }

    /// Evaluate a method on one sample: average metrics over heads and
    /// query positions (every `stride`-th position with ≥ 16 context).
    pub fn evaluate_sample(&self, sample: &Sample, method: Method,
                           stride: usize) -> FidelityReport {
        let d_k = sample.d_k;
        let inv = 1.0 / (d_k as f32).sqrt();
        let mut reports = Vec::new();

        for head in 0..self.model_cfg.n_head {
            let keys = &sample.keys[head];
            let values = &sample.values[head];
            let queries = &sample.queries[head];

            // method-specific key representation, built once per head;
            // codebooks are trained on held-out calibration keys (see
            // Sample::calib_keys)
            enum Rep {
                Raw(Vec<f32>),
                Pq { codec: PqCodec, codes: Vec<u8> },
            }
            let rep = match method {
                Method::Fp16 => Rep::Raw(keys.clone()),
                Method::Int8 => Rep::Raw(quant::quant_roundtrip(keys, 8)),
                Method::Int4 => Rep::Raw(quant::quant_roundtrip(keys, 4)),
                Method::Lookat { m } => {
                    let codec = PqCodec::train(
                        &sample.calib_keys[head],
                        d_k,
                        m,
                        crate::pq::NUM_CENTROIDS,
                        &TrainOpts { seed: self.seed, ..Default::default() },
                    );
                    let codes = codec.encode_batch(keys, sample.len);
                    Rep::Pq { codec, codes }
                }
            };

            let mut t = 16.max(stride);
            while t < sample.len {
                let n = t + 1; // causal prefix length
                let q = &queries[t * d_k..(t + 1) * d_k];

                // oracle
                let mut s_ref: Vec<f32> = (0..n)
                    .map(|l| {
                        crate::tensor::dot(
                            q, &keys[l * d_k..(l + 1) * d_k]) * inv
                    })
                    .collect();
                softmax_inplace(&mut s_ref);
                let out_ref = weighted_values(&s_ref, values, d_k);

                // approximation
                let mut s_apx: Vec<f32> = match &rep {
                    Rep::Raw(kk) => (0..n)
                        .map(|l| {
                            crate::tensor::dot(
                                q, &kk[l * d_k..(l + 1) * d_k]) * inv
                        })
                        .collect(),
                    Rep::Pq { codec, codes } => {
                        let lut = LookupTable::build(q, &codec.codebook);
                        let mut s = lut.scores(&codes[..n * codes.len()
                            / sample.len], n);
                        for v in s.iter_mut() {
                            *v *= inv;
                        }
                        s
                    }
                };
                softmax_inplace(&mut s_apx);
                let out_apx = weighted_values(&s_apx, values, d_k);

                reports.push(FidelityReport::compare(
                    &out_ref, &out_apx, &s_ref, &s_apx));
                t += stride;
            }
        }
        average_reports(&reports)
    }

    /// Evaluate LOOKAT with externally-trained per-head codecs (used by
    /// the calibration-transfer and centroid-count ablations).
    pub fn evaluate_sample_with_codecs(
        &self,
        sample: &Sample,
        codecs: &[PqCodec],
        stride: usize,
    ) -> FidelityReport {
        let d_k = sample.d_k;
        let inv = 1.0 / (d_k as f32).sqrt();
        let mut reports = Vec::new();
        for head in 0..self.model_cfg.n_head {
            let keys = &sample.keys[head];
            let values = &sample.values[head];
            let queries = &sample.queries[head];
            let codec = &codecs[head];
            let m = codec.codebook.m;
            let codes = codec.encode_batch(keys, sample.len);
            let mut t = 16.max(stride);
            while t < sample.len {
                let n = t + 1;
                let q = &queries[t * d_k..(t + 1) * d_k];
                let mut s_ref: Vec<f32> = (0..n)
                    .map(|l| {
                        crate::tensor::dot(
                            q, &keys[l * d_k..(l + 1) * d_k]) * inv
                    })
                    .collect();
                softmax_inplace(&mut s_ref);
                let out_ref = weighted_values(&s_ref, values, d_k);
                let lut = LookupTable::build(q, &codec.codebook);
                let mut s_apx = lut.scores(&codes[..n * m], n);
                for v in s_apx.iter_mut() {
                    *v *= inv;
                }
                softmax_inplace(&mut s_apx);
                let out_apx = weighted_values(&s_apx, values, d_k);
                reports.push(FidelityReport::compare(
                    &out_ref, &out_apx, &s_ref, &s_apx));
                t += stride;
            }
        }
        average_reports(&reports)
    }

    /// Evaluate the §5.2 extension: keys AND values PQ-compressed
    /// (value codebooks trained on held-out calibration values too).
    ///
    /// Runs the *serving path*, not a standalone loop: the sample is
    /// replayed into a paged [`KvCache`] with `KeyStorage::Pq` +
    /// `ValueStorage::Pq` and every probe position attends through
    /// [`LookatKernel::decode_batch`] — the same block-resident ADC
    /// scan and fused blocked weighted decode `Engine::decode_batch`
    /// uses in production.
    pub fn evaluate_sample_kv(
        &self,
        sample: &Sample,
        m_keys: usize,
        m_values: usize,
        stride: usize,
    ) -> FidelityReport {
        use crate::attention::{AttentionKernel, DecodePlan, WorkItem};
        use crate::attention::kernel::LookatKernel;
        use crate::kvcache::{
            KeyStorage, KvCache, ValueStorage, BLOCK_TOKENS,
        };

        let d_k = sample.d_k;
        let h = self.model_cfg.n_head;
        let inv = 1.0 / (d_k as f32).sqrt();
        let train = |calib: &[f32], m: usize, salt: u64| {
            PqCodec::train(
                calib, d_k, m, crate::pq::NUM_CENTROIDS,
                &TrainOpts { seed: self.seed ^ salt, ..Default::default() })
        };
        let kcs: Vec<PqCodec> = (0..h)
            .map(|head| train(&sample.calib_keys[head], m_keys, 0))
            .collect();
        let vcs: Vec<PqCodec> = (0..h)
            .map(|head| train(&sample.calib_values[head], m_values, 1))
            .collect();
        let mut cache = KvCache::new(
            h,
            d_k,
            sample.len.div_ceil(BLOCK_TOKENS),
            KeyStorage::pq(kcs).expect("non-empty key codecs"),
            ValueStorage::pq(vcs).expect("non-empty value codecs"),
        );
        cache.create_seq(0).expect("fresh cache");
        let mut kernel = LookatKernel;

        let mut reports = Vec::new();
        let first = 16.max(stride);
        for t in 0..sample.len {
            // replay token t into the cache exactly as serving would
            let mut k_row = Vec::with_capacity(h * d_k);
            let mut v_row = Vec::with_capacity(h * d_k);
            for head in 0..h {
                k_row.extend_from_slice(
                    &sample.keys[head][t * d_k..(t + 1) * d_k]);
                v_row.extend_from_slice(
                    &sample.values[head][t * d_k..(t + 1) * d_k]);
            }
            cache.append(0, &k_row, &v_row).expect("within block budget");
            if t < first || (t - first) % stride != 0 {
                continue;
            }
            // one decode plan over the causal prefix [0, t], all heads
            let n = t + 1;
            let items: Vec<WorkItem> = (0..h)
                .map(|head| WorkItem {
                    seq: 0,
                    head,
                    q: &sample.queries[head][t * d_k..(t + 1) * d_k],
                    rows: 1,
                    prefixes: None,
                })
                .collect();
            let plan =
                DecodePlan {
                    cache: &cache,
                    d_k,
                    threads: 1,
                    timers: None,
                    items,
                };
            let outs =
                kernel.decode_batch(&plan).expect("lookat-kv decode");
            for head in 0..h {
                let keys = &sample.keys[head];
                let values = &sample.values[head];
                let q = &sample.queries[head][t * d_k..(t + 1) * d_k];
                let mut s_ref: Vec<f32> = (0..n)
                    .map(|l| {
                        crate::tensor::dot(
                            q, &keys[l * d_k..(l + 1) * d_k]) * inv
                    })
                    .collect();
                softmax_inplace(&mut s_ref);
                let out_ref = weighted_values(&s_ref, values, d_k);
                reports.push(FidelityReport::compare(
                    &out_ref,
                    &outs[head].out,
                    &s_ref,
                    &outs[head].weights,
                ));
            }
        }
        average_reports(&reports)
    }

    /// Evaluate a method over all samples -> (per-sample reports, agg).
    pub fn evaluate(&self, method: Method, stride: usize)
        -> (Vec<FidelityReport>, AggregateFidelity)
    {
        let per_sample: Vec<FidelityReport> = self
            .samples
            .iter()
            .map(|s| self.evaluate_sample(s, method, stride))
            .collect();
        let agg = AggregateFidelity::of(&per_sample);
        (per_sample, agg)
    }

    /// Full attention map (T×T lower-triangular, one head) for a method —
    /// Figure 4's raw material.
    pub fn attention_map(&self, sample: &Sample, head: usize,
                         method: Method) -> Vec<Vec<f32>> {
        let d_k = sample.d_k;
        let inv = 1.0 / (d_k as f32).sqrt();
        let keys = &sample.keys[head];
        let queries = &sample.queries[head];

        let (kk, pq): (Vec<f32>, Option<(PqCodec, Vec<u8>)>) = match method {
            Method::Fp16 => (keys.clone(), None),
            Method::Int8 => (quant::quant_roundtrip(keys, 8), None),
            Method::Int4 => (quant::quant_roundtrip(keys, 4), None),
            Method::Lookat { m } => {
                let codec = PqCodec::train(
                    &sample.calib_keys[head], d_k, m,
                    crate::pq::NUM_CENTROIDS,
                    &TrainOpts { seed: self.seed, ..Default::default() });
                let codes = codec.encode_batch(keys, sample.len);
                (Vec::new(), Some((codec, codes)))
            }
        };

        (0..sample.len)
            .map(|t| {
                let q = &queries[t * d_k..(t + 1) * d_k];
                let n = t + 1;
                let mut s: Vec<f32> = match &pq {
                    None => (0..n)
                        .map(|l| {
                            crate::tensor::dot(
                                q, &kk[l * d_k..(l + 1) * d_k]) * inv
                        })
                        .collect(),
                    Some((codec, codes)) => {
                        let lut = LookupTable::build(q, &codec.codebook);
                        let m = codec.codebook.m;
                        let mut s = lut.scores(&codes[..n * m], n);
                        for v in s.iter_mut() {
                            *v *= inv;
                        }
                        s
                    }
                };
                softmax_inplace(&mut s);
                s
            })
            .collect()
    }
}

fn weighted_values(weights: &[f32], values: &[f32], d_k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; d_k];
    for (l, &a) in weights.iter().enumerate() {
        if a > 0.0 {
            crate::tensor::axpy(&mut out, a, &values[l * d_k..(l + 1) * d_k]);
        }
    }
    out
}

/// Mean of many fidelity reports (positions × heads within one sample).
pub fn average_reports(reports: &[FidelityReport]) -> FidelityReport {
    assert!(!reports.is_empty());
    let n = reports.len() as f64;
    FidelityReport {
        cosine: reports.iter().map(|r| r.cosine).sum::<f64>() / n,
        kl: reports.iter().map(|r| r.kl).sum::<f64>() / n,
        spearman: reports.iter().map(|r| r.spearman).sum::<f64>() / n,
        top5: reports.iter().map(|r| r.top5).sum::<f64>() / n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_ctx() -> EvalContext {
        EvalContext::build_with(ModelConfig::test_tiny(), 64, 7)
    }

    #[test]
    fn context_has_three_genre_samples() {
        let ctx = quick_ctx();
        assert_eq!(ctx.samples.len(), 3);
        for s in &ctx.samples {
            assert_eq!(s.keys.len(), ctx.model_cfg.n_head);
            assert_eq!(s.keys[0].len(), s.len * s.d_k);
        }
    }

    #[test]
    fn fp16_method_is_perfect() {
        let ctx = quick_ctx();
        let (_, agg) = ctx.evaluate(Method::Fp16, 8);
        assert!((agg.cosine.0 - 1.0).abs() < 1e-9);
        assert!(agg.kl.0 < 1e-9);
        assert!((agg.spearman.0 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn int8_near_lossless_int4_worse() {
        let ctx = quick_ctx();
        let (_, i8agg) = ctx.evaluate(Method::Int8, 8);
        let (_, i4agg) = ctx.evaluate(Method::Int4, 8);
        assert!(i8agg.cosine.0 > 0.999);
        assert!(i4agg.cosine.0 < i8agg.cosine.0 + 1e-12);
        assert!(i4agg.kl.0 > i8agg.kl.0);
    }

    #[test]
    fn lookat_preserves_rank_structure() {
        let ctx = quick_ctx();
        let (_, agg) = ctx.evaluate(Method::Lookat { m: 4 }, 8);
        assert!(agg.cosine.0 > 0.85, "cosine {}", agg.cosine.0);
        assert!(agg.spearman.0 > 0.7, "spearman {}", agg.spearman.0);
    }

    #[test]
    fn method_accounting() {
        assert_eq!(Method::Fp16.compression(64), 1.0);
        assert_eq!(Method::Lookat { m: 2 }.compression(64), 64.0);
        assert_eq!(Method::Lookat { m: 4 }.compression(64), 32.0);
        assert_eq!(Method::Lookat { m: 16 }.compression(64), 8.0);
        assert_eq!(Method::Int8.bytes_per_token(64), 64.0);
        assert_eq!(Method::Int4.bytes_per_token(64), 32.0);
    }

    #[test]
    fn attention_map_is_causal_and_normalized() {
        let ctx = quick_ctx();
        let map = ctx.attention_map(&ctx.samples[0], 0, Method::Fp16);
        assert_eq!(map.len(), ctx.samples[0].len);
        for (t, row) in map.iter().enumerate() {
            assert_eq!(row.len(), t + 1);
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn evaluate_is_deterministic() {
        let ctx = quick_ctx();
        let (_, a) = ctx.evaluate(Method::Lookat { m: 4 }, 16);
        let (_, b) = ctx.evaluate(Method::Lookat { m: 4 }, 16);
        assert_eq!(a.cosine, b.cosine);
        assert_eq!(a.kl, b.kl);
    }
}
