//! Report writer: every experiment emits markdown (human), JSON
//! (machine) and CSV (plotting) under `artifacts/reports/`.

use std::path::PathBuf;

use crate::util::json::Json;

/// Destination directory for reports.
pub fn reports_dir() -> PathBuf {
    crate::runtime::default_artifacts_dir().join("reports")
}

/// A rendered experiment report.
pub struct Report {
    pub id: String,
    pub title: String,
    pub markdown: String,
    pub json: Json,
    pub csv: String,
}

impl Report {
    pub fn write(&self) -> anyhow::Result<()> {
        let dir = reports_dir();
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join(format!("{}.md", self.id)),
                       &self.markdown)?;
        std::fs::write(
            dir.join(format!("{}.json", self.id)),
            self.json.to_string_pretty(),
        )?;
        if !self.csv.is_empty() {
            std::fs::write(dir.join(format!("{}.csv", self.id)), &self.csv)?;
        }
        Ok(())
    }

    /// Print the markdown to stdout and persist all formats.
    pub fn emit(&self) -> anyhow::Result<()> {
        println!("\n## {} — {}\n", self.id, self.title);
        println!("{}", self.markdown);
        self.write()?;
        println!("(written to {}/{}.{{md,json,csv}})",
                 reports_dir().display(), self.id);
        Ok(())
    }
}

/// Format "mean ± std" to 3 decimals, paper-style.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:.3} ± {std:.3}")
}

/// Markdown table builder.
pub struct MdTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MdTable {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("| {} |\n", self.header.join(" | ")));
        s.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for r in &self.rows {
            s.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for r in &self.rows {
            // strip the ± decoration for machine consumption
            let cells: Vec<String> = r
                .iter()
                .map(|c| c.replace(" ± ", ";").replace(',', ";"))
                .collect();
            s.push_str(&cells.join(","));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown_and_csv() {
        let mut t = MdTable::new(&["Method", "Cosine"]);
        t.row(vec!["FP16".into(), pm(1.0, 0.0)]);
        t.row(vec!["LOOKAT-4".into(), pm(0.95, 0.022)]);
        let md = t.render();
        assert!(md.contains("| Method | Cosine |"));
        assert!(md.contains("LOOKAT-4"));
        assert!(md.contains("0.950 ± 0.022"));
        let csv = t.to_csv();
        assert!(csv.starts_with("Method,Cosine\n"));
        assert!(csv.contains("0.950;0.022"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn report_writes_files() {
        let r = Report {
            id: "selftest".into(),
            title: "self test".into(),
            markdown: "hello".into(),
            json: Json::Num(1.0),
            csv: "a,b\n1,2\n".into(),
        };
        r.write().unwrap();
        let dir = reports_dir();
        assert!(dir.join("selftest.md").exists());
        assert!(dir.join("selftest.json").exists());
        assert!(dir.join("selftest.csv").exists());
        for ext in ["md", "json", "csv"] {
            std::fs::remove_file(dir.join(format!("selftest.{ext}"))).ok();
        }
    }
}
