//! Ablation A3 — calibration-data transfer (paper §5.1's limitation:
//! "codebook quality depends on calibration data ... though strong
//! cross-domain generalization is observed").
//!
//! 3×3 matrix: codebooks trained on genre X, applied to genre Y's cache
//! (LOOKAT-4). Diagonal = in-domain; off-diagonal = transfer.

use super::eval::EvalContext;
use super::report::{MdTable, Report};
use crate::pq::{PqCodec, TrainOpts};
use crate::util::json::Json;
use crate::workload::Genre;

pub struct Matrix {
    /// `cosine[i][j]`: trained on genre i, evaluated on genre j
    pub cosine: Vec<Vec<f64>>,
    pub spearman: Vec<Vec<f64>>,
}

pub fn compute(len: usize, stride: usize, seed: u64) -> Matrix {
    let ctx = EvalContext::build(len, seed);
    let d_k = ctx.model_cfg.d_head;
    let n_gen = Genre::ALL.len();
    let mut cosine = vec![vec![0.0; n_gen]; n_gen];
    let mut spearman = vec![vec![0.0; n_gen]; n_gen];
    for (i, train_sample) in ctx.samples.iter().enumerate() {
        // codebooks from genre i's calibration keys
        let codecs: Vec<PqCodec> = (0..ctx.model_cfg.n_head)
            .map(|h| {
                PqCodec::train(
                    &train_sample.calib_keys[h], d_k, 4, 256,
                    &TrainOpts { seed, ..Default::default() })
            })
            .collect();
        for (j, eval_sample) in ctx.samples.iter().enumerate() {
            let rep = ctx.evaluate_sample_with_codecs(
                eval_sample, &codecs, stride);
            cosine[i][j] = rep.cosine;
            spearman[i][j] = rep.spearman;
        }
    }
    Matrix { cosine, spearman }
}

/// Mean diagonal minus mean off-diagonal (the transfer gap).
pub fn transfer_gap(m: &[Vec<f64>]) -> f64 {
    let n = m.len();
    let mut diag = 0.0;
    let mut off = 0.0;
    for i in 0..n {
        for j in 0..n {
            if i == j {
                diag += m[i][j];
            } else {
                off += m[i][j];
            }
        }
    }
    diag / n as f64 - off / (n * n - n) as f64
}

pub fn render(m: &Matrix) -> Report {
    let names: Vec<&str> = Genre::ALL.iter().map(|g| g.name()).collect();
    let mut header = vec!["train \\ eval"];
    header.extend(names.iter());
    let mut t = MdTable::new(&header);
    let mut arr = Vec::new();
    for (i, row) in m.cosine.iter().enumerate() {
        let mut cells = vec![names[i].to_string()];
        cells.extend(row.iter().map(|v| format!("{v:.4}")));
        t.row(cells);
        let mut o = Json::obj();
        o.set("train", Json::Str(names[i].into()));
        o.set(
            "cosine",
            Json::Arr(row.iter().map(|&v| Json::Num(v)).collect()),
        );
        o.set(
            "spearman",
            Json::Arr(m.spearman[i].iter().map(|&v| Json::Num(v)).collect()),
        );
        arr.push(o);
    }
    let gap = transfer_gap(&m.cosine);
    let markdown = format!(
        "Cosine similarity, codebooks trained on the row genre and \
         applied to the column genre. In-domain − cross-domain gap: \
         **{gap:.4}** — small, supporting the paper's cross-domain \
         generalization claim (§5.1).\n\n{}",
        t.render()
    );
    Report {
        id: "ablation_calibration".into(),
        title: "Calibration-data transfer matrix (paper §5.1)".into(),
        markdown,
        json: Json::Arr(arr),
        csv: t.to_csv(),
    }
}

pub fn run(quick: bool) -> anyhow::Result<Matrix> {
    let (len, stride) = if quick { (96, 16) } else { (384, 8) };
    let m = compute(len, stride, 0xAB3C);
    render(&m).emit()?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_is_3x3_with_sane_values() {
        let m = compute(64, 16, 10);
        assert_eq!(m.cosine.len(), 3);
        for row in &m.cosine {
            assert_eq!(row.len(), 3);
            for &v in row {
                assert!((0.0..=1.0 + 1e-9).contains(&v), "cosine {v}");
            }
        }
    }

    #[test]
    fn cross_domain_transfer_is_strong() {
        // the paper's claim: off-diagonal stays close to diagonal
        let m = compute(64, 16, 10);
        let gap = transfer_gap(&m.cosine);
        assert!(gap < 0.15, "transfer gap too large: {gap}");
    }
}
