//! Table 3 — quality vs sequence length, LOOKAT-4 configuration:
//! L ∈ {64, 128, 256, 512, 1024}.

use super::eval::{EvalContext, Method};
use super::report::{pm, MdTable, Report};
use crate::metrics::AggregateFidelity;
use crate::util::json::Json;

pub struct Row {
    pub len: usize,
    pub agg: AggregateFidelity,
}

pub const LENS: [usize; 5] = [64, 128, 256, 512, 1024];

pub fn compute(lens: &[usize], stride: usize, seed: u64) -> Vec<Row> {
    // calibration length is pinned so L is the only variable (otherwise
    // longer L would also mean a larger calibration set)
    let calib_len = 512.min(lens.iter().copied().max().unwrap_or(512));
    lens.iter()
        .map(|&len| {
            let ctx = EvalContext::build_with_calib(
                crate::model::ModelConfig::gpt2_layer0(), len, calib_len,
                seed);
            let (_, agg) = ctx.evaluate(Method::Lookat { m: 4 }, stride);
            Row { len, agg }
        })
        .collect()
}

pub fn render(rows: &[Row]) -> Report {
    let mut t = MdTable::new(&[
        "Seq Length (L)", "Cosine Sim ↑", "KL Divergence ↓",
        "Spearman ρ ↑",
    ]);
    let mut arr = Vec::new();
    for r in rows {
        t.row(vec![
            format!("{}", r.len),
            pm(r.agg.cosine.0, r.agg.cosine.1),
            pm(r.agg.kl.0, r.agg.kl.1),
            pm(r.agg.spearman.0, r.agg.spearman.1),
        ]);
        let mut o = Json::obj();
        o.set("len", Json::Num(r.len as f64));
        o.set("metrics", r.agg.to_json());
        arr.push(o);
    }
    Report {
        id: "table3".into(),
        title: "Long-context scaling, LOOKAT-4 (paper Table 3)".into(),
        markdown: t.render(),
        json: Json::Arr(arr),
        csv: t.to_csv(),
    }
}

pub fn run(quick: bool) -> anyhow::Result<Vec<Row>> {
    let (lens, stride): (&[usize], usize) =
        if quick { (&[64, 128], 16) } else { (&LENS, 8) };
    let rows = compute(lens, stride, 0x7AB3);
    render(&rows).emit()?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quality_degrades_gently_with_length() {
        let rows = compute(&[32, 128], 16, 9);
        assert_eq!(rows.len(), 2);
        // rank correlation must stay meaningful at both lengths (held-out
        // calibration at this tiny scale is the hardest setting), and
        // short contexts should be at least as good as long ones
        assert!(rows[0].agg.spearman.0 > 0.5, "{}", rows[0].agg.spearman.0);
        assert!(rows[1].agg.spearman.0 > 0.5, "{}", rows[1].agg.spearman.0);
        // (the L-monotonicity direction is only meaningful at full scale,
        // where calibration sets are large — see the table3 bench; at
        // L=32 the codebook is trained on just 32 held-out keys)
        assert!(rows[0].agg.cosine.0 > 0.75 && rows[1].agg.cosine.0 > 0.75);
    }

    #[test]
    fn render_has_length_column() {
        let rows = compute(&[32], 16, 9);
        let rep = render(&rows);
        assert!(rep.markdown.contains("| 32 |"));
    }
}
