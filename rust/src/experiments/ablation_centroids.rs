//! Ablation A2 — centroid count K and Proposition 1.
//!
//! The paper's Proposition 1 claims E[ρ] ≥ 1 − O(d_k/(mK)). We sweep K
//! at fixed m and check the empirical rank-correlation *deficit*
//! (1 − ρ) shrinks as K grows, and report the fitted constant of
//! (1 − ρ) ≈ c · d_k/(mK).
//!
//! The sweep also carries the 4-bit fast-scan mode's equal-bit pairs:
//! (m, K=256) vs (2m, K=16) spend the same code bits per token
//! (m·8 = 2m·4), so their rows compare fidelity at matched compression
//! — the trade the SIMD shuffle scan buys its speed with.

use super::eval::EvalContext;
use super::report::{MdTable, Report};
use crate::pq::{PqCodec, TrainOpts};
use crate::util::json::Json;

pub struct Row {
    pub k: usize,
    pub m: usize,
    /// theory knob d_k/(m·K)
    pub knob: f64,
    /// code bits per token, m·log2(K) — rows with equal bits are
    /// equal-compression alternatives
    pub bits: usize,
    pub spearman: f64,
    pub cosine: f64,
}

pub fn compute(len: usize, stride: usize, seed: u64) -> Vec<Row> {
    let ctx = EvalContext::build(len, seed);
    let d_k = ctx.model_cfg.d_head;
    let mut rows = Vec::new();
    // (2, 256)/(4, 16) and (4, 256)/(8, 16) are the equal-bit pairs:
    // 16 and 32 code bits per token respectively
    for (m, k) in [(4usize, 16usize), (4, 32), (4, 64), (4, 128), (4, 256),
                   (2, 64), (8, 64), (2, 256), (8, 16)] {
        let mut per_sample = Vec::new();
        for s in &ctx.samples {
            let codecs: Vec<PqCodec> = (0..ctx.model_cfg.n_head)
                .map(|h| {
                    PqCodec::train(
                        &s.calib_keys[h], d_k, m, k,
                        &TrainOpts { seed, ..Default::default() })
                })
                .collect();
            per_sample.push(
                ctx.evaluate_sample_with_codecs(s, &codecs, stride));
        }
        let agg = crate::metrics::AggregateFidelity::of(&per_sample);
        rows.push(Row {
            k,
            m,
            knob: d_k as f64 / (m * k) as f64,
            bits: m * k.trailing_zeros() as usize,
            spearman: agg.spearman.0,
            cosine: agg.cosine.0,
        });
    }
    rows
}

/// Least-squares fit of (1 − ρ) = c · knob through the origin.
pub fn fit_constant(rows: &[Row]) -> f64 {
    let num: f64 = rows.iter().map(|r| (1.0 - r.spearman) * r.knob).sum();
    let den: f64 = rows.iter().map(|r| r.knob * r.knob).sum();
    num / den
}

pub fn render(rows: &[Row]) -> Report {
    let mut t = MdTable::new(&[
        "m", "K", "bits/tok", "d_k/(mK)", "Spearman ρ", "1−ρ", "Cosine",
    ]);
    let mut arr = Vec::new();
    for r in rows {
        t.row(vec![
            format!("{}", r.m),
            format!("{}", r.k),
            format!("{}", r.bits),
            format!("{:.4}", r.knob),
            format!("{:.4}", r.spearman),
            format!("{:.4}", 1.0 - r.spearman),
            format!("{:.4}", r.cosine),
        ]);
        let mut o = Json::obj();
        o.set("m", Json::Num(r.m as f64));
        o.set("k", Json::Num(r.k as f64));
        o.set("bits_per_token", Json::Num(r.bits as f64));
        o.set("knob", Json::Num(r.knob));
        o.set("spearman", Json::Num(r.spearman));
        o.set("cosine", Json::Num(r.cosine));
        arr.push(o);
    }
    let c = fit_constant(rows);
    let markdown = format!(
        "Empirical check of Proposition 1: E[ρ] ≥ 1 − O(d_k/(mK)). \
         Fitted (1−ρ) ≈ {c:.3} · d_k/(mK) over the sweep below — the \
         deficit shrinks as K (or m) grows, as the bound predicts. \
         Rows with equal bits/tok pair the 4-bit fast-scan mode \
         against the byte-code default at matched compression: \
         (2m, K=16) vs (m, K=256).\n\n{}",
        t.render()
    );
    let mut j = Json::obj();
    j.set("rows", Json::Arr(arr));
    j.set("fitted_constant", Json::Num(c));
    Report {
        id: "ablation_centroids".into(),
        title: "Centroid-count sweep / Proposition 1 (paper §3.6)".into(),
        markdown,
        json: j,
        csv: t.to_csv(),
    }
}

pub fn run(quick: bool) -> anyhow::Result<Vec<Row>> {
    let (len, stride) = if quick { (96, 16) } else { (384, 8) };
    let rows = compute(len, stride, 0xAB2C);
    render(&rows).emit()?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rho_improves_with_k_at_fixed_m() {
        let rows = compute(64, 16, 8);
        let get = |k: usize| {
            rows.iter().find(|r| r.m == 4 && r.k == k).unwrap().spearman
        };
        // allow small non-monotonic jitter but require the trend
        assert!(
            get(256) > get(16) + 0.01,
            "rho(K=256)={} should beat rho(K=16)={}",
            get(256),
            get(16)
        );
    }

    #[test]
    fn equal_bit_pairs_spend_the_same_code_budget() {
        let rows = compute(64, 16, 8);
        let get = |m: usize, k: usize| {
            rows.iter().find(|r| r.m == m && r.k == k).unwrap()
        };
        for ((mw, kw), (mp, kp)) in
            [((4, 256), (8, 16)), ((2, 256), (4, 16))]
        {
            let wide = get(mw, kw);
            let packed = get(mp, kp);
            assert_eq!(wide.bits, packed.bits, "not an equal-bit pair");
            // doubling m buys back most of what the narrow codebook
            // loses: the packed row must stay competitive, not collapse
            assert!(
                packed.spearman > wide.spearman - 0.1,
                "(m={mp}, K={kp}) rho {} vs (m={mw}, K={kw}) rho {}",
                packed.spearman,
                wide.spearman
            );
        }
    }

    #[test]
    fn fit_constant_is_positive_and_finite() {
        let rows = compute(64, 16, 8);
        let c = fit_constant(&rows);
        assert!(c.is_finite());
        assert!(c > 0.0, "deficit must correlate positively with knob");
    }
}
