//! Ablation A1 — value compression (paper §5.2, now in the serving
//! path): keys-only LOOKAT vs keys+values LOOKAT at matched
//! configurations. Total cache bytes/token include the value side,
//! which dominates once keys are compressed (values are 128 B/token
//! FP16 at d_k=64).
//!
//! The keys+values rows run through `EvalContext::evaluate_sample_kv`,
//! which replays each sample into a paged `KvCache`
//! (`KeyStorage::Pq` + `ValueStorage::Pq`) and attends through
//! `LookatKernel::decode_batch` — the same block-resident ADC scan and
//! fused blocked weighted decode `Engine::decode_batch` serves with,
//! not a standalone evaluation loop.

use super::eval::EvalContext;
use super::report::{pm, MdTable, Report};
use crate::metrics::AggregateFidelity;
use crate::util::json::Json;

pub struct Row {
    pub label: String,
    /// total (key + value) bytes per token per head
    pub total_bytes: f64,
    pub agg: AggregateFidelity,
}

pub fn compute(len: usize, stride: usize, seed: u64) -> Vec<Row> {
    let ctx = EvalContext::build(len, seed);
    let d_k = ctx.model_cfg.d_head as f64;
    let mut rows = Vec::new();

    // keys-only LOOKAT-4 (paper's main configuration)
    let (_, agg) = ctx.evaluate(super::eval::Method::Lookat { m: 4 },
                                stride);
    rows.push(Row {
        label: "LOOKAT-4 keys only".into(),
        total_bytes: 4.0 + d_k * 2.0,
        agg,
    });

    // keys + values, value-side m ∈ {4, 8, 16}
    for m_v in [4usize, 8, 16] {
        let reports: Vec<_> = ctx
            .samples
            .iter()
            .map(|s| ctx.evaluate_sample_kv(s, 4, m_v, stride))
            .collect();
        rows.push(Row {
            label: format!("LOOKAT-4 keys + LOOKAT-{m_v} values"),
            total_bytes: 4.0 + m_v as f64,
            agg: AggregateFidelity::of(&reports),
        });
    }
    rows
}

pub fn render(rows: &[Row]) -> Report {
    let mut t = MdTable::new(&[
        "Configuration", "Cache B/token", "vs FP16", "Cosine Sim ↑",
        "KL ↓", "Spearman ρ ↑",
    ]);
    let mut arr = Vec::new();
    let fp16_total = 64.0 * 2.0 * 2.0; // keys + values
    for r in rows {
        t.row(vec![
            r.label.clone(),
            format!("{:.0} B", r.total_bytes),
            format!("{:.1}×", fp16_total / r.total_bytes),
            pm(r.agg.cosine.0, r.agg.cosine.1),
            pm(r.agg.kl.0, r.agg.kl.1),
            pm(r.agg.spearman.0, r.agg.spearman.1),
        ]);
        let mut o = Json::obj();
        o.set("label", Json::Str(r.label.clone()));
        o.set("total_bytes", Json::Num(r.total_bytes));
        o.set("metrics", r.agg.to_json());
        arr.push(o);
    }
    let markdown = format!(
        "Key-only LOOKAT leaves FP16 values as the dominant cache cost \
         (128 B/token/head at d_k=64). Compressing values with the \
         transposed-ADC weighted decode (pq::values) pushes *total* \
         cache compression to ~32× while the attention distribution is \
         untouched (value coding can't change scores). Keys+values rows \
         are measured through the serving path itself: a paged KvCache \
         in ValueStorage::Pq mode attended via LookatKernel's fused \
         blocked weighted decode.\n\n{}",
        t.render()
    );
    Report {
        id: "ablation_values".into(),
        title: "Value-compression extension (paper §5.2)".into(),
        markdown,
        json: Json::Arr(arr),
        csv: t.to_csv(),
    }
}

pub fn run(quick: bool) -> anyhow::Result<Vec<Row>> {
    let (len, stride) = if quick { (96, 16) } else { (384, 8) };
    let rows = compute(len, stride, 0xAB7A);
    render(&rows).emit()?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_compression_keeps_high_fidelity() {
        let rows = compute(64, 16, 6);
        assert_eq!(rows.len(), 4);
        let key_only = &rows[0];
        // m_v=16 value coding should track the key-only config closely
        let kv16 = rows.iter().find(|r| r.label.contains("16 values"))
            .unwrap();
        assert!(
            kv16.agg.cosine.0 > key_only.agg.cosine.0 - 0.15,
            "kv {} vs key-only {}",
            kv16.agg.cosine.0,
            key_only.agg.cosine.0
        );
        // total bytes shrink dramatically
        assert!(kv16.total_bytes < key_only.total_bytes / 5.0);
    }

    #[test]
    fn spearman_unchanged_by_value_coding() {
        let rows = compute(64, 16, 6);
        let key_only = &rows[0];
        for r in &rows[1..] {
            assert!(
                (r.agg.spearman.0 - key_only.agg.spearman.0).abs() < 1e-9,
                "value coding must not perturb score ranking"
            );
        }
    }
}
