//! §4.7 efficiency analysis: analytic FLOP/bandwidth model (the paper's
//! numbers) plus *measured* score-phase throughput on this host —
//! exact-dot-product scan vs LOOKAT's LUT-build + ADC scan.

use std::time::Instant;

use super::report::{MdTable, Report};
use crate::pq::{LookupTable, PqCodec, TrainOpts};
use crate::util::bench::black_box;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Analytic per-query cost model (paper §4.7, d=64, m, L, K=256).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub d_k: usize,
    pub m: usize,
    pub l: usize,
    pub k: usize,
}

impl CostModel {
    /// Standard attention score FLOPs: L·d MACs ≈ 2·L·d ops — paper
    /// counts MACs as single FLOPs (L·d), we follow the paper.
    pub fn standard_flops(&self) -> usize {
        self.l * self.d_k
    }

    /// LOOKAT FLOPs: LUT build (m·K·d_sub = K·d) amortized per query +
    /// L·m lookup-adds. Paper: m·256 + L·m.
    pub fn lookat_flops(&self) -> usize {
        self.m * self.k + self.l * self.m
    }

    /// Bytes of key traffic per query: FP16 keys vs uint8 codes.
    pub fn standard_key_bytes(&self) -> usize {
        self.l * self.d_k * 2
    }

    pub fn lookat_key_bytes(&self) -> usize {
        self.l * self.m
    }

    pub fn flop_reduction(&self) -> f64 {
        self.standard_flops() as f64 / self.lookat_flops() as f64
    }

    pub fn bandwidth_reduction(&self) -> f64 {
        self.standard_key_bytes() as f64 / self.lookat_key_bytes() as f64
    }
}

/// Measured score-phase timing for one configuration.
pub struct Measured {
    pub m: usize,
    pub l: usize,
    /// exact q·K scan, seconds/query
    pub exact_s: f64,
    /// LUT build + ADC scan, seconds/query
    pub lookat_s: f64,
    /// ADC scan only (LUT amortized across heads/batches), s/query
    pub adc_only_s: f64,
}

impl Measured {
    pub fn speedup(&self) -> f64 {
        self.exact_s / self.lookat_s
    }

    pub fn speedup_amortized(&self) -> f64 {
        self.exact_s / self.adc_only_s
    }
}

fn time_per_iter<F: FnMut()>(mut f: F, iters: usize) -> f64 {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Measure exact vs LOOKAT score phases at (m, L).
pub fn measure(d_k: usize, m: usize, l: usize, iters: usize) -> Measured {
    let mut rng = Pcg32::seed(0xEFF1);
    let keys: Vec<f32> = (0..l * d_k).map(|_| rng.next_f32_std()).collect();
    let q: Vec<f32> = (0..d_k).map(|_| rng.next_f32_std()).collect();
    let codec = PqCodec::train(
        &keys, d_k, m, 256,
        &TrainOpts { iters: 8, ..Default::default() });
    let codes = codec.encode_batch(&keys, l);
    let mut scores = vec![0.0f32; l];

    let exact_s = time_per_iter(
        || {
            for i in 0..l {
                scores[i] = crate::tensor::dot(
                    &q, &keys[i * d_k..(i + 1) * d_k]);
            }
            black_box(&scores);
        },
        iters,
    );
    let lookat_s = time_per_iter(
        || {
            let lut = LookupTable::build(&q, &codec.codebook);
            lut.scores_into(&codes, l, &mut scores);
            black_box(&scores);
        },
        iters,
    );
    let lut = LookupTable::build(&q, &codec.codebook);
    let adc_only_s = time_per_iter(
        || {
            lut.scores_into(&codes, l, &mut scores);
            black_box(&scores);
        },
        iters,
    );
    Measured { m, l, exact_s, lookat_s, adc_only_s }
}

pub fn render(models: &[CostModel], measured: &[Measured]) -> Report {
    let mut t1 = MdTable::new(&[
        "Config", "Std FLOPs", "LOOKAT FLOPs", "FLOP ↓", "Std key B",
        "LOOKAT key B", "BW ↓",
    ]);
    let mut arr = Vec::new();
    for c in models {
        t1.row(vec![
            format!("d={}, m={}, L={}", c.d_k, c.m, c.l),
            format!("{}", c.standard_flops()),
            format!("{}", c.lookat_flops()),
            format!("{:.1}×", c.flop_reduction()),
            format!("{}", c.standard_key_bytes()),
            format!("{}", c.lookat_key_bytes()),
            format!("{:.0}×", c.bandwidth_reduction()),
        ]);
        let mut o = Json::obj();
        o.set("m", Json::Num(c.m as f64));
        o.set("L", Json::Num(c.l as f64));
        o.set("flop_reduction", Json::Num(c.flop_reduction()));
        o.set("bandwidth_reduction", Json::Num(c.bandwidth_reduction()));
        arr.push(o);
    }

    let mut t2 = MdTable::new(&[
        "Config", "exact scan", "LUT+ADC", "ADC only", "speedup",
        "speedup (LUT amortized)",
    ]);
    let mut arr2 = Vec::new();
    for m in measured {
        t2.row(vec![
            format!("m={}, L={}", m.m, m.l),
            format!("{:.2} µs", m.exact_s * 1e6),
            format!("{:.2} µs", m.lookat_s * 1e6),
            format!("{:.2} µs", m.adc_only_s * 1e6),
            format!("{:.2}×", m.speedup()),
            format!("{:.2}×", m.speedup_amortized()),
        ]);
        let mut o = Json::obj();
        o.set("m", Json::Num(m.m as f64));
        o.set("L", Json::Num(m.l as f64));
        o.set("exact_s", Json::Num(m.exact_s));
        o.set("lookat_s", Json::Num(m.lookat_s));
        o.set("adc_only_s", Json::Num(m.adc_only_s));
        o.set("speedup", Json::Num(m.speedup()));
        arr2.push(o);
    }

    let paper = CostModel { d_k: 64, m: 4, l: 512, k: 256 };
    let markdown = format!(
        "### Analytic model (paper's §4.7 accounting)\n\n{}\n\
         Paper headline at d=64, m=4, L=512: {} vs {} FLOPs \
         (~{:.0}× ↓) and {}× key-bandwidth reduction — matching the \
         paper's \"3,072 FLOPs\" and \"~10×/64×\" claims ({} = 32,768, \
         {} = 3,072).\n\n\
         ### Measured on this host (single core, f32)\n\n{}\n\
         The measured CPU speedup is smaller than the bandwidth model \
         because this host computes scores from L1-resident data — on \
         the paper's bandwidth-bound edge target the 64× byte reduction \
         is the binding constraint.\n",
        t1.render(),
        paper.standard_flops(),
        paper.lookat_flops(),
        paper.flop_reduction(),
        (paper.d_k * 2) / paper.m,
        paper.standard_flops(),
        paper.lookat_flops(),
        t2.render(),
    );
    let mut j = Json::obj();
    j.set("analytic", Json::Arr(arr));
    j.set("measured", Json::Arr(arr2));
    Report {
        id: "efficiency".into(),
        title: "Efficiency analysis (paper §4.7)".into(),
        markdown,
        json: j,
        csv: t2.to_csv(),
    }
}

pub fn run(quick: bool) -> anyhow::Result<()> {
    let models: Vec<CostModel> = [2usize, 4, 8, 16]
        .iter()
        .map(|&m| CostModel { d_k: 64, m, l: 512, k: 256 })
        .collect();
    let iters = if quick { 50 } else { 2000 };
    let measured: Vec<Measured> = [(4usize, 512usize), (2, 512), (8, 512),
                                   (4, 1024)]
        .iter()
        .map(|&(m, l)| measure(64, m, l, iters))
        .collect();
    render(&models, &measured).emit()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_model_matches_paper_numbers() {
        // paper §4.7: standard = 512·64 = 32,768; LOOKAT = 4·256 + 512·4
        // = 3,072; ~10× FLOPs; 64× bandwidth (128 B -> 4 B... the paper
        // says 32x for m=4; its "64×" headline is the m=2 config)
        let c = CostModel { d_k: 64, m: 4, l: 512, k: 256 };
        assert_eq!(c.standard_flops(), 32_768);
        assert_eq!(c.lookat_flops(), 3_072);
        assert!((c.flop_reduction() - 10.67).abs() < 0.1);
        assert_eq!(c.standard_key_bytes(), 512 * 128);
        assert_eq!(c.lookat_key_bytes(), 512 * 4);
        assert_eq!(c.bandwidth_reduction(), 32.0);
        let c2 = CostModel { d_k: 64, m: 2, l: 512, k: 256 };
        assert_eq!(c2.bandwidth_reduction(), 64.0);
    }

    #[test]
    fn measured_timing_sane() {
        let m = measure(64, 4, 256, 30);
        assert!(m.exact_s > 0.0 && m.lookat_s > 0.0 && m.adc_only_s > 0.0);
        // ADC-only must beat LUT+ADC (it does strictly less work)
        assert!(m.adc_only_s <= m.lookat_s * 1.5);
    }
}
