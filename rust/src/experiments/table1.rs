//! Table 1 — quantitative results across compression methods.
//!
//! Paper's rows: FP16, INT8, INT4, LOOKAT-16/8/4/2 with compression,
//! bytes/token, cosine, KL, Spearman ρ and Top-5 accuracy, mean ± std
//! over the three genre samples.

use super::eval::{EvalContext, Method};
use super::report::{pm, MdTable, Report};
use crate::metrics::AggregateFidelity;
use crate::util::json::Json;

pub const METHODS: [Method; 7] = [
    Method::Fp16,
    Method::Int8,
    Method::Int4,
    Method::Lookat { m: 16 },
    Method::Lookat { m: 8 },
    Method::Lookat { m: 4 },
    Method::Lookat { m: 2 },
];

/// One computed row (shared with Table 4 and Figure 3).
#[derive(Clone, Debug)]
pub struct Row {
    pub method: Method,
    pub compression: f64,
    pub bytes_per_token: f64,
    pub agg: AggregateFidelity,
}

/// Compute all Table-1 rows at the given sample length.
pub fn compute(len: usize, stride: usize, seed: u64) -> Vec<Row> {
    let ctx = EvalContext::build(len, seed);
    let d_k = ctx.model_cfg.d_head;
    METHODS
        .iter()
        .map(|&method| {
            let (_, agg) = ctx.evaluate(method, stride);
            Row {
                method,
                compression: method.compression(d_k),
                bytes_per_token: method.bytes_per_token(d_k),
                agg,
            }
        })
        .collect()
}

pub fn render(rows: &[Row], len: usize) -> Report {
    let mut t = MdTable::new(&[
        "Method", "Comp.", "Mem (B/tok)", "Cosine Sim ↑", "KL Div ↓",
        "Spearman ρ ↑", "Top-5 Acc ↑",
    ]);
    let mut arr = Vec::new();
    for r in rows {
        t.row(vec![
            r.method.name(),
            format!("{:.0}×", r.compression),
            format!("{:.0} B", r.bytes_per_token),
            pm(r.agg.cosine.0, r.agg.cosine.1),
            pm(r.agg.kl.0, r.agg.kl.1),
            pm(r.agg.spearman.0, r.agg.spearman.1),
            pm(r.agg.top5.0, r.agg.top5.1),
        ]);
        let mut o = Json::obj();
        o.set("method", Json::Str(r.method.name()));
        o.set("compression", Json::Num(r.compression));
        o.set("bytes_per_token", Json::Num(r.bytes_per_token));
        o.set("metrics", r.agg.to_json());
        arr.push(o);
    }
    let markdown = format!(
        "Sample length L={len}, KV from layer 0, mean ± std over 3 genre \
         samples.\nNOTE: Mem column uses exact byte accounting — the \
         paper's INT8=16 B / INT4=8 B entries are arithmetically \
         inconsistent for d_k=64 (see EXPERIMENTS.md).\n\n{}",
        t.render()
    );
    Report {
        id: "table1".into(),
        title: "Compression–quality tradeoff (paper Table 1)".into(),
        markdown,
        json: Json::Arr(arr),
        csv: t.to_csv(),
    }
}

pub fn run(quick: bool) -> anyhow::Result<Vec<Row>> {
    let (len, stride) = if quick { (96, 16) } else { (512, 8) };
    let rows = compute(len, stride, 0xA11CE);
    render(&rows, len).emit()?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_rows() -> Vec<Row> {
        // tiny but real end-to-end computation
        compute(64, 16, 3)
    }

    #[test]
    fn shape_of_results_matches_paper() {
        let rows = quick_rows();
        assert_eq!(rows.len(), 7);
        let by_name = |n: &str| {
            rows.iter().find(|r| r.method.name() == n).unwrap().clone()
        };
        let fp16 = by_name("FP16 (Baseline)");
        let int8 = by_name("INT8");
        let int4 = by_name("INT4");
        let lk2 = by_name("LOOKAT-2");
        let lk4 = by_name("LOOKAT-4");

        // FP16 is exact
        assert!((fp16.agg.cosine.0 - 1.0).abs() < 1e-9);
        // INT8 ~ lossless, INT4 degrades
        assert!(int8.agg.cosine.0 > 0.999);
        assert!(int8.agg.spearman.0 > 0.99);
        assert!(int4.agg.cosine.0 <= int8.agg.cosine.0);
        // LOOKAT reaches 64x where scalar methods stop at 4x (exact
        // accounting), with high rank correlation — the paper's claim
        assert_eq!(lk2.compression, 64.0);
        assert_eq!(lk4.compression, 32.0);
        assert!(lk2.agg.spearman.0 > 0.7, "ρ={}", lk2.agg.spearman.0);
        assert!(lk2.agg.cosine.0 > 0.8, "cos={}", lk2.agg.cosine.0);
    }

    #[test]
    fn render_includes_all_rows() {
        let rows = quick_rows();
        let rep = render(&rows, 64);
        for name in ["FP16", "INT8", "INT4", "LOOKAT-16", "LOOKAT-2"] {
            assert!(rep.markdown.contains(name), "missing {name}");
        }
        assert!(rep.csv.lines().count() == 8); // header + 7 rows
    }
}
