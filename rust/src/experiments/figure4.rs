//! Figure 4 — attention-pattern reconstruction: FP16 vs LOOKAT-4
//! heatmaps for one sample per genre, plus the per-sample KL range the
//! caption quotes (2.17–5.16 nats in the paper).
//!
//! Emits CSV heatmaps (full attention matrices) and ASCII thumbnails.

use super::eval::{EvalContext, Method};
use super::report::Report;
use crate::metrics::kl_divergence;
use crate::util::json::Json;
use crate::workload::Genre;

pub struct GenreMap {
    pub genre: Genre,
    /// mean KL between FP16 and LOOKAT-4 rows
    pub kl: f64,
    /// spatial alignment: fraction of rows whose argmax matches
    pub peak_match: f64,
    pub map_ref: Vec<Vec<f32>>,
    pub map_apx: Vec<Vec<f32>>,
}

pub fn compute(len: usize, seed: u64, head: usize) -> Vec<GenreMap> {
    let ctx = EvalContext::build(len, seed);
    ctx.samples
        .iter()
        .map(|s| {
            let map_ref = ctx.attention_map(s, head, Method::Fp16);
            let map_apx =
                ctx.attention_map(s, head, Method::Lookat { m: 4 });
            let mut kls = Vec::new();
            let mut matches = 0usize;
            let mut rows = 0usize;
            for (r, a) in map_ref.iter().zip(&map_apx).skip(8) {
                kls.push(kl_divergence(r, a, 1e-10));
                let am = |v: &[f32]| {
                    crate::metrics::top_k_indices(v, 1)[0]
                };
                if am(r) == am(a) {
                    matches += 1;
                }
                rows += 1;
            }
            GenreMap {
                genre: s.genre,
                kl: kls.iter().sum::<f64>() / kls.len() as f64,
                peak_match: matches as f64 / rows as f64,
                map_ref,
                map_apx,
            }
        })
        .collect()
}

/// Downsample an attention map to a w×w ASCII thumbnail.
fn thumbnail(map: &[Vec<f32>], w: usize) -> String {
    let t = map.len();
    let shades = [' ', '.', ':', '+', '*', '#', '@'];
    let mut s = String::new();
    for by in 0..w {
        for bx in 0..w {
            let y0 = by * t / w;
            let y1 = ((by + 1) * t / w).max(y0 + 1);
            let x0 = bx * t / w;
            let x1 = ((bx + 1) * t / w).max(x0 + 1);
            let mut acc: f32 = 0.0;
            let mut cnt = 0;
            for y in y0..y1 {
                for x in x0..x1.min(map[y].len()) {
                    acc += map[y][x];
                    cnt += 1;
                }
            }
            let v = if cnt > 0 { acc / cnt as f32 } else { 0.0 };
            // log-ish shading: attention rows are peaky
            let idx = ((v * 30.0).sqrt() * (shades.len() - 1) as f32)
                .clamp(0.0, (shades.len() - 1) as f32) as usize;
            s.push(shades[idx]);
        }
        s.push('\n');
    }
    s
}

fn map_csv(map: &[Vec<f32>]) -> String {
    let mut s = String::new();
    for row in map {
        let cells: Vec<String> =
            row.iter().map(|v| format!("{v:.5}")).collect();
        s.push_str(&cells.join(","));
        s.push('\n');
    }
    s
}

pub fn render(maps: &[GenreMap]) -> Report {
    let mut md = String::from(
        "FP16 reference (left) vs LOOKAT-4 (right), one head, row-\
         normalized attention. Peaks should align spatially despite 32× \
         compression.\n",
    );
    let mut arr = Vec::new();
    for g in maps {
        md.push_str(&format!(
            "\n### {} — mean KL {:.2} nats, peak match {:.0}%\n\n",
            g.genre.name(),
            g.kl,
            g.peak_match * 100.0
        ));
        let left = thumbnail(&g.map_ref, 28);
        let right = thumbnail(&g.map_apx, 28);
        md.push_str("```\n");
        for (l, r) in left.lines().zip(right.lines()) {
            md.push_str(&format!("{l}   {r}\n"));
        }
        md.push_str("```\n");
        let mut o = Json::obj();
        o.set("genre", Json::Str(g.genre.name().into()));
        o.set("kl", Json::Num(g.kl));
        o.set("peak_match", Json::Num(g.peak_match));
        arr.push(o);
    }
    // full matrices for external plotting: prose sample, both variants
    let csv = format!(
        "# prose FP16 rows then prose LOOKAT-4 rows\n{}\n{}",
        map_csv(&maps[0].map_ref),
        map_csv(&maps[0].map_apx)
    );
    Report {
        id: "figure4".into(),
        title: "Attention pattern reconstruction (paper Figure 4)".into(),
        markdown: md,
        json: Json::Arr(arr),
        csv,
    }
}

pub fn run(quick: bool) -> anyhow::Result<Vec<GenreMap>> {
    let len = if quick { 96 } else { 256 };
    let maps = compute(len, 0xF164, 0);
    render(&maps).emit()?;
    Ok(maps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_cover_three_genres_with_aligned_peaks() {
        let maps = compute(48, 2, 0);
        assert_eq!(maps.len(), 3);
        for g in &maps {
            assert!(g.kl.is_finite() && g.kl >= 0.0);
            // tiny test config (d_k=16 under gpt2_layer0's 64 here is
            // L=48): far above the ~2% random-argmax baseline is enough
            assert!(
                g.peak_match > 0.15,
                "{}: peaks misaligned ({:.2})",
                g.genre.name(),
                g.peak_match
            );
            assert_eq!(g.map_ref.len(), 48);
        }
    }

    #[test]
    fn thumbnail_dimensions() {
        let maps = compute(32, 2, 0);
        let t = thumbnail(&maps[0].map_ref, 10);
        assert_eq!(t.lines().count(), 10);
        assert!(t.lines().all(|l| l.chars().count() == 10));
    }

    #[test]
    fn render_emits_all_genres() {
        let maps = compute(32, 2, 0);
        let rep = render(&maps);
        for g in ["prose", "code", "technical"] {
            assert!(rep.markdown.contains(g));
        }
        assert!(!rep.csv.is_empty());
    }
}
