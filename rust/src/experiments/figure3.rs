//! Figure 3 — comprehensive evaluation, four panels:
//! (a) cosine vs compression, (b) KL (log scale) vs compression,
//! (c) Spearman ρ vs compression, (d) Pareto frontier (compression vs
//! cosine, scalar vs LOOKAT families).
//!
//! Emits the series as CSV (one row per method) + a Pareto analysis in
//! JSON; the markdown includes an ASCII rendition of panel (d).

use super::report::Report;
use super::table1::{self, Row};
use crate::util::json::Json;

pub struct Figure3 {
    pub rows: Vec<Row>,
    /// methods on the (compression, cosine) Pareto frontier
    pub pareto: Vec<String>,
}

/// A point dominates another if it has ≥ compression and ≥ cosine with
/// at least one strict.
pub fn pareto_frontier(rows: &[Row]) -> Vec<String> {
    let mut frontier = Vec::new();
    for a in rows {
        let dominated = rows.iter().any(|b| {
            (b.compression >= a.compression
                && b.agg.cosine.0 >= a.agg.cosine.0)
                && (b.compression > a.compression
                    || b.agg.cosine.0 > a.agg.cosine.0)
        });
        if !dominated {
            frontier.push(a.method.name());
        }
    }
    frontier
}

fn ascii_pareto(rows: &[Row]) -> String {
    // 48x14 scatter: x = log2(compression) 0..6, y = cosine 0.90..1.00
    const W: usize = 49;
    const H: usize = 15;
    let mut grid = vec![vec![' '; W]; H];
    let mut legend = String::new();
    for (i, r) in rows.iter().enumerate() {
        let x = ((r.compression.log2() / 6.0) * (W - 1) as f64)
            .clamp(0.0, (W - 1) as f64) as usize;
        let ymin = 0.90;
        let y = (((r.agg.cosine.0 - ymin) / (1.0 - ymin))
            * (H - 1) as f64)
            .clamp(0.0, (H - 1) as f64) as usize;
        let ch = char::from(b'A' + i as u8);
        grid[H - 1 - y][x] = ch;
        legend.push_str(&format!(
            "  {ch} = {:<16} ({:>4.0}x, cos {:.3})\n",
            r.method.name(),
            r.compression,
            r.agg.cosine.0
        ));
    }
    let mut s = String::from(
        "cosine 1.00 ┌─ Pareto panel (x: log2 compression 1x→64x) ─┐\n");
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            "            "
        } else if i == H - 1 {
            "cosine 0.90 "
        } else {
            "            "
        };
        s.push_str(label);
        s.push('│');
        s.extend(row.iter());
        s.push_str("│\n");
    }
    s.push_str("            └");
    s.push_str(&"─".repeat(W));
    s.push_str("┘\n");
    s.push_str(&legend);
    s
}

pub fn render(fig: &Figure3, len: usize) -> Report {
    let mut csv = String::from(
        "method,family,compression,bytes_per_token,cosine,cosine_std,\
         kl,kl_std,spearman,spearman_std,top5,top5_std\n",
    );
    let mut arr = Vec::new();
    for r in &fig.rows {
        let family = if matches!(r.method,
                                 super::eval::Method::Lookat { .. }) {
            "lookat"
        } else {
            "scalar"
        };
        csv.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{}\n",
            r.method.name(),
            family,
            r.compression,
            r.bytes_per_token,
            r.agg.cosine.0,
            r.agg.cosine.1,
            r.agg.kl.0,
            r.agg.kl.1,
            r.agg.spearman.0,
            r.agg.spearman.1,
            r.agg.top5.0,
            r.agg.top5.1,
        ));
        let mut o = Json::obj();
        o.set("method", Json::Str(r.method.name()));
        o.set("family", Json::Str(family.into()));
        o.set("compression", Json::Num(r.compression));
        o.set("metrics", r.agg.to_json());
        arr.push(o);
    }
    let mut j = Json::obj();
    j.set("series", Json::Arr(arr));
    j.set(
        "pareto_frontier",
        Json::Arr(fig.pareto.iter().map(|s| Json::Str(s.clone())).collect()),
    );

    let markdown = format!(
        "Four-panel data at L={len} (panels a–c are the CSV columns \
         cosine/kl/spearman vs compression; panel d below).\n\n\
         Pareto frontier (compression ⊕ cosine): **{}**\n\n```\n{}```\n",
        fig.pareto.join(", "),
        ascii_pareto(&fig.rows)
    );
    Report {
        id: "figure3".into(),
        title: "Comprehensive evaluation panels (paper Figure 3)".into(),
        markdown,
        json: j,
        csv,
    }
}

pub fn run(quick: bool) -> anyhow::Result<Figure3> {
    let (len, stride) = if quick { (96, 16) } else { (512, 8) };
    let rows = table1::compute(len, stride, 0xF16_3);
    let pareto = pareto_frontier(&rows);
    let fig = Figure3 { rows, pareto };
    render(&fig, len).emit()?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookat_dominates_high_compression_regime() {
        let rows = table1::compute(64, 16, 4);
        let pareto = pareto_frontier(&rows);
        // the highest-compression point is LOOKAT-2 by construction and
        // must be on the frontier (nothing has more compression)
        assert!(
            pareto.iter().any(|m| m == "LOOKAT-2"),
            "frontier: {pareto:?}"
        );
        // FP16 (cosine 1.0) is also non-dominated
        assert!(pareto.iter().any(|m| m.starts_with("FP16")));
    }

    #[test]
    fn csv_has_all_methods_and_families() {
        let rows = table1::compute(64, 16, 4);
        let pareto = pareto_frontier(&rows);
        let rep = render(&Figure3 { rows, pareto }, 64);
        assert_eq!(rep.csv.lines().count(), 8);
        assert!(rep.csv.contains(",lookat,"));
        assert!(rep.csv.contains(",scalar,"));
        assert!(rep.markdown.contains("Pareto"));
    }
}
