//! Paper-metric assertion helpers: Spearman rank-correlation and cosine
//! fidelity floors with readable failure messages.
//!
//! Thin, f32-friendly wrappers over [`crate::metrics`] — the single
//! source of truth for the metric definitions — plus `assert_*` forms
//! that report the observed value, the floor and a caller-supplied
//! context string on failure.

use crate::metrics;

/// Spearman rank correlation of two f32 score vectors.
pub fn spearman(a: &[f32], b: &[f32]) -> f64 {
    let af: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    let bf: Vec<f64> = b.iter().map(|&x| x as f64).collect();
    metrics::spearman_rho(&af, &bf)
}

/// Cosine similarity of two f32 vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    metrics::cosine_similarity(a, b)
}

/// Assert Spearman ρ(a, b) > `floor`; returns the observed ρ so callers
/// can additionally record it (e.g. for bit-stability comparisons).
pub fn assert_spearman_at_least(
    a: &[f32],
    b: &[f32],
    floor: f64,
    ctx: &str,
) -> f64 {
    let rho = spearman(a, b);
    assert!(
        rho > floor,
        "[{ctx}] Spearman rho {rho:.6} <= floor {floor}"
    );
    rho
}

/// Assert cosine(a, b) > `floor`; returns the observed value.
pub fn assert_cosine_at_least(
    a: &[f32],
    b: &[f32],
    floor: f64,
    ctx: &str,
) -> f64 {
    let cos = cosine(a, b);
    assert!(
        cos > floor,
        "[{ctx}] cosine {cos:.6} <= floor {floor}"
    );
    cos
}

/// Assert elementwise |a - b| <= tol with an index-carrying message.
pub fn assert_all_close(a: &[f32], b: &[f32], tol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "[{ctx}] length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            (x - y).abs() <= tol,
            "[{ctx}] element {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrappers_agree_with_metrics() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [2.0f32, 4.0, 6.0, 8.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        assert!((cosine(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn assert_forms_pass_and_return_value() {
        let a = [0.1f32, 0.9, 0.5];
        let rho = assert_spearman_at_least(&a, &a, 0.99, "self");
        assert!((rho - 1.0).abs() < 1e-12);
        let cos = assert_cosine_at_least(&a, &a, 0.99, "self");
        assert!((cos - 1.0).abs() < 1e-9);
        assert_all_close(&a, &a, 0.0, "self");
    }

    #[test]
    #[should_panic(expected = "Spearman")]
    fn spearman_floor_violation_panics_with_context() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [3.0f32, 2.0, 1.0];
        assert_spearman_at_least(&a, &b, 0.0, "reversed");
    }

    #[test]
    #[should_panic(expected = "element 1")]
    fn all_close_reports_failing_index() {
        assert_all_close(&[1.0, 2.0], &[1.0, 3.0], 0.5, "t");
    }
}
