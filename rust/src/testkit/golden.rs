//! Golden-value JSON fixtures: record-then-compare regression anchors.
//!
//! A [`Golden`] file maps fixture names to f32 arrays. The first run of a
//! test records the observed values (the file is created); later runs
//! compare against the recorded values within a tolerance. Re-bless by
//! deleting the file or setting `LOOKAT_BLESS=1`.
//!
//! Values are stored via their exact `f32::to_bits` representation in
//! addition to a human-readable decimal, so a comparison at `tol = 0.0`
//! is a true bit-stability check — JSON number round-tripping never
//! touches the payload.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::json::Json;

/// One golden-fixture file (lazy: loads if present, records if not).
pub struct Golden {
    path: PathBuf,
    doc: Json,
    /// true when the file did not exist and this run is recording
    recording: bool,
    dirty: bool,
}

impl Golden {
    /// Open (or start recording) the golden file at `path`.
    ///
    /// Bless mode (`LOOKAT_BLESS` set to anything but ""/"0") re-records
    /// the fixtures a run touches while keeping every other entry in the
    /// file intact — blessing one test must not delete its neighbours.
    pub fn open(path: &Path) -> anyhow::Result<Golden> {
        let bless = std::env::var("LOOKAT_BLESS")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        Self::open_with(path, bless)
    }

    /// [`Golden::open`] with an explicit bless flag (testable without
    /// process-global env mutation).
    pub fn open_with(path: &Path, bless: bool) -> anyhow::Result<Golden> {
        if path.exists() {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading golden {path:?}"))?;
            let doc = Json::parse(&text)
                .with_context(|| format!("parsing golden {path:?}"))?;
            Ok(Golden {
                path: path.to_path_buf(),
                doc,
                recording: bless,
                dirty: false,
            })
        } else {
            Ok(Golden {
                path: path.to_path_buf(),
                doc: Json::obj(),
                recording: true,
                dirty: false,
            })
        }
    }

    /// Whether this run is recording (no golden file existed).
    pub fn recording(&self) -> bool {
        self.recording
    }

    /// Check `values` against the recorded fixture `name`, or record
    /// them when recording. Returns true if a comparison happened.
    pub fn check_or_record(
        &mut self,
        name: &str,
        values: &[f32],
        tol: f32,
    ) -> anyhow::Result<bool> {
        if self.recording {
            let bits: Vec<Json> = values
                .iter()
                .map(|&v| Json::Num(v.to_bits() as f64))
                .collect();
            let dec: Vec<Json> =
                values.iter().map(|&v| Json::Num(v as f64)).collect();
            let mut entry = Json::obj();
            entry.set("bits", Json::Arr(bits));
            entry.set("values", Json::Arr(dec));
            self.doc.set(name, entry);
            self.dirty = true;
            return Ok(false);
        }
        let entry = self
            .doc
            .get(name)
            .with_context(|| format!("golden fixture '{name}' missing"))?;
        let bits = entry
            .get("bits")
            .and_then(|b| b.as_arr())
            .with_context(|| format!("golden '{name}' has no bits array"))?;
        anyhow::ensure!(
            bits.len() == values.len(),
            "golden '{name}': recorded {} values, observed {}",
            bits.len(),
            values.len()
        );
        for (i, (b, &got)) in bits.iter().zip(values).enumerate() {
            let want = f32::from_bits(
                b.as_f64()
                    .with_context(|| format!("golden '{name}' bad bits"))?
                    as u32,
            );
            let ok = if tol == 0.0 {
                want.to_bits() == got.to_bits()
            } else {
                (want - got).abs() <= tol
            };
            anyhow::ensure!(
                ok,
                "golden '{name}' mismatch at {i}: recorded {want}, \
                 observed {got} (tol {tol})"
            );
        }
        Ok(true)
    }

    /// Persist newly-recorded fixtures (no-op unless recording+dirty).
    pub fn save(&self) -> anyhow::Result<()> {
        if !self.dirty {
            return Ok(());
        }
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&self.path, self.doc.to_string_pretty())
            .with_context(|| format!("writing golden {:?}", self.path))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("lookat-golden-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn record_then_check_roundtrip_is_bit_exact() {
        let path = tmp("roundtrip.json");
        std::fs::remove_file(&path).ok();
        let vals = [1.5f32, -0.25, 3.0e-8, 1234.5678];

        let mut g = Golden::open_with(&path, false).unwrap();
        assert!(g.recording());
        assert!(!g.check_or_record("v", &vals, 0.0).unwrap());
        g.save().unwrap();
        assert!(path.exists());

        let mut g2 = Golden::open_with(&path, false).unwrap();
        assert!(!g2.recording());
        assert!(g2.check_or_record("v", &vals, 0.0).unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bless_preserves_untouched_fixtures() {
        // blessing one fixture must keep the file's other entries
        let path = tmp("bless-merge.json");
        std::fs::remove_file(&path).ok();
        let mut g = Golden::open_with(&path, false).unwrap();
        g.check_or_record("a", &[1.0], 0.0).unwrap();
        g.check_or_record("b", &[2.0], 0.0).unwrap();
        g.save().unwrap();

        // bless mode: existing doc is loaded, not discarded
        let mut g2 = Golden::open_with(&path, true).unwrap();
        assert!(g2.recording());
        g2.check_or_record("a", &[1.5], 0.0).unwrap();
        g2.save().unwrap();

        let mut g3 = Golden::open_with(&path, false).unwrap();
        assert!(!g3.recording());
        assert!(g3.check_or_record("a", &[1.5], 0.0).unwrap());
        assert!(g3.check_or_record("b", &[2.0], 0.0).unwrap(),
                "untouched fixture must survive a bless run");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bless_off_flag_compares_instead_of_recording() {
        let path = tmp("bless-off.json");
        std::fs::remove_file(&path).ok();
        let mut g = Golden::open_with(&path, false).unwrap();
        g.check_or_record("v", &[1.0], 0.0).unwrap();
        g.save().unwrap();
        let g2 = Golden::open_with(&path, false).unwrap();
        assert!(!g2.recording(), "existing file + bless off must compare");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mismatch_is_detected() {
        let path = tmp("mismatch.json");
        std::fs::remove_file(&path).ok();
        let mut g = Golden::open_with(&path, false).unwrap();
        g.check_or_record("v", &[1.0, 2.0], 0.0).unwrap();
        g.save().unwrap();

        let mut g2 = Golden::open_with(&path, false).unwrap();
        let err = g2
            .check_or_record("v", &[1.0, 2.5], 0.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("mismatch"), "{err}");
        // within tolerance passes
        assert!(g2.check_or_record("v", &[1.0, 2.5], 1.0).unwrap());
        // length change is an error
        assert!(g2.check_or_record("v", &[1.0], 0.0).is_err());
        // unknown fixture is an error
        assert!(g2.check_or_record("w", &[1.0], 0.0).is_err());
        std::fs::remove_file(&path).ok();
    }
}
