//! Test support kit: seeded fixture generators, golden-value JSON
//! fixtures, and paper-metric assertion helpers.
//!
//! Everything here is deterministic by construction — fixtures are
//! parameterized by an explicit [`crate::util::rng::Pcg32`] seed, so the
//! paper-fidelity suite (`rust/tests/paper_fidelity.rs`) is bit-stable
//! across runs and platforms. The module is part of the public crate so
//! integration tests, benches and downstream experiment code can share
//! one vocabulary of inputs.

pub mod assertions;
pub mod fixtures;
pub mod golden;

pub use assertions::{
    assert_all_close, assert_cosine_at_least, assert_spearman_at_least,
    cosine, spearman,
};
pub use fixtures::{
    cluster_centers, clustered_keys, gaussian_keys, keys_from_centers,
    low_rank_keys, queries,
};
pub use golden::Golden;
