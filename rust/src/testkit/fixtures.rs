//! Seeded fixture generators for key/value/query tensors.
//!
//! The paper's premise (§1) is that transformer keys live near a
//! low-intrinsic-dimension manifold, which is what makes PQ codebooks
//! capture them at 32–64× compression. The generators here span that
//! spectrum explicitly:
//!
//! * [`gaussian_keys`] — iid N(0,1): the PQ *worst case* at fixed
//!   variance (no structure to exploit).
//! * [`low_rank_keys`] — rank-r + noise, mirroring the structured model
//!   init in `model::weights`.
//! * [`clustered_keys`] — a C-cluster Gaussian mixture with tight
//!   clusters: the PQ-favorable regime the fidelity floors are asserted
//!   on. [`cluster_centers`] + [`keys_from_centers`] let the calibration
//!   and evaluation sets share centers while drawing independent noise,
//!   which is the paper's §5.1 deployment setting (train on calibration
//!   data, apply to fresh caches from the same distribution).

use crate::util::rng::Pcg32;

/// iid standard-normal keys, (n × d) row-major.
pub fn gaussian_keys(n: usize, d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seed(seed);
    (0..n * d).map(|_| rng.next_f32_std()).collect()
}

/// `n_q` query vectors, (n_q × d) row-major, iid N(0,1).
pub fn queries(n_q: usize, d: usize, seed: u64) -> Vec<f32> {
    gaussian_keys(n_q, d, seed ^ 0x51EE17)
}

/// `c` cluster centers, (c × d) row-major, iid N(0,1).
pub fn cluster_centers(c: usize, d: usize, seed: u64) -> Vec<f32> {
    assert!(c > 0 && d > 0);
    let mut rng = Pcg32::seed(seed ^ 0xCE17E2);
    (0..c * d).map(|_| rng.next_f32_std()).collect()
}

/// `n` keys drawn around the given (c × d) centers: cluster id uniform,
/// key = center + sigma·N(0,1). Independent draws for any `seed`, so the
/// same centers can back both a calibration and an evaluation set.
pub fn keys_from_centers(
    centers: &[f32],
    c: usize,
    n: usize,
    d: usize,
    sigma: f32,
    seed: u64,
) -> Vec<f32> {
    assert_eq!(centers.len(), c * d, "centers shape mismatch");
    let mut rng = Pcg32::seed(seed);
    let mut out = Vec::with_capacity(n * d);
    for _ in 0..n {
        let id = rng.next_bounded(c as u32) as usize;
        let center = &centers[id * d..(id + 1) * d];
        for &cv in center {
            out.push(cv + sigma * rng.next_f32_std());
        }
    }
    out
}

/// Convenience: fresh centers + one key set in a single call.
pub fn clustered_keys(
    n: usize,
    d: usize,
    c: usize,
    sigma: f32,
    seed: u64,
) -> Vec<f32> {
    let centers = cluster_centers(c, d, seed);
    keys_from_centers(&centers, c, n, d, sigma, seed ^ 0x0FF5E7)
}

/// Rank-`r` + noise keys: z(r) @ B(r×d) + eps·N(0,1), matching the
/// anisotropic key-projection init of `model::weights`.
pub fn low_rank_keys(
    n: usize,
    d: usize,
    r: usize,
    eps: f32,
    seed: u64,
) -> Vec<f32> {
    assert!(r > 0 && r <= d);
    let mut rng = Pcg32::seed(seed ^ 0x10243A);
    let basis: Vec<f32> = (0..r * d)
        .map(|_| rng.next_f32_std() / (r as f32).sqrt())
        .collect();
    let mut out = Vec::with_capacity(n * d);
    for _ in 0..n {
        let z: Vec<f32> = (0..r).map(|_| rng.next_f32_std()).collect();
        for col in 0..d {
            let mut v = 0.0f32;
            for (k, &zk) in z.iter().enumerate() {
                v += zk * basis[k * d + col];
            }
            out.push(v + eps * rng.next_f32_std());
        }
    }
    out
}

/// Re-pack token-major (n × m) PQ codes into subspace-major fast-scan
/// lanes of at most `group` tokens each: one `(m × group)` row-major
/// lane per group (full stride even for a partial tail, mirroring the
/// paged cache's block layout), paired with the group's valid token
/// count. This is the layout `KvCache` blocks expose to
/// `LookupTable::scores_lanes` / `pq::values::weighted_decode_lanes`;
/// the parity suites use this helper to build reference lanes.
pub fn interleave_lanes(
    codes: &[u8],
    m: usize,
    group: usize,
) -> Vec<(Vec<u8>, usize)> {
    assert!(m > 0 && group > 0);
    assert_eq!(codes.len() % m, 0, "token-major codes must be n × m");
    let n = codes.len() / m;
    let mut lanes = Vec::new();
    let mut t0 = 0usize;
    while t0 < n {
        let len = group.min(n - t0);
        let mut lane = vec![0u8; m * group];
        for t in 0..len {
            for i in 0..m {
                lane[i * group + t] = codes[(t0 + t) * m + i];
            }
        }
        lanes.push((lane, len));
        t0 += len;
    }
    lanes
}

/// Nibble-packed sibling of [`interleave_lanes`] for K ≤ 16 codecs:
/// each lane row holds `group/2` bytes, two 4-bit codes per byte (low
/// nibble = even token, high nibble = odd token — the paged cache's
/// packed block layout consumed by `LookupTable::scores_lanes_packed`
/// and `pq::values::weighted_decode_lanes_packed`). `group` must be
/// even (the cache's `BLOCK_TOKENS` is); a partial tail simply leaves
/// trailing bytes zero, like a partially filled block.
pub fn interleave_lanes_packed(
    codes: &[u8],
    m: usize,
    group: usize,
) -> Vec<(Vec<u8>, usize)> {
    assert!(m > 0 && group > 0 && group % 2 == 0);
    assert_eq!(codes.len() % m, 0, "token-major codes must be n × m");
    assert!(
        codes.iter().all(|&c| c < 16),
        "packed lanes hold 4-bit codes"
    );
    let row = group / 2;
    let n = codes.len() / m;
    let mut lanes = Vec::new();
    let mut t0 = 0usize;
    while t0 < n {
        let len = group.min(n - t0);
        let mut lane = vec![0u8; m * row];
        for t in 0..len {
            for i in 0..m {
                let c = codes[(t0 + t) * m + i];
                let b = &mut lane[i * row + t / 2];
                if t % 2 == 0 {
                    *b = c;
                } else {
                    *b |= c << 4;
                }
            }
        }
        lanes.push((lane, len));
        t0 += len;
    }
    lanes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic_per_seed() {
        assert_eq!(gaussian_keys(8, 4, 1), gaussian_keys(8, 4, 1));
        assert_ne!(gaussian_keys(8, 4, 1), gaussian_keys(8, 4, 2));
        assert_eq!(
            clustered_keys(16, 8, 4, 0.1, 3),
            clustered_keys(16, 8, 4, 0.1, 3)
        );
        assert_eq!(
            low_rank_keys(16, 8, 2, 0.1, 4),
            low_rank_keys(16, 8, 2, 0.1, 4)
        );
    }

    #[test]
    fn shapes_are_correct() {
        assert_eq!(gaussian_keys(7, 5, 0).len(), 35);
        assert_eq!(cluster_centers(3, 4, 0).len(), 12);
        let centers = cluster_centers(3, 4, 0);
        assert_eq!(keys_from_centers(&centers, 3, 10, 4, 0.1, 1).len(), 40);
        assert_eq!(low_rank_keys(6, 8, 3, 0.05, 2).len(), 48);
        assert_eq!(queries(2, 16, 9).len(), 32);
    }

    #[test]
    fn packed_lanes_mirror_byte_lanes() {
        // same codes, both layouts: unpacking the packed lane must give
        // the byte lane exactly, nibble order low-then-high
        let codes: Vec<u8> =
            (0..37 * 3).map(|i| (i * 7 % 16) as u8).collect();
        let byte_lanes = interleave_lanes(&codes, 3, 8);
        let packed = interleave_lanes_packed(&codes, 3, 8);
        assert_eq!(byte_lanes.len(), packed.len());
        for ((bl, bn), (pl, pn)) in byte_lanes.iter().zip(&packed) {
            assert_eq!(bn, pn);
            assert_eq!(pl.len(), 3 * 4);
            for i in 0..3 {
                for t in 0..*bn {
                    let nib = (pl[i * 4 + t / 2] >> ((t % 2) * 4)) & 0xF;
                    assert_eq!(nib, bl[i * 8 + t], "i={i} t={t}");
                }
            }
        }
    }

    #[test]
    fn clustered_keys_sit_near_their_centers() {
        let (c, d, sigma) = (4usize, 16usize, 0.05f32);
        let centers = cluster_centers(c, d, 7);
        let keys = keys_from_centers(&centers, c, 64, d, sigma, 8);
        // every key must be within a few sigma·sqrt(d) of SOME center
        let bound = 6.0 * sigma * (d as f32).sqrt();
        for t in 0..64 {
            let key = &keys[t * d..(t + 1) * d];
            let min_d = (0..c)
                .map(|i| {
                    crate::tensor::dist2(key, &centers[i * d..(i + 1) * d])
                        .sqrt()
                })
                .fold(f32::INFINITY, f32::min);
            assert!(min_d < bound, "key {t} is {min_d} from nearest center");
        }
    }

    #[test]
    fn low_rank_keys_are_actually_low_rank() {
        // residual energy off the top-r directions should be ~eps²·d;
        // cheap proxy: compare quantization-friendliness per
        // model/gpt2.rs::key_anisotropy_visible_in_cache
        let d = 32;
        let n = 256;
        let lr = low_rank_keys(n, d, 4, 0.05, 11);
        let var: f64 = lr.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            / lr.len() as f64;
        // rank-4 signal with unit z and 1/sqrt(r) basis scaling has
        // per-dim variance ~1/r·r = O(1); just sanity-check spread exists
        assert!(var > 0.01 && var.is_finite());
    }
}
