//! Small statistics toolkit: summary stats, percentiles, histograms and
//! online (Welford) accumulation. Used by the bench harness, the metrics
//! module and the coordinator's latency accounting.

/// Summary of a sample: mean, std (unbiased), min/max, percentiles.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / (n as f64 - 1.0)
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        })
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of a slice (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, 0.5)
}

/// Median absolute deviation — robust spread estimate for bench timings.
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Mean and (sample) standard deviation as a pair.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let s = Summary::of(xs).expect("mean_std of empty slice");
    (s.mean, s.std)
}

/// Online mean/variance accumulator (Welford). O(1) memory, numerically
/// stable; used by the coordinator's rolling latency stats.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance (0 for n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Merge another accumulator (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        self.mean += delta * other.n as f64 / n as f64;
        self.m2 += other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
    }
}

/// Fixed-bin histogram over [lo, hi); under/overflow clamp to edge bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self { lo, hi, bins: vec![0; nbins] }
    }

    pub fn push(&mut self, x: f64) {
        let nb = self.bins.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * nb as f64) as i64).clamp(0, nb as i64 - 1) as usize;
        self.bins[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let s = Summary::of(&xs).unwrap();
        assert!((s.mean - 5.0).abs() < 1e-12);
        // unbiased std of this classic sample is sqrt(32/7)
        assert!((s.std - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::of(&[3.5]).unwrap();
        assert_eq!(s.mean, 3.5);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 3.5);
        assert_eq!(s.p99, 3.5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile_sorted(&xs, 0.5) - 2.5).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 4.0);
    }

    #[test]
    fn median_and_mad() {
        let xs = [1.0, 1.0, 2.0, 2.0, 4.0, 6.0, 9.0];
        assert_eq!(median(&xs), 2.0);
        assert_eq!(mad(&xs), 1.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 3.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-10);
        assert!((w.std() - s.std).abs() < 1e-10);
    }

    #[test]
    fn welford_merge_matches_sequential() {
        let xs: Vec<f64> = (0..500).map(|i| i as f64 * 0.1).collect();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..200] {
            a.push(x);
        }
        for &x in &xs[200..] {
            b.push(x);
        }
        a.merge(&b);
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(a.count(), w.count());
        assert!((a.mean() - w.mean()).abs() < 1e-10);
        assert!((a.variance() - w.variance()).abs() < 1e-9);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-5.0); // clamps to bin 0
        h.push(50.0); // clamps to last bin
        assert_eq!(h.total(), 12);
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
    }
}
