//! Miniature property-testing framework (the real proptest crate is not
//! vendored offline).
//!
//! A property is a closure over a [`Gen`] (seeded RNG wrapper with value
//! generators). [`check`] runs it for N seeded cases; on failure it
//! reports the failing case index and seed so the case can be replayed
//! deterministically with [`replay`].

use crate::util::rng::Pcg32;

/// Value generators for one property-test case.
pub struct Gen {
    pub rng: Pcg32,
    pub case: usize,
}

impl Gen {
    /// usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.next_bounded((hi - lo + 1) as u32) as usize
    }

    /// f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.next_range_f32(lo, hi)
    }

    /// Standard-normal f32 vector of length n.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.next_f32_std()).collect()
    }

    /// Uniform f32 vector in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.next_range_f32(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_bounded(xs.len() as u32) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }
}

/// Outcome of a property over many cases.
#[derive(Debug)]
pub struct PropResult {
    pub cases: usize,
    pub failure: Option<PropFailure>,
}

#[derive(Debug)]
pub struct PropFailure {
    pub case: usize,
    pub seed: u64,
    pub message: String,
}

/// Run `prop` for `cases` seeded cases. Return Err-like result on first
/// failure (panics are caught so the failing seed is always reported).
pub fn check<F>(
    name: &str,
    cases: usize,
    base_seed: u64,
    mut prop: F,
) -> PropResult
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                let mut g = Gen { rng: Pcg32::seed(seed), case };
                prop(&mut g)
            },
        ));
        let failed = match outcome {
            Ok(Ok(())) => None,
            Ok(Err(msg)) => Some(msg),
            Err(p) => Some(format!(
                "panic: {}",
                p.downcast_ref::<&str>().copied().unwrap_or("<non-str>")
            )),
        };
        if let Some(message) = failed {
            return PropResult {
                cases,
                failure: Some(PropFailure { case, seed, message }),
            };
        }
    }
    let _ = name;
    PropResult { cases, failure: None }
}

/// Re-run a single failing case by seed (debugging helper).
pub fn replay<F>(seed: u64, prop: F) -> Result<(), String>
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen { rng: Pcg32::seed(seed), case: 0 };
    prop(&mut g)
}

/// Assert a property holds; formats the failing seed into the panic.
#[macro_export]
macro_rules! prop_assert {
    ($name:expr, $cases:expr, $prop:expr) => {{
        let r = $crate::util::proptest::check($name, $cases, 0xC0FFEE, $prop);
        if let Some(f) = r.failure {
            panic!(
                "property '{}' failed at case {}/{} (replay seed {:#x}): {}",
                $name, f.case, r.cases, f.seed, f.message
            );
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_assert!("add-commutes", 50, |g: &mut Gen| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = check("always-fails", 10, 42, |_g| Err("nope".into()));
        let f = r.failure.expect("should fail");
        assert_eq!(f.case, 0);
        assert!(f.message.contains("nope"));
        // the reported seed replays to the same failure
        assert!(replay(f.seed, |_g| Err::<(), _>("nope".into())).is_err());
    }

    #[test]
    fn panicking_property_is_caught() {
        let r = check("panics", 5, 7, |g| {
            if g.case == 3 {
                panic!("boom");
            }
            Ok(())
        });
        let f = r.failure.expect("should fail");
        assert_eq!(f.case, 3);
        assert!(f.message.contains("panic"));
    }

    #[test]
    fn generators_in_range() {
        let mut g = Gen { rng: Pcg32::seed(1), case: 0 };
        for _ in 0..1000 {
            let u = g.usize_in(3, 9);
            assert!((3..=9).contains(&u));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let v = g.normal_vec(100);
        assert_eq!(v.len(), 100);
        let picked = *g.choose(&[1, 2, 3]);
        assert!([1, 2, 3].contains(&picked));
    }

    #[test]
    fn cases_are_deterministic_for_same_base_seed() {
        let collect = |base| {
            let mut vals = Vec::new();
            check("det", 5, base, |g| {
                vals.push(g.rng.next_u32());
                Ok(())
            });
            vals
        };
        assert_eq!(collect(9), collect(9));
        assert_ne!(collect(9), collect(10));
    }
}
