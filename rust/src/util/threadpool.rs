//! Minimal work-stealing-free thread pool + scoped parallel-for.
//!
//! tokio is not vendored in the offline image; the coordinator's event
//! loop and the experiment harness use this instead. The pool owns N
//! worker threads fed from a shared MPMC queue built on std primitives.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    inflight: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            inflight: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("lookat-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(f));
        }
        self.shared.cv.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_mx.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done_cv.wait(guard).unwrap();
        }
    }

    /// Number of queued-or-running jobs.
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::SeqCst)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if *sh.shutdown.lock().unwrap() {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        job();
        if sh.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.done_mx.lock().unwrap();
            sh.done_cv.notify_all();
        }
    }
}

/// Run `f(i)` for i in 0..n, chunked across up to `threads` scoped threads,
/// writing results into the returned Vec. Uses std::thread::scope, so `f`
/// only needs to be Sync (no 'static bound).
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out = vec![T::default(); n];
    if n == 0 {
        return out;
    }
    if threads == 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        return out;
    }
    let chunk = n.div_ceil(threads);
    let fref = &f;
    std::thread::scope(|s| {
        for (t, slice) in out.chunks_mut(chunk).enumerate() {
            s.spawn(move || {
                for (j, slot) in slice.iter_mut().enumerate() {
                    *slot = fref(t * chunk + j);
                }
            });
        }
    });
    out
}

/// Fallible [`parallel_map`]: run `f(i)` for i in 0..n across up to
/// `threads` scoped threads and collect the results, or return the
/// lowest-index error. Unlike [`parallel_map`] there is no
/// `Default + Clone` bound, so it also suits result types that carry
/// owned buffers (the batched-decode kernels' `AttnOutput`s).
///
/// Like [`parallel_map`], workers are `std::thread::scope` threads
/// spawned per call — that is what lets `f` borrow non-`'static` plan
/// state. The spawn/join cost is a few tens of µs per call, noise next
/// to a decode tick's model math; a borrow-capable fan-out over the
/// persistent [`ThreadPool`] is a ROADMAP item if profiles ever say
/// otherwise.
pub fn parallel_try_map<T, E, F>(
    n: usize,
    threads: usize,
    f: F,
) -> Result<Vec<T>, E>
where
    T: Send,
    E: Send,
    F: Fn(usize) -> Result<T, E> + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(f(i)?);
        }
        return Ok(out);
    }
    let mut slots: Vec<Option<Result<T, E>>> =
        (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    let fref = &f;
    std::thread::scope(|s| {
        for (t, slice) in slots.chunks_mut(chunk).enumerate() {
            s.spawn(move || {
                for (j, slot) in slice.iter_mut().enumerate() {
                    *slot = Some(fref(t * chunk + j));
                }
            });
        }
    });
    let mut out = Vec::with_capacity(n);
    for r in slots {
        out.push(r.expect("parallel_try_map: unfilled slot")?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not deadlock
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn jobs_can_be_submitted_after_wait() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang
        // jobs may or may not all have run before shutdown flag is seen,
        // but the queued ones before drop had inflight ticks; just ensure
        // no deadlock occurred to get here.
    }

    #[test]
    fn parallel_map_matches_serial() {
        let got = parallel_map(1000, 8, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn parallel_map_single_thread_path() {
        let got = parallel_map(64, 1, |i| i as f64 * 0.5);
        assert_eq!(got[63], 31.5);
    }

    #[test]
    fn parallel_try_map_ok_matches_serial() {
        let par = parallel_try_map(500, 8, |i| Ok::<_, String>(i * 3));
        let ser = parallel_try_map(500, 1, |i| Ok::<_, String>(i * 3));
        let want: Vec<usize> = (0..500).map(|i| i * 3).collect();
        assert_eq!(par.unwrap(), want);
        assert_eq!(ser.unwrap(), want);
    }

    #[test]
    fn parallel_try_map_reports_lowest_index_error() {
        let got = parallel_try_map(100, 4, |i| {
            if i == 17 || i == 63 {
                Err(format!("boom {i}"))
            } else {
                Ok(i)
            }
        });
        assert_eq!(got.unwrap_err(), "boom 17");
    }

    #[test]
    fn parallel_try_map_empty() {
        let got: Result<Vec<usize>, String> =
            parallel_try_map(0, 4, |i| Ok(i));
        assert_eq!(got.unwrap(), Vec::<usize>::new());
    }
}
