//! Minimal work-stealing-free thread pool + scoped parallel-for.
//!
//! tokio is not vendored in the offline image; the coordinator's event
//! loop and the experiment harness use this instead. The pool owns N
//! worker threads fed from a shared MPMC queue built on std primitives.
//!
//! [`ThreadPool::run_scoped`] is the borrow-capable fan-out primitive:
//! it runs closures that borrow caller state on the *persistent*
//! workers (blocking until every task finishes, which is what makes the
//! lifetime erasure sound), and the free functions
//! [`parallel_map`]/[`parallel_try_map`] route through a process-wide
//! pool via it — so decode-tick workers, and their thread-local gather
//! scratch, persist across ticks instead of being re-spawned per call.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    inflight: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            inflight: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("lookat-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(f));
        }
        self.shared.cv.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_mx.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done_cv.wait(guard).unwrap();
        }
    }

    /// Number of queued-or-running jobs.
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    /// Pop one queued job and run it on the calling thread. Returns
    /// false when the queue is empty. This is the work-helping hook
    /// [`ThreadPool::run_scoped`] uses while it blocks, so a fan-out
    /// issued *from inside* a pool job can never deadlock the fixed
    /// worker set.
    fn try_run_one(&self) -> bool {
        let job = { self.shared.queue.lock().unwrap().pop_front() };
        match job {
            Some(job) => {
                job();
                if self.shared.inflight.fetch_sub(1, Ordering::SeqCst)
                    == 1
                {
                    let _g = self.shared.done_mx.lock().unwrap();
                    self.shared.done_cv.notify_all();
                }
                true
            }
            None => false,
        }
    }

    /// Run `f(t)` for t in 0..tasks on the pool's persistent workers,
    /// blocking until every task has finished. Unlike [`submit`],
    /// `f` may borrow caller state (no `'static` bound): the closure
    /// reference is lifetime-erased for the queue, which is sound
    /// because this call does not return — and so the borrow cannot
    /// dangle — until the last task completes. The calling thread helps
    /// drain the queue while it waits.
    ///
    /// [`submit`]: ThreadPool::submit
    pub fn run_scoped<'env, F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync + 'env,
    {
        if tasks == 0 {
            return;
        }
        struct Latch {
            left: Mutex<usize>,
            cv: Condvar,
            /// first panic payload from a task, repropagated on the
            /// calling thread so assertion messages stay attributed
            panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
        }
        let latch = Arc::new(Latch {
            left: Mutex::new(tasks),
            cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the erased reference is only reachable from the
        // `tasks` jobs enqueued below, and this function blocks until
        // the latch counts every one of them as finished — `f` and
        // everything it borrows strictly outlive all uses.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(f_ref)
        };
        for t in 0..tasks {
            let latch = latch.clone();
            self.submit(move || {
                // a panicking task must still count down (and keep its
                // worker alive) or the caller would block forever; the
                // payload is repropagated on the calling thread below
                if let Err(payload) = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| f_static(t)),
                ) {
                    let mut slot = latch.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
                let mut left = latch.left.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    // notify while holding the lock: the waiter below
                    // checks the count under the same lock, so the
                    // wakeup cannot be lost
                    latch.cv.notify_all();
                }
            });
        }
        loop {
            // opportunistically run queued jobs (ours or another
            // scope's) instead of parking
            let done = loop {
                if *latch.left.lock().unwrap() == 0 {
                    break true;
                }
                if !self.try_run_one() {
                    break false;
                }
            };
            if done {
                break;
            }
            let left = latch.left.lock().unwrap();
            if *left == 0 {
                break;
            }
            // queue drained but tasks still running on workers — sleep
            // until a completion notifies
            drop(latch.cv.wait(left).unwrap());
        }
        if let Some(payload) = latch.panic.lock().unwrap().take() {
            // same behavior as std::thread::scope: the child's payload
            // (e.g. an assert message) reaches the caller intact
            std::panic::resume_unwind(payload);
        }
    }
}

/// The process-wide pool behind [`parallel_map`]/[`parallel_try_map`]:
/// one persistent worker per available core, spawned on first use.
/// Worker threads — and their `thread_local!` scratch — live for the
/// whole process, so per-tick fan-outs reuse warm allocations.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(ThreadPool::with_default_size)
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if *sh.shutdown.lock().unwrap() {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        job();
        if sh.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.done_mx.lock().unwrap();
            sh.done_cv.notify_all();
        }
    }
}

/// Run `f(i)` for i in 0..n, chunked across up to `threads` tasks on
/// the persistent [`global`] pool, writing results into the returned
/// Vec. `f` only needs to be Sync (no 'static bound) — the pool's
/// scoped fan-out blocks until every chunk lands.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out = vec![T::default(); n];
    if n == 0 {
        return out;
    }
    if threads == 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        return out;
    }
    let chunk = n.div_ceil(threads);
    let chunks: Vec<Mutex<&mut [T]>> =
        out.chunks_mut(chunk).map(Mutex::new).collect();
    global().run_scoped(chunks.len(), |t| {
        // each chunk's mutex is locked exactly once, by its own task —
        // it only exists to hand the &mut slice across the Fn boundary
        let slice = &mut *chunks[t].lock().unwrap();
        for (j, slot) in slice.iter_mut().enumerate() {
            *slot = f(t * chunk + j);
        }
    });
    drop(chunks);
    out
}

/// Fallible [`parallel_map`]: run `f(i)` for i in 0..n across up to
/// `threads` tasks on the persistent [`global`] pool and collect the
/// results, or return the lowest-index error. Unlike [`parallel_map`]
/// there is no `Default + Clone` bound, so it also suits result types
/// that carry owned buffers (the batched-decode kernels'
/// `AttnOutput`s). Per-index results are independent, so routing
/// through the pool changes nothing observable — the decode pipeline's
/// batched-equals-serial bit-parity holds by construction.
pub fn parallel_try_map<T, E, F>(
    n: usize,
    threads: usize,
    f: F,
) -> Result<Vec<T>, E>
where
    T: Send,
    E: Send,
    F: Fn(usize) -> Result<T, E> + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(f(i)?);
        }
        return Ok(out);
    }
    let mut slots: Vec<Option<Result<T, E>>> =
        (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    let chunks: Vec<Mutex<&mut [Option<Result<T, E>>]>> =
        slots.chunks_mut(chunk).map(Mutex::new).collect();
    global().run_scoped(chunks.len(), |t| {
        let slice = &mut *chunks[t].lock().unwrap();
        for (j, slot) in slice.iter_mut().enumerate() {
            *slot = Some(f(t * chunk + j));
        }
    });
    drop(chunks);
    let mut out = Vec::with_capacity(n);
    for r in slots {
        out.push(r.expect("parallel_try_map: unfilled slot")?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not deadlock
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn jobs_can_be_submitted_after_wait() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang
        // jobs may or may not all have run before shutdown flag is seen,
        // but the queued ones before drop had inflight ticks; just ensure
        // no deadlock occurred to get here.
    }

    #[test]
    fn run_scoped_borrows_caller_state() {
        // the whole point of the scoped API: f borrows non-'static data
        let data: Vec<u64> = (0..256).collect();
        let sum = AtomicU64::new(0);
        let pool = ThreadPool::new(4);
        pool.run_scoped(8, |t| {
            let part: u64 =
                data[t * 32..(t + 1) * 32].iter().sum();
            sum.fetch_add(part, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), data.iter().sum::<u64>());
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn run_scoped_zero_tasks_returns() {
        let pool = ThreadPool::new(2);
        pool.run_scoped(0, |_| panic!("must not run"));
    }

    #[test]
    fn nested_run_scoped_does_not_deadlock() {
        // a fan-out issued from inside a pool job must complete even
        // when it outnumbers the workers (caller work-helping)
        let pool = ThreadPool::new(2);
        let counter = AtomicU64::new(0);
        pool.run_scoped(4, |_| {
            pool.run_scoped(4, |_| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn run_scoped_on_single_worker_pool_completes() {
        let pool = ThreadPool::new(1);
        let counter = AtomicU64::new(0);
        pool.run_scoped(32, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn run_scoped_surfaces_task_panics_without_hanging() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run_scoped(4, |t| {
                    if t == 2 {
                        panic!("boom");
                    }
                });
            }),
        );
        assert!(caught.is_err(), "panic must propagate to the caller");
        // pool still works afterwards
        let counter = AtomicU64::new(0);
        pool.run_scoped(4, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        assert!(global().size() >= 1);
        assert!(std::ptr::eq(global(), global()));
    }

    #[test]
    fn parallel_map_matches_serial() {
        let got = parallel_map(1000, 8, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn parallel_map_single_thread_path() {
        let got = parallel_map(64, 1, |i| i as f64 * 0.5);
        assert_eq!(got[63], 31.5);
    }

    #[test]
    fn parallel_try_map_ok_matches_serial() {
        let par = parallel_try_map(500, 8, |i| Ok::<_, String>(i * 3));
        let ser = parallel_try_map(500, 1, |i| Ok::<_, String>(i * 3));
        let want: Vec<usize> = (0..500).map(|i| i * 3).collect();
        assert_eq!(par.unwrap(), want);
        assert_eq!(ser.unwrap(), want);
    }

    #[test]
    fn parallel_try_map_reports_lowest_index_error() {
        let got = parallel_try_map(100, 4, |i| {
            if i == 17 || i == 63 {
                Err(format!("boom {i}"))
            } else {
                Ok(i)
            }
        });
        assert_eq!(got.unwrap_err(), "boom 17");
    }

    #[test]
    fn parallel_try_map_empty() {
        let got: Result<Vec<usize>, String> =
            parallel_try_map(0, 4, |i| Ok(i));
        assert_eq!(got.unwrap(), Vec::<usize>::new());
    }
}
