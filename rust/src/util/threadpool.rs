//! Minimal work-stealing-free thread pool + scoped parallel-for +
//! tick-scoped scratch arenas.
//!
//! tokio is not vendored in the offline image; the coordinator's event
//! loop and the experiment harness use this instead. The pool owns N
//! worker threads fed from a shared MPMC queue built on std primitives.
//!
//! [`ThreadPool::run_scoped`] is the borrow-capable fan-out primitive:
//! it runs closures that borrow caller state on the *persistent*
//! workers (blocking until every task finishes, which is what makes the
//! lifetime erasure sound), and the free functions
//! [`parallel_map`]/[`parallel_try_map`] route through a process-wide
//! pool via it — so decode-tick workers, and their thread-local gather
//! scratch, persist across ticks instead of being re-spawned per call.
//! [`ThreadPool::overlap`] is the two-stage sibling: one borrow-capable
//! background task on a worker while the caller runs a foreground
//! closure inline, joined before returning — the engine's
//! software-pipelined layer executor is built on it.
//!
//! The pool also owns a [`ScratchPool`]: a recycler of f32 buffers
//! that the decode hot path leases per tick (LUT tables, score/weight
//! vectors, GEMM staging). Buffers cycle engine → kernels → engine, so
//! after warm-up a steady-state decode tick performs no scratch heap
//! allocations — the churn of per-item `Vec::with_capacity`/`vec!` that
//! used to dominate the allocator profile is gone.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Recycler of scratch buffers owned by a [`ThreadPool`].
///
/// [`ScratchPool::take_f32`] leases a zero-filled buffer (identical
/// semantics to `vec![0.0; len]`); [`ScratchPool::take_f32_any`] skips
/// the fill for consumers that overwrite every element;
/// [`ScratchPool::put_f32`] returns one. The pool is shared across
/// threads behind a mutex: lease/return pairs are coarse (per work
/// item or per tick stage, never per element), so the lock is touched
/// a few hundred times per serving tick, which is noise next to the
/// attention math. Returned buffers keep their capacity, so after one
/// warm tick every lease is satisfied without touching the allocator;
/// [`ScratchPool::stats`] exposes the take/fresh-allocation counters
/// the arena tests assert on.
#[derive(Default)]
pub struct ScratchPool {
    f32s: Mutex<PoolInner>,
    takes: AtomicUsize,
    fresh: AtomicUsize,
    zeroed: AtomicUsize,
    peak_bytes: AtomicUsize,
}

/// Point-in-time arena counters, published into the telemetry registry
/// and `ServingReport` so a steady-state-allocates-nothing regression
/// (the PR 5 invariant) is visible instead of silent.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Total buffer leases.
    pub leases: usize,
    /// Leases that had to touch the allocator (empty pool or growth).
    pub fresh: usize,
    /// Leases that paid a zero-fill (`take_f32` as opposed to
    /// `take_f32_any`).
    pub zeroed: usize,
    /// f32 bytes currently retained on the free list.
    pub held_bytes: usize,
    /// High-water mark of retained bytes.
    pub peak_bytes: usize,
}

#[derive(Default)]
struct PoolInner {
    bufs: Vec<Vec<f32>>,
    /// Σ capacity over `bufs`, in f32 elements — the retention bound
    bytes_held: usize,
}

/// Buffer-count and byte retention bounds: returns beyond either are
/// dropped instead of pooled. Growth bounds, not correctness knobs —
/// the byte cap keeps one giant monolithic-prefill staging lease from
/// ratcheting the process high-water mark forever. (The free list is
/// deliberately size-agnostic LIFO: the serving tick leases in a
/// stable rhythm, so capacities converge; a pathological mix of sizes
/// degrades to allocator calls, never to incorrectness.)
const MAX_POOLED: usize = 1024;
const MAX_POOLED_F32S: usize = 16 << 20; // 64 MB

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    fn lease(&self, len: usize, zero: bool) -> Vec<f32> {
        self.takes.fetch_add(1, Ordering::Relaxed);
        if zero {
            self.zeroed.fetch_add(1, Ordering::Relaxed);
        }
        let mut v = {
            let mut pool = self.f32s.lock().unwrap();
            match pool.bufs.pop() {
                Some(v) => {
                    pool.bytes_held -= v.capacity();
                    v
                }
                None => Vec::new(),
            }
        };
        if v.capacity() < len {
            // this lease touches the allocator — empty pool OR a
            // recycled buffer too small for the request (resize must
            // grow it). Counting both keeps `stats()` an honest
            // observer of the zero-allocation contract.
            self.fresh.fetch_add(1, Ordering::Relaxed);
        }
        if zero {
            v.clear();
        }
        v.resize(len, 0.0);
        v
    }

    /// Lease a zero-filled f32 buffer of length `len`.
    pub fn take_f32(&self, len: usize) -> Vec<f32> {
        self.lease(len, true)
    }

    /// Lease a buffer of length `len` with **unspecified contents**
    /// (recycled scratch values, zeros only where the buffer had to
    /// grow) — for consumers that overwrite every element before
    /// reading (GEMM outputs, copy/assign targets). Skips the
    /// zero-fill [`ScratchPool::take_f32`] pays, which would be
    /// redundant work on the hot path; still entirely safe — recycled
    /// buffers only ever hold earlier scratch f32s.
    pub fn take_f32_any(&self, len: usize) -> Vec<f32> {
        self.lease(len, false)
    }

    /// Return an f32 buffer for reuse (its contents are discarded).
    pub fn put_f32(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let mut pool = self.f32s.lock().unwrap();
        if pool.bufs.len() < MAX_POOLED
            && pool.bytes_held + v.capacity() <= MAX_POOLED_F32S
        {
            pool.bytes_held += v.capacity();
            pool.bufs.push(v);
            let held = pool.bytes_held * std::mem::size_of::<f32>();
            self.peak_bytes.fetch_max(held, Ordering::Relaxed);
        }
    }

    /// (total takes, takes that had to allocate a fresh buffer).
    pub fn stats(&self) -> (usize, usize) {
        (
            self.takes.load(Ordering::Relaxed),
            self.fresh.load(Ordering::Relaxed),
        )
    }

    /// Full arena counters (supersedes [`ScratchPool::stats`], which is
    /// kept for the original zero-allocation assertions).
    pub fn arena_stats(&self) -> ArenaStats {
        let held_bytes = {
            let pool = self.f32s.lock().unwrap();
            pool.bytes_held * std::mem::size_of::<f32>()
        };
        ArenaStats {
            leases: self.takes.load(Ordering::Relaxed),
            fresh: self.fresh.load(Ordering::Relaxed),
            zeroed: self.zeroed.load(Ordering::Relaxed),
            held_bytes,
            peak_bytes: self.peak_bytes.load(Ordering::Relaxed).max(held_bytes),
        }
    }
}

/// The process-wide scratch pool (owned by the [`global`] ThreadPool).
pub fn scratch() -> &'static ScratchPool {
    &global().scratch
}

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    inflight: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// tick-scoped scratch arenas — see [`ScratchPool`]
    pub scratch: ScratchPool,
}

impl ThreadPool {
    /// Spawn a pool with `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            inflight: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("lookat-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers, scratch: ScratchPool::new() }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit_boxed(Box::new(f));
    }

    fn submit_boxed(&self, job: Job) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(job);
        }
        self.shared.cv.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_mx.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done_cv.wait(guard).unwrap();
        }
    }

    /// Number of queued-or-running jobs.
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    /// Pop one queued job and run it on the calling thread. Returns
    /// false when the queue is empty. This is the work-helping hook
    /// [`ThreadPool::run_scoped`] uses while it blocks, so a fan-out
    /// issued *from inside* a pool job can never deadlock the fixed
    /// worker set.
    fn try_run_one(&self) -> bool {
        let job = { self.shared.queue.lock().unwrap().pop_front() };
        match job {
            Some(job) => {
                job();
                if self.shared.inflight.fetch_sub(1, Ordering::SeqCst)
                    == 1
                {
                    let _g = self.shared.done_mx.lock().unwrap();
                    self.shared.done_cv.notify_all();
                }
                true
            }
            None => false,
        }
    }

    /// Run `f(t)` for t in 0..tasks on the pool's persistent workers,
    /// blocking until every task has finished. Unlike [`submit`],
    /// `f` may borrow caller state (no `'static` bound): the closure
    /// reference is lifetime-erased for the queue, which is sound
    /// because this call does not return — and so the borrow cannot
    /// dangle — until the last task completes. The calling thread helps
    /// drain the queue while it waits.
    ///
    /// [`submit`]: ThreadPool::submit
    pub fn run_scoped<'env, F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync + 'env,
    {
        if tasks == 0 {
            return;
        }
        struct Latch {
            left: Mutex<usize>,
            cv: Condvar,
            /// first panic payload from a task, repropagated on the
            /// calling thread so assertion messages stay attributed
            panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
        }
        let latch = Arc::new(Latch {
            left: Mutex::new(tasks),
            cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the erased reference is only reachable from the
        // `tasks` jobs enqueued below, and this function blocks until
        // the latch counts every one of them as finished — `f` and
        // everything it borrows strictly outlive all uses.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(f_ref)
        };
        for t in 0..tasks {
            let latch = latch.clone();
            self.submit(move || {
                // a panicking task must still count down (and keep its
                // worker alive) or the caller would block forever; the
                // payload is repropagated on the calling thread below
                if let Err(payload) = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| f_static(t)),
                ) {
                    let mut slot = latch.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
                let mut left = latch.left.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    // notify while holding the lock: the waiter below
                    // checks the count under the same lock, so the
                    // wakeup cannot be lost
                    latch.cv.notify_all();
                }
            });
        }
        loop {
            // opportunistically run queued jobs (ours or another
            // scope's) instead of parking
            let done = loop {
                if *latch.left.lock().unwrap() == 0 {
                    break true;
                }
                if !self.try_run_one() {
                    break false;
                }
            };
            if done {
                break;
            }
            let left = latch.left.lock().unwrap();
            if *left == 0 {
                break;
            }
            // queue drained but tasks still running on workers — sleep
            // until a completion notifies
            drop(latch.cv.wait(left).unwrap());
        }
        if let Some(payload) = latch.panic.lock().unwrap().take() {
            // same behavior as std::thread::scope: the child's payload
            // (e.g. an assert message) reaches the caller intact
            std::panic::resume_unwind(payload);
        }
    }

    /// Submit ONE borrow-capable task and return a join handle — the
    /// asynchronous sibling of [`ThreadPool::run_scoped`], built for
    /// stage overlap: the caller keeps computing on its own thread
    /// while the task runs, then joins. This is the primitive behind
    /// the engine's software-pipelined layer executor (layer `l`
    /// attention inline on the caller — which is what keeps the
    /// non-`Send` PJRT kernels legal — overlapped with layer `l+1` QKV
    /// on a worker).
    ///
    /// Soundness mirrors `run_scoped`: the closure may borrow caller
    /// state because [`ScopedJoin`] cannot outlive `'env`, and both
    /// [`ScopedJoin::join`] and its `Drop` block until the task has
    /// finished — the borrow can never dangle. While blocked, the
    /// caller helps drain the queue, so a fan-out issued from inside a
    /// pool job cannot deadlock the fixed worker set. A panicking task
    /// parks its payload in the handle and rethrows on join.
    fn submit_scoped<'env, R, F>(&'env self, f: F) -> ScopedJoin<'env, R>
    where
        R: Send + 'env,
        F: FnOnce() -> R + Send + 'env,
    {
        let slot: Arc<TaskSlot<R>> = Arc::new(TaskSlot {
            done: Mutex::new(None),
            cv: Condvar::new(),
        });
        let slot2 = slot.clone();
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            let r = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(f),
            );
            // assign under the lock, notify while holding it: the
            // joiner re-checks under the same lock, so no lost wakeup
            let mut g = slot2.done.lock().unwrap();
            *g = Some(r);
            slot2.cv.notify_all();
        });
        // SAFETY: the job is only reachable from the queue until it
        // runs, and ScopedJoin (tied to 'env) blocks in join() AND in
        // Drop until the job has completed — everything `f` borrows
        // strictly outlives every use.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(
                job,
            )
        };
        self.submit_boxed(job);
        ScopedJoin { slot, pool: self, joined: false }
    }

    /// Overlap two stages: run `bg` on a pool worker while `fg` runs
    /// inline on the calling thread, then return both results —
    /// joining `bg` *before* returning even if `fg` panics, which is
    /// what makes the borrow-capable background closure sound without
    /// exposing a forgettable join handle. `fg` needs no `Send` (it
    /// never leaves the caller), so stages that own non-`Send` state —
    /// the PJRT attention kernels — always go in the foreground. This
    /// is the engine's software-pipelining primitive.
    pub fn overlap<'env, RF, RB>(
        &'env self,
        bg: impl FnOnce() -> RB + Send + 'env,
        fg: impl FnOnce() -> RF,
    ) -> (RF, RB)
    where
        RB: Send + 'env,
    {
        let task = self.submit_scoped(bg);
        let f = fg();
        (f, task.join())
    }
}

struct TaskSlot<R> {
    done: Mutex<Option<std::thread::Result<R>>>,
    cv: Condvar,
}

/// Join handle of one `submit_scoped` task. Module-private on
/// purpose: it must not be `mem::forget`-ten while the task borrows
/// caller state (dropping blocks until the task completes — a
/// forgotten handle would let the lifetime-erased borrow dangle), so
/// the only exposed surface is the always-joining
/// [`ThreadPool::overlap`].
struct ScopedJoin<'env, R> {
    slot: Arc<TaskSlot<R>>,
    pool: &'env ThreadPool,
    joined: bool,
}

impl<R> ScopedJoin<'_, R> {
    /// Block until the task finishes and return its result,
    /// repropagating a task panic on the calling thread. The caller
    /// work-helps on the pool's queue while it waits.
    fn join(mut self) -> R {
        let r = self.wait_result();
        self.joined = true;
        match r {
            Ok(v) => v,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }

    fn wait_result(&self) -> std::thread::Result<R> {
        loop {
            if let Some(r) = self.slot.done.lock().unwrap().take() {
                return r;
            }
            if !self.pool.try_run_one() {
                // queue drained but our task still runs on a worker —
                // sleep until its completion notifies
                let g = self.slot.done.lock().unwrap();
                if g.is_some() {
                    continue;
                }
                let mut g = self.slot.cv.wait(g).unwrap();
                if let Some(r) = g.take() {
                    return r;
                }
            }
        }
    }
}

impl<R> Drop for ScopedJoin<'_, R> {
    fn drop(&mut self) {
        if !self.joined {
            // an unjoined handle (early return, unwind) must still
            // block out the borrow; the task's own panic, if any, is
            // swallowed here — the caller is already unwinding or has
            // chosen not to look
            let _ = self.wait_result();
        }
    }
}

/// The process-wide pool behind [`parallel_map`]/[`parallel_try_map`]:
/// one persistent worker per available core, spawned on first use.
/// Worker threads — and their `thread_local!` scratch — live for the
/// whole process, so per-tick fan-outs reuse warm allocations.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(ThreadPool::with_default_size)
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if *sh.shutdown.lock().unwrap() {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        job();
        if sh.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.done_mx.lock().unwrap();
            sh.done_cv.notify_all();
        }
    }
}

/// Run `f(i)` for i in 0..n, chunked across up to `threads` tasks on
/// the persistent [`global`] pool, writing results into the returned
/// Vec. `f` only needs to be Sync (no 'static bound) — the pool's
/// scoped fan-out blocks until every chunk lands.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out = vec![T::default(); n];
    if n == 0 {
        return out;
    }
    if threads == 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        return out;
    }
    let chunk = n.div_ceil(threads);
    let chunks: Vec<Mutex<&mut [T]>> =
        out.chunks_mut(chunk).map(Mutex::new).collect();
    global().run_scoped(chunks.len(), |t| {
        // each chunk's mutex is locked exactly once, by its own task —
        // it only exists to hand the &mut slice across the Fn boundary
        let slice = &mut *chunks[t].lock().unwrap();
        for (j, slot) in slice.iter_mut().enumerate() {
            *slot = f(t * chunk + j);
        }
    });
    drop(chunks);
    out
}

/// Fallible [`parallel_map`]: run `f(i)` for i in 0..n across up to
/// `threads` tasks on the persistent [`global`] pool and collect the
/// results, or return the lowest-index error. Unlike [`parallel_map`]
/// there is no `Default + Clone` bound, so it also suits result types
/// that carry owned buffers (the batched-decode kernels'
/// `AttnOutput`s). Per-index results are independent, so routing
/// through the pool changes nothing observable — the decode pipeline's
/// batched-equals-serial bit-parity holds by construction.
pub fn parallel_try_map<T, E, F>(
    n: usize,
    threads: usize,
    f: F,
) -> Result<Vec<T>, E>
where
    T: Send,
    E: Send,
    F: Fn(usize) -> Result<T, E> + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(f(i)?);
        }
        return Ok(out);
    }
    let mut slots: Vec<Option<Result<T, E>>> =
        (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    let chunks: Vec<Mutex<&mut [Option<Result<T, E>>]>> =
        slots.chunks_mut(chunk).map(Mutex::new).collect();
    global().run_scoped(chunks.len(), |t| {
        let slice = &mut *chunks[t].lock().unwrap();
        for (j, slot) in slice.iter_mut().enumerate() {
            *slot = Some(f(t * chunk + j));
        }
    });
    drop(chunks);
    let mut out = Vec::with_capacity(n);
    for r in slots {
        out.push(r.expect("parallel_try_map: unfilled slot")?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not deadlock
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn jobs_can_be_submitted_after_wait() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang
        // jobs may or may not all have run before shutdown flag is seen,
        // but the queued ones before drop had inflight ticks; just ensure
        // no deadlock occurred to get here.
    }

    #[test]
    fn run_scoped_borrows_caller_state() {
        // the whole point of the scoped API: f borrows non-'static data
        let data: Vec<u64> = (0..256).collect();
        let sum = AtomicU64::new(0);
        let pool = ThreadPool::new(4);
        pool.run_scoped(8, |t| {
            let part: u64 =
                data[t * 32..(t + 1) * 32].iter().sum();
            sum.fetch_add(part, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), data.iter().sum::<u64>());
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn run_scoped_zero_tasks_returns() {
        let pool = ThreadPool::new(2);
        pool.run_scoped(0, |_| panic!("must not run"));
    }

    #[test]
    fn nested_run_scoped_does_not_deadlock() {
        // a fan-out issued from inside a pool job must complete even
        // when it outnumbers the workers (caller work-helping)
        let pool = ThreadPool::new(2);
        let counter = AtomicU64::new(0);
        pool.run_scoped(4, |_| {
            pool.run_scoped(4, |_| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn run_scoped_on_single_worker_pool_completes() {
        let pool = ThreadPool::new(1);
        let counter = AtomicU64::new(0);
        pool.run_scoped(32, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn run_scoped_surfaces_task_panics_without_hanging() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run_scoped(4, |t| {
                    if t == 2 {
                        panic!("boom");
                    }
                });
            }),
        );
        assert!(caught.is_err(), "panic must propagate to the caller");
        // pool still works afterwards
        let counter = AtomicU64::new(0);
        pool.run_scoped(4, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        assert!(global().size() >= 1);
        assert!(std::ptr::eq(global(), global()));
    }

    #[test]
    fn parallel_map_matches_serial() {
        let got = parallel_map(1000, 8, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn parallel_map_single_thread_path() {
        let got = parallel_map(64, 1, |i| i as f64 * 0.5);
        assert_eq!(got[63], 31.5);
    }

    #[test]
    fn parallel_try_map_ok_matches_serial() {
        let par = parallel_try_map(500, 8, |i| Ok::<_, String>(i * 3));
        let ser = parallel_try_map(500, 1, |i| Ok::<_, String>(i * 3));
        let want: Vec<usize> = (0..500).map(|i| i * 3).collect();
        assert_eq!(par.unwrap(), want);
        assert_eq!(ser.unwrap(), want);
    }

    #[test]
    fn parallel_try_map_reports_lowest_index_error() {
        let got = parallel_try_map(100, 4, |i| {
            if i == 17 || i == 63 {
                Err(format!("boom {i}"))
            } else {
                Ok(i)
            }
        });
        assert_eq!(got.unwrap_err(), "boom 17");
    }

    #[test]
    fn parallel_try_map_empty() {
        let got: Result<Vec<usize>, String> =
            parallel_try_map(0, 4, |i| Ok(i));
        assert_eq!(got.unwrap(), Vec::<usize>::new());
    }

    #[test]
    fn scratch_pool_zero_fills_and_recycles() {
        let pool = ScratchPool::new();
        let mut a = pool.take_f32(8);
        assert_eq!(a, vec![0.0f32; 8]);
        a.iter_mut().for_each(|v| *v = 9.0);
        let cap = a.capacity();
        pool.put_f32(a);
        // the recycled lease reuses capacity and is zeroed again
        let b = pool.take_f32(4);
        assert_eq!(b, vec![0.0f32; 4]);
        assert!(b.capacity() >= cap.min(4));
        let (takes, fresh) = pool.stats();
        assert_eq!(takes, 2);
        assert_eq!(fresh, 1, "second take must reuse, not allocate");
    }

    #[test]
    fn take_f32_any_skips_the_zero_fill_but_sizes_correctly() {
        let pool = ScratchPool::new();
        let mut a = pool.take_f32(6);
        a.iter_mut().for_each(|v| *v = 5.0);
        pool.put_f32(a);
        // shrinking lease: old contents may show through (that is the
        // point — the consumer overwrites every element)
        let b = pool.take_f32_any(4);
        assert_eq!(b.len(), 4);
        pool.put_f32(b);
        // growing lease: the new tail is zeroed, len is exact — and
        // because the recycled buffer's capacity is far too small
        // (1000 exceeds any amortized over-allocation of a 6-element
        // vec), the growth is booked as a fresh allocation
        let c = pool.take_f32_any(1000);
        assert_eq!(c.len(), 1000);
        assert!(c[6..].iter().all(|&x| x == 0.0));
        let (takes, fresh) = pool.stats();
        assert_eq!((takes, fresh), (3, 2));
    }

    #[test]
    fn scratch_pool_steady_state_allocates_nothing() {
        // lease/return cycles after warm-up never hit the allocator —
        // the arena contract the decode tick relies on
        let pool = ScratchPool::new();
        for _ in 0..3 {
            let v = pool.take_f32(64);
            pool.put_f32(v);
        }
        let (_, fresh_before) = pool.stats();
        for _ in 0..100 {
            let v = pool.take_f32(64);
            pool.put_f32(v);
        }
        let (_, fresh_after) = pool.stats();
        assert_eq!(fresh_before, fresh_after, "steady state allocated");
    }

    #[test]
    fn arena_stats_track_leases_zeroing_and_peak() {
        let pool = ScratchPool::new();
        let a = pool.take_f32(256); // zeroed lease
        let b = pool.take_f32_any(64); // raw lease
        let cap_bytes = a.capacity() * 4 + b.capacity() * 4;
        pool.put_f32(a);
        pool.put_f32(b);
        let s = pool.arena_stats();
        assert_eq!(s.leases, 2);
        assert_eq!(s.fresh, 2);
        assert_eq!(s.zeroed, 1, "only take_f32 pays a zero-fill");
        assert_eq!(s.held_bytes, cap_bytes);
        assert_eq!(s.peak_bytes, cap_bytes);
        // Draining the pool drops held bytes but the peak sticks.
        let c = pool.take_f32_any(64);
        let d = pool.take_f32_any(256);
        let s2 = pool.arena_stats();
        assert_eq!(s2.held_bytes, 0);
        assert_eq!(s2.peak_bytes, cap_bytes);
        assert_eq!(s2.leases, 4);
        assert_eq!(s2.fresh, 2, "warm leases must not allocate");
        drop((c, d));
        // stats() stays consistent with the richer view
        assert_eq!(pool.stats(), (s2.leases, s2.fresh));
    }

    #[test]
    fn global_pool_owns_a_scratch_pool() {
        let v = scratch().take_f32(16);
        assert_eq!(v.len(), 16);
        scratch().put_f32(v);
    }

    #[test]
    fn submit_scoped_runs_borrowing_task_and_joins() {
        let pool = ThreadPool::new(2);
        let data: Vec<u64> = (0..100).collect();
        let task = pool.submit_scoped(|| data.iter().sum::<u64>());
        // caller keeps working while the task runs
        let local: u64 = data.iter().map(|x| x * 2).sum();
        let remote = task.join();
        assert_eq!(remote, 4950);
        assert_eq!(local, 9900);
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn submit_scoped_overlaps_with_inline_fanout() {
        // the pipelined-executor shape: one scoped task in flight while
        // the caller runs its own run_scoped fan-out on the same pool
        let pool = ThreadPool::new(2);
        let side = AtomicU64::new(0);
        let task = pool.submit_scoped(|| {
            side.fetch_add(7, Ordering::SeqCst);
            7u64
        });
        let counter = AtomicU64::new(0);
        pool.run_scoped(8, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(task.join(), 7);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
        assert_eq!(side.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn submit_scoped_propagates_panics_on_join() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let task = pool.submit_scoped(|| {
                    panic!("scoped boom");
                });
                task.join()
            }),
        );
        assert!(caught.is_err(), "panic must reach the joiner");
        // pool still serviceable afterwards
        let t = pool.submit_scoped(|| 41 + 1);
        assert_eq!(t.join(), 42);
    }

    #[test]
    fn overlap_runs_both_sides_and_orders_results() {
        let pool = ThreadPool::new(2);
        let data: Vec<u64> = (0..64).collect();
        let (fg, bg) = pool.overlap(
            || data.iter().sum::<u64>(),
            || "foreground",
        );
        assert_eq!(fg, "foreground");
        assert_eq!(bg, 2016);
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn overlap_joins_background_even_when_foreground_panics() {
        let pool = ThreadPool::new(2);
        let flag = AtomicU64::new(0);
        let caught = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.overlap(
                    || {
                        std::thread::sleep(
                            std::time::Duration::from_millis(10),
                        );
                        flag.fetch_add(1, Ordering::SeqCst);
                    },
                    || {
                        panic!("fg boom");
                    },
                )
            }),
        );
        assert!(caught.is_err());
        // the background task completed before overlap unwound
        assert_eq!(flag.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn unjoined_scoped_handle_blocks_on_drop() {
        let pool = ThreadPool::new(1);
        let flag = AtomicU64::new(0);
        {
            let _task = pool.submit_scoped(|| {
                std::thread::sleep(
                    std::time::Duration::from_millis(20),
                );
                flag.fetch_add(1, Ordering::SeqCst);
            });
            // dropped unjoined: must block until the task completed
        }
        assert_eq!(flag.load(Ordering::SeqCst), 1);
    }
}
