//! Minimal JSON value model, parser and serializer (serde is not vendored
//! in the offline image — see DESIGN.md).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP are passed through unvalidated. Good enough for the artifact
//! manifest, experiment reports and config files this repo produces.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Borrow the key→value map of an object (None for non-objects).
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Insert into an object. On a non-object receiver this is a no-op
    /// with a logged warning — report-building code paths chain many
    /// `set` calls and must not take the process down over one bad value
    /// (previously this panicked; see the regression test).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            crate::log_warn!(
                "Json::set('{key}') ignored: receiver is {} not an object",
                self.type_name()
            );
        }
        self
    }

    /// Fallible insert for callers that want to handle the mismatch.
    pub fn try_set(&mut self, key: &str, val: Json)
        -> Result<&mut Json, JsonError>
    {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
                Ok(self)
            }
            other => Err(JsonError {
                pos: 0,
                msg: format!(
                    "set('{key}') on {} (expected object)",
                    other.type_name()
                ),
            }),
        }
    }

    /// Variant name, for diagnostics.
    pub fn type_name(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // -- serialization -----------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no inf/nan; emit null like most encoders
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind + 1));
                        item.write(out, Some(ind + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    if !v.is_empty() {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind));
                    }
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        val.write(out, Some(ind + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        val.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    if !m.is_empty() {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind));
                    }
                }
                out.push('}');
            }
        }
    }

    // -- parsing -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// JSON error: a parse failure with byte offset, or a value-model
/// misuse from [`Json::try_set`] (reported with `pos` 0).
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{FFFD}'),
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = &self.b[start..];
                    let len = utf8_len(rest[0]);
                    if rest.len() < len {
                        return Err(self.err("bad utf-8"));
                    }
                    let chunk = std::str::from_utf8(&rest[..len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": "c"}], "d": null, "e": true}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
        assert_eq!(v.get("e").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let v = Json::parse(r#""A\t\"ü""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\"ü"));
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"k":false}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::from_pairs(vec![
            ("name", Json::Str("lookat".into())),
            ("vals", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn set_and_get() {
        let mut o = Json::obj();
        o.set("x", Json::Num(7.0));
        assert_eq!(o.get("x").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn set_on_non_object_is_noop_not_panic() {
        // regression: this used to panic!("Json::set on non-object")
        let mut n = Json::Num(1.0);
        n.set("x", Json::Num(2.0));
        assert_eq!(n, Json::Num(1.0), "value must be unchanged");
        let mut a = Json::Arr(vec![]);
        a.set("k", Json::Null).set("k2", Json::Null); // chaining still ok
        assert_eq!(a, Json::Arr(vec![]));
    }

    #[test]
    fn try_set_reports_type_mismatch() {
        let mut o = Json::obj();
        assert!(o.try_set("x", Json::Num(7.0)).is_ok());
        assert_eq!(o.get("x").unwrap().as_usize(), Some(7));
        let mut s = Json::Str("nope".into());
        let err = s.try_set("x", Json::Null).unwrap_err();
        assert!(err.msg.contains("string"), "{}", err.msg);
    }

    #[test]
    fn deterministic_key_order() {
        let v =
            Json::parse(r#"{"zebra": 1, "apple": 2, "mango": 3}"#).unwrap();
        let s = v.to_string();
        let a = s.find("apple").unwrap();
        let m = s.find("mango").unwrap();
        let z = s.find("zebra").unwrap();
        assert!(a < m && m < z);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version": 1, "artifacts": [
            {"name": "attn_fp16_L128", "file": "attn_fp16_L128.hlo.txt",
             "inputs": [{"name": "q", "shape": [12, 64],
                         "dtype": "float32"}],
             "outputs": [{"name": "out", "shape": [12, 64],
                          "dtype": "float32"}],
             "meta": {"kind": "attn_fp16", "L": 128}}]}"#;
        let v = Json::parse(src).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(
            arts[0].get("meta").unwrap().get("L").unwrap().as_usize(),
            Some(128)
        );
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}
