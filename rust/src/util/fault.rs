//! Deterministic, seeded fault injection for chaos testing.
//!
//! A [`FaultPlan`] is parsed from a compact spec (CLI `--faults` or the
//! `LOOKAT_FAULTS` environment variable) and threaded into the serving
//! stack, which consults it at a fixed set of hook points — block
//! allocation, swap out/in, prefix attach, and tick boundaries. Every
//! trigger is deterministic: probabilistic clauses draw from a seeded
//! [`Pcg32`] stream and nth-call clauses count per-site invocations, so
//! a chaos run replays bit-for-bit under the same spec.
//!
//! Spec grammar (comma-separated clauses):
//!
//! ```text
//! seed:42                  seed for probabilistic draws (default 0)
//! alloc:0.05               fail 5% of block-allocation checks
//! swap_in:err@3            fail exactly the 3rd swap-in
//! swap_out:err@1           fail exactly the 1st swap-out
//! prefix:err@2             fail exactly the 2nd prefix attach
//! tick:panic@7             panic at the start of the 7th tick
//! tick:err@4               fail the 4th tick with an error
//! tick_delay:20ms          sleep 20 ms at every tick boundary
//! tick_delay:5ms@3         sleep 5 ms at the 3rd tick only
//! ```
//!
//! An empty/absent spec parses to the disabled plan, whose
//! [`FaultPlan::check`] is a branch on an empty `Vec` — free on the
//! serving fast path.

use std::time::Duration;

use anyhow::{bail, Context};

use super::rng::Pcg32;

/// Hook points the serving stack consults the plan at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// block-demand check in the engine tick (simulates allocator
    /// exhaustion; surfaces as `CacheError::OutOfBlocks`)
    Alloc,
    /// engine-level swap-out of a preemption victim
    SwapOut,
    /// engine-level swap-in of a parked sequence
    SwapIn,
    /// prefix-cache block attach at admission
    PrefixAttach,
    /// batcher tick boundary (before any engine state is touched)
    Tick,
}

impl FaultSite {
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::Alloc => "alloc",
            FaultSite::SwapOut => "swap_out",
            FaultSite::SwapIn => "swap_in",
            FaultSite::PrefixAttach => "prefix",
            FaultSite::Tick => "tick",
        }
    }

    fn idx(self) -> usize {
        match self {
            FaultSite::Alloc => 0,
            FaultSite::SwapOut => 1,
            FaultSite::SwapIn => 2,
            FaultSite::PrefixAttach => 3,
            FaultSite::Tick => 4,
        }
    }
}

/// What an armed clause does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// fail the hooked operation with an injected error
    Err,
    /// panic at the hook (exercises the serving loop's isolation)
    Panic,
    /// stall the hooked operation (models a slow tier / noisy core)
    Delay(Duration),
}

/// When a clause fires.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Trigger {
    /// every call, independently, with this probability (seeded draw)
    Prob(f64),
    /// exactly the nth call to this site (1-indexed)
    Nth(u64),
    /// every call
    Every,
}

#[derive(Clone, Debug)]
struct Clause {
    site: FaultSite,
    trigger: Trigger,
    action: FaultAction,
}

/// A parsed, seeded fault schedule. `Default` is the disabled plan.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    clauses: Vec<Clause>,
    rng: Option<Pcg32>,
    /// per-site call counters (indexed by [`FaultSite::idx`])
    calls: [u64; 5],
    spec: String,
}

impl FaultPlan {
    /// Parse a spec string. Empty (after trimming) means disabled.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(FaultPlan::default());
        }
        let mut seed = 0u64;
        let mut clauses = Vec::new();
        for raw in spec.split(',') {
            let clause = raw.trim();
            if clause.is_empty() {
                continue;
            }
            let (key, val) = clause.split_once(':').with_context(|| {
                format!("fault clause '{clause}' is not 'site:spec'")
            })?;
            let (key, val) = (key.trim(), val.trim());
            if key == "seed" {
                seed = val.parse().with_context(|| {
                    format!("fault seed '{val}' is not a u64")
                })?;
                continue;
            }
            if key == "tick_delay" {
                let (dur, trigger) = parse_delay(val)?;
                clauses.push(Clause {
                    site: FaultSite::Tick,
                    trigger,
                    action: FaultAction::Delay(dur),
                });
                continue;
            }
            let site = match key {
                "alloc" => FaultSite::Alloc,
                "swap_out" => FaultSite::SwapOut,
                "swap_in" => FaultSite::SwapIn,
                "prefix" => FaultSite::PrefixAttach,
                "tick" => FaultSite::Tick,
                other => bail!(
                    "unknown fault site '{other}' (expected alloc, \
                     swap_out, swap_in, prefix, tick, tick_delay, seed)"
                ),
            };
            clauses.push(parse_action(site, val)?);
        }
        let need_rng = clauses
            .iter()
            .any(|c| matches!(c.trigger, Trigger::Prob(_)));
        Ok(FaultPlan {
            clauses,
            rng: need_rng.then(|| Pcg32::seed(seed)),
            calls: [0; 5],
            spec: spec.to_string(),
        })
    }

    /// Resolve from an explicit CLI spec, falling back to the
    /// `LOOKAT_FAULTS` environment variable, else the disabled plan.
    pub fn resolve(cli: Option<&str>) -> anyhow::Result<FaultPlan> {
        match cli {
            Some(s) => FaultPlan::parse(s)
                .context("invalid --faults spec"),
            None => match std::env::var("LOOKAT_FAULTS") {
                Ok(s) => FaultPlan::parse(&s)
                    .context("invalid LOOKAT_FAULTS spec"),
                Err(_) => Ok(FaultPlan::default()),
            },
        }
    }

    /// Whether any clause is armed.
    pub fn is_active(&self) -> bool {
        !self.clauses.is_empty()
    }

    /// The original spec (empty for the disabled plan).
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// Consult the plan at a hook point. Counts the call and returns
    /// the first firing clause's action, if any. The disabled plan
    /// returns `None` after a single branch.
    #[inline]
    pub fn check(&mut self, site: FaultSite) -> Option<FaultAction> {
        if self.clauses.is_empty() {
            return None;
        }
        self.check_slow(site)
    }

    fn check_slow(&mut self, site: FaultSite) -> Option<FaultAction> {
        self.calls[site.idx()] += 1;
        let n = self.calls[site.idx()];
        for i in 0..self.clauses.len() {
            if self.clauses[i].site != site {
                continue;
            }
            let fires = match self.clauses[i].trigger {
                Trigger::Every => true,
                Trigger::Nth(k) => n == k,
                Trigger::Prob(p) => {
                    // one draw per armed probabilistic clause per call,
                    // so the stream is independent of whether earlier
                    // clauses fired
                    self.rng.as_mut().unwrap().next_f64() < p
                }
            };
            if fires {
                return Some(self.clauses[i].action);
            }
        }
        None
    }
}

/// Action grammar for err/panic sites: `err@N`, `panic@N`, `err`,
/// `panic`, or a bare probability like `0.05` (implies `Err`).
fn parse_action(site: FaultSite, val: &str) -> anyhow::Result<Clause> {
    let (word, trigger) = match val.split_once('@') {
        Some((w, n)) => {
            let n: u64 = n.trim().parse().with_context(|| {
                format!("fault count '@{n}' is not a u64")
            })?;
            if n == 0 {
                bail!("fault counts are 1-indexed; '@0' never fires");
            }
            (w.trim(), Trigger::Nth(n))
        }
        None => (val, Trigger::Every),
    };
    if let Ok(p) = word.parse::<f64>() {
        if !(0.0..=1.0).contains(&p) {
            bail!("fault probability {p} is outside [0, 1]");
        }
        if !matches!(trigger, Trigger::Every) {
            bail!("a probability clause cannot take '@N'");
        }
        return Ok(Clause {
            site,
            trigger: Trigger::Prob(p),
            action: FaultAction::Err,
        });
    }
    let action = match word {
        "err" => FaultAction::Err,
        "panic" => FaultAction::Panic,
        other => bail!(
            "unknown fault action '{other}' for site '{}' (expected \
             err, panic, or a probability)",
            site.name()
        ),
    };
    Ok(Clause { site, trigger, action })
}

/// Delay grammar: `20ms` or `20ms@N` (ms suffix optional).
fn parse_delay(val: &str) -> anyhow::Result<(Duration, Trigger)> {
    let (dur, trigger) = match val.split_once('@') {
        Some((d, n)) => {
            let n: u64 = n.trim().parse().with_context(|| {
                format!("fault count '@{n}' is not a u64")
            })?;
            if n == 0 {
                bail!("fault counts are 1-indexed; '@0' never fires");
            }
            (d.trim(), Trigger::Nth(n))
        }
        None => (val, Trigger::Every),
    };
    let ms: u64 = dur
        .strip_suffix("ms")
        .unwrap_or(dur)
        .trim()
        .parse()
        .with_context(|| {
            format!("tick_delay '{dur}' is not '<N>ms'")
        })?;
    Ok((Duration::from_millis(ms), trigger))
}

/// FNV-1a over a byte slab — the integrity checksum used on swapped
/// sequences and prefix-cache blocks (same constants as the prefix
/// chain hash, so one self-consistent hash family repo-wide).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_extend(0xcbf29ce484222325, bytes)
}

/// Continue an FNV-1a stream — chain multi-slab checksums without
/// concatenating the slabs.
pub fn fnv1a_extend(state: u64, bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h = state;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_is_disabled() {
        let mut p = FaultPlan::parse("").unwrap();
        assert!(!p.is_active());
        for _ in 0..10 {
            assert_eq!(p.check(FaultSite::Alloc), None);
            assert_eq!(p.check(FaultSite::Tick), None);
        }
    }

    #[test]
    fn nth_clause_fires_exactly_once() {
        let mut p = FaultPlan::parse("swap_in:err@3").unwrap();
        let fired: Vec<bool> = (0..6)
            .map(|_| p.check(FaultSite::SwapIn).is_some())
            .collect();
        assert_eq!(fired, [false, false, true, false, false, false]);
        // other sites never fire
        assert_eq!(p.check(FaultSite::SwapOut), None);
    }

    #[test]
    fn probability_stream_is_seeded_and_reproducible() {
        let run = |spec: &str| -> Vec<bool> {
            let mut p = FaultPlan::parse(spec).unwrap();
            (0..200)
                .map(|_| p.check(FaultSite::Alloc).is_some())
                .collect()
        };
        let a = run("seed:7,alloc:0.3");
        let b = run("seed:7,alloc:0.3");
        assert_eq!(a, b);
        let c = run("seed:8,alloc:0.3");
        assert_ne!(a, c);
        let hits = a.iter().filter(|&&x| x).count();
        assert!(
            (30..=90).contains(&hits),
            "p=0.3 over 200 draws fired {hits} times"
        );
    }

    #[test]
    fn tick_delay_and_panic_grammar() {
        let mut p =
            FaultPlan::parse("tick_delay:20ms,tick:panic@2").unwrap();
        assert_eq!(
            p.check(FaultSite::Tick),
            Some(FaultAction::Delay(Duration::from_millis(20)))
        );
        // the delay clause is listed first, so it wins tick 2 as well;
        // order in the spec is priority order
        assert_eq!(
            p.check(FaultSite::Tick),
            Some(FaultAction::Delay(Duration::from_millis(20)))
        );
        let mut q = FaultPlan::parse("tick:panic@2").unwrap();
        assert_eq!(q.check(FaultSite::Tick), None);
        assert_eq!(q.check(FaultSite::Tick), Some(FaultAction::Panic));
        let mut d = FaultPlan::parse("tick_delay:5ms@3").unwrap();
        assert_eq!(d.check(FaultSite::Tick), None);
        assert_eq!(d.check(FaultSite::Tick), None);
        assert_eq!(
            d.check(FaultSite::Tick),
            Some(FaultAction::Delay(Duration::from_millis(5)))
        );
    }

    #[test]
    fn issue_example_spec_parses() {
        let p = FaultPlan::parse(
            "alloc:0.05,swap_in:err@3,tick_delay:20ms",
        )
        .unwrap();
        assert!(p.is_active());
        assert_eq!(p.spec(), "alloc:0.05,swap_in:err@3,tick_delay:20ms");
    }

    #[test]
    fn bad_specs_fail_with_context() {
        for bad in [
            "alloc",           // no colon
            "bogus:0.5",       // unknown site
            "alloc:1.5",       // probability out of range
            "alloc:0.5@3",     // probability with count
            "swap_in:boom",    // unknown action
            "swap_in:err@0",   // zero count
            "tick_delay:fast", // non-numeric delay
            "seed:banana",     // non-numeric seed
        ] {
            assert!(
                FaultPlan::parse(bad).is_err(),
                "spec '{bad}' should be rejected"
            );
        }
    }

    #[test]
    fn resolve_prefers_cli_over_env() {
        let p = FaultPlan::resolve(Some("alloc:err@1")).unwrap();
        assert!(p.is_active());
        let d = FaultPlan::resolve(Some("")).unwrap();
        assert!(!d.is_active());
    }

    #[test]
    fn fnv1a_matches_known_vectors() {
        // standard FNV-1a 64-bit test vectors
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
        // chaining is equivalent to one pass
        let whole = fnv1a(b"foobar");
        let chained = fnv1a_extend(fnv1a(b"foo"), b"bar");
        assert_eq!(whole, chained);
    }
}
