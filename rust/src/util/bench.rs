//! Benchmark harness (criterion is not vendored offline).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, timed iterations, median + MAD reporting, derived throughput,
//! and a black-box sink to stop the optimizer deleting the benchmarked
//! work. Results can be serialized through [`crate::util::json`].

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// Prevent dead-code elimination of a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// wall-clock per iteration, seconds
    pub median_s: f64,
    pub mad_s: f64,
    pub mean_s: f64,
    pub iters: usize,
    /// optional items-per-iteration for throughput derivation
    pub items_per_iter: Option<f64>,
    /// optional bytes-per-iteration
    pub bytes_per_iter: Option<f64>,
}

impl Measurement {
    pub fn throughput_items_per_s(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.median_s)
    }

    pub fn throughput_gb_per_s(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b / self.median_s / 1e9)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()));
        o.set("median_s", Json::Num(self.median_s));
        o.set("mad_s", Json::Num(self.mad_s));
        o.set("mean_s", Json::Num(self.mean_s));
        o.set("iters", Json::Num(self.iters as f64));
        if let Some(t) = self.throughput_items_per_s() {
            o.set("items_per_s", Json::Num(t));
        }
        if let Some(t) = self.throughput_gb_per_s() {
            o.set("gb_per_s", Json::Num(t));
        }
        o
    }

    /// One human line, criterion-style.
    pub fn pretty(&self) -> String {
        let mut s = format!(
            "{:44} {:>12}  ±{:>10}",
            self.name,
            fmt_duration(self.median_s),
            fmt_duration(self.mad_s)
        );
        if let Some(t) = self.throughput_items_per_s() {
            s.push_str(&format!("  {:>12.3} Melem/s", t / 1e6));
        }
        if let Some(t) = self.throughput_gb_per_s() {
            s.push_str(&format!("  {t:>8.3} GB/s"));
        }
        s
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Bench runner with a time budget per benchmark.
pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fast settings for CI / tests.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(10),
            budget: Duration::from_millis(100),
            min_iters: 3,
            max_iters: 1000,
            ..Default::default()
        }
    }

    /// Time `f`, which must do one unit of benchmarked work per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        self.run_with(name, None, None, &mut f)
    }

    /// Time `f` and derive items/s throughput.
    pub fn run_items<F: FnMut()>(
        &mut self,
        name: &str,
        items: f64,
        mut f: F,
    ) -> &Measurement {
        self.run_with(name, Some(items), None, &mut f)
    }

    /// Time `f` and derive both items/s and GB/s.
    pub fn run_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        items: f64,
        bytes: f64,
        mut f: F,
    ) -> &Measurement {
        self.run_with(name, Some(items), Some(bytes), &mut f)
    }

    fn run_with(
        &mut self,
        name: &str,
        items: Option<f64>,
        bytes: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &Measurement {
        // warmup + per-iteration cost estimate
        let wstart = Instant::now();
        let mut witers = 0usize;
        while wstart.elapsed() < self.warmup || witers == 0 {
            f();
            witers += 1;
            if witers >= self.max_iters {
                break;
            }
        }
        let est = wstart.elapsed().as_secs_f64() / witers as f64;
        let target_iters = ((self.budget.as_secs_f64() / est.max(1e-9))
            as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut times = Vec::with_capacity(target_iters);
        for _ in 0..target_iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            median_s: stats::median(&times),
            mad_s: stats::mad(&times),
            mean_s: times.iter().sum::<f64>() / times.len() as f64,
            iters: target_iters,
            items_per_iter: items,
            bytes_per_iter: bytes,
        };
        println!("{}", m.pretty());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Serialize all results (for artifacts/reports/).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.results.iter().map(|m| m.to_json()).collect())
    }

    /// Write results JSON to `artifacts/reports/<name>.json`.
    pub fn write_report(&self, name: &str) -> std::io::Result<()> {
        let dir = std::path::Path::new("artifacts/reports");
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            dir.join(format!("{name}.json")),
            self.to_json().to_string_pretty(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::quick();
        let mut acc = 0u64;
        let m = b
            .run("noop-ish", || {
                acc = black_box(acc.wrapping_add(1));
            })
            .clone();
        assert!(m.median_s >= 0.0);
        assert!(m.iters >= 3);
    }

    #[test]
    fn throughput_derivation() {
        let m = Measurement {
            name: "x".into(),
            median_s: 0.5,
            mad_s: 0.0,
            mean_s: 0.5,
            iters: 10,
            items_per_iter: Some(1000.0),
            bytes_per_iter: Some(2e9),
        };
        assert_eq!(m.throughput_items_per_s(), Some(2000.0));
        assert_eq!(m.throughput_gb_per_s(), Some(4.0));
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }

    #[test]
    fn json_output_has_fields() {
        let mut b = Bench::quick();
        b.run_items("t", 10.0, || {
            black_box(1 + 1);
        });
        let j = b.to_json();
        let first = j.idx(0).unwrap();
        assert!(first.get("median_s").is_some());
        assert!(first.get("items_per_s").is_some());
    }
}
