//! Bench-artifact regression comparison — the CI perf gate.
//!
//! CI downloads the previous run's bench artifact (`BENCH_serving.json`
//! and `BENCH_adc.json`) and runs `lookat bench-check --old <prev>
//! --new <current>` on each: any throughput metric that regresses by
//! more than the tolerance fails the job, and a result entry that
//! disappears from the sweep fails it too (silent coverage loss reads
//! as a pass otherwise). New entries in the current file are ignored —
//! they have no baseline.
//!
//! The document contract is schema-light: a top-level `results` array
//! of objects, each carrying a `backend` name plus numeric throughput
//! metrics. Which metrics exist is discovered from the *baseline*
//! entry: every numeric key ending in `_tok_s`, `_gb_s` or `_per_s`
//! is compared (higher is better). That makes the same gate cover the
//! serving sweep's `batch_N_tok_s` columns and the ADC micro-bench's
//! scan figures without either knowing about the other.

use crate::util::json::Json;

/// Key suffixes treated as higher-is-better throughput metrics.
const METRIC_SUFFIXES: [&str; 3] = ["_tok_s", "_gb_s", "_per_s"];

/// One tokens/s comparison that exceeded the tolerance (or vanished).
#[derive(Clone, Debug, PartialEq)]
pub struct Regression {
    pub backend: String,
    pub metric: String,
    pub old: f64,
    pub new: f64,
}

impl std::fmt::Display for Regression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.new.is_nan() {
            write!(
                f,
                "{} {}: present in baseline, missing from new sweep",
                self.backend, self.metric
            )
        } else {
            write!(
                f,
                "{} {}: {:.1} -> {:.1} tok/s ({:+.1}%)",
                self.backend,
                self.metric,
                self.old,
                self.new,
                (self.new / self.old - 1.0) * 100.0
            )
        }
    }
}

/// Compare two bench documents. Returns every regression beyond
/// `max_regress` (0.10 = a 10% throughput drop fails); an empty vec is
/// a pass. `Err` means a document is structurally malformed.
pub fn compare(
    old: &Json,
    new: &Json,
    max_regress: f64,
) -> Result<Vec<Regression>, String> {
    let old_results = results_of(old, "old")?;
    let new_results = results_of(new, "new")?;

    let mut regressions = Vec::new();
    for entry in old_results {
        let backend = entry
            .get("backend")
            .and_then(|b| b.as_str())
            .ok_or("old: result without backend name")?;
        let fields = entry
            .as_obj()
            .ok_or("old: result entry is not an object")?;
        let new_entry = new_results.iter().find(|e| {
            e.get("backend").and_then(|b| b.as_str()) == Some(backend)
        });
        for (metric, val) in fields {
            if !METRIC_SUFFIXES.iter().any(|s| metric.ends_with(s)) {
                continue;
            }
            let Some(old_v) = val.as_f64() else {
                continue; // non-numeric metric-looking key
            };
            let new_v = new_entry
                .and_then(|e| e.get(metric))
                .and_then(|v| v.as_f64());
            match new_v {
                None => regressions.push(Regression {
                    backend: backend.to_string(),
                    metric: metric.clone(),
                    old: old_v,
                    new: f64::NAN,
                }),
                Some(n) if n < old_v * (1.0 - max_regress) => {
                    regressions.push(Regression {
                        backend: backend.to_string(),
                        metric: metric.clone(),
                        old: old_v,
                        new: n,
                    })
                }
                Some(_) => {}
            }
        }
    }
    Ok(regressions)
}

fn results_of<'a>(
    doc: &'a Json,
    which: &str,
) -> Result<&'a [Json], String> {
    doc.get("results")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| format!("{which}: missing results array"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(entries: &[(&str, &[(usize, f64)])]) -> Json {
        let mut top = Json::obj();
        top.set(
            "batch_sizes",
            Json::Arr(vec![Json::Num(1.0), Json::Num(4.0)]),
        );
        let results = entries
            .iter()
            .map(|(name, runs)| {
                let mut o = Json::obj();
                o.set("backend", Json::Str(name.to_string()));
                for (bs, tok_s) in runs.iter() {
                    o.set(
                        &format!("batch_{bs}_tok_s"),
                        Json::Num(*tok_s),
                    );
                }
                o
            })
            .collect();
        top.set("results", Json::Arr(results));
        top
    }

    #[test]
    fn identical_sweeps_pass() {
        let d = doc(&[("fp16", &[(1, 100.0), (4, 300.0)])]);
        assert!(compare(&d, &d, 0.10).unwrap().is_empty());
    }

    #[test]
    fn small_drop_within_tolerance_passes() {
        let old = doc(&[("fp16", &[(1, 100.0)])]);
        let new = doc(&[("fp16", &[(1, 91.0)])]);
        assert!(compare(&old, &new, 0.10).unwrap().is_empty());
    }

    #[test]
    fn big_drop_fails() {
        let old = doc(&[("lookat-4", &[(1, 100.0), (4, 400.0)])]);
        let new = doc(&[("lookat-4", &[(1, 100.0), (4, 350.0)])]);
        let regs = compare(&old, &new, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "batch_4_tok_s");
        assert!(regs[0].to_string().contains("lookat-4"));
    }

    #[test]
    fn missing_backend_fails() {
        let old = doc(&[("fp16", &[(1, 100.0)]), ("int8", &[(1, 90.0)])]);
        let new = doc(&[("fp16", &[(1, 100.0)])]);
        let regs = compare(&old, &new, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].backend, "int8");
        assert!(regs[0].new.is_nan());
        assert!(regs[0].to_string().contains("missing"));
    }

    #[test]
    fn new_backends_are_ignored() {
        let old = doc(&[("fp16", &[(1, 100.0)])]);
        let new = doc(&[
            ("fp16", &[(1, 100.0)]),
            ("lookat-4+vpq-8", &[(1, 50.0)]),
        ]);
        assert!(compare(&old, &new, 0.10).unwrap().is_empty());
    }

    #[test]
    fn improvements_pass() {
        let old = doc(&[("fp16", &[(1, 100.0)])]);
        let new = doc(&[("fp16", &[(1, 180.0)])]);
        assert!(compare(&old, &new, 0.10).unwrap().is_empty());
    }

    #[test]
    fn malformed_docs_error() {
        let good = doc(&[("fp16", &[(1, 100.0)])]);
        assert!(compare(&Json::obj(), &good, 0.1).is_err());
        assert!(compare(&good, &Json::obj(), 0.1).is_err());
    }

    /// Build a BENCH_adc.json-shaped doc: arbitrary metric keys.
    fn adc_doc(entries: &[(&str, &[(&str, f64)])]) -> Json {
        let mut top = Json::obj();
        let results = entries
            .iter()
            .map(|(name, metrics)| {
                let mut o = Json::obj();
                o.set("backend", Json::Str(name.to_string()));
                for (k, v) in metrics.iter() {
                    o.set(k, Json::Num(*v));
                }
                o
            })
            .collect();
        top.set("results", Json::Arr(results));
        top
    }

    #[test]
    fn metric_discovery_covers_adc_scan_keys() {
        // the ADC micro-bench records GB/s and tokens/s per m; the
        // same gate must cover them without a batch_sizes array
        let old = adc_doc(&[(
            "adc-m4-lanes",
            &[("scan_gb_s", 10.0), ("scan_tok_s", 5e8), ("m", 4.0)],
        )]);
        let ok = adc_doc(&[(
            "adc-m4-lanes",
            &[("scan_gb_s", 9.5), ("scan_tok_s", 5e8), ("m", 4.0)],
        )]);
        assert!(compare(&old, &ok, 0.10).unwrap().is_empty());
        let bad = adc_doc(&[(
            "adc-m4-lanes",
            // `m` shrinking is NOT a regression (not a metric key);
            // scan_gb_s dropping 30% is
            &[("scan_gb_s", 7.0), ("scan_tok_s", 5e8), ("m", 2.0)],
        )]);
        let regs = compare(&old, &bad, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "scan_gb_s");
    }
}
