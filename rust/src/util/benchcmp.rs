//! Bench-artifact regression comparison — the CI perf gate.
//!
//! CI downloads the previous run's bench artifact (`BENCH_serving.json`
//! and `BENCH_adc.json`) and runs `lookat bench-check --old <prev>
//! --new <current>` on each: any throughput metric that regresses by
//! more than the tolerance fails the job, and a result entry that
//! disappears from the sweep fails it too (silent coverage loss reads
//! as a pass otherwise). New entries in the current file are ignored —
//! they have no baseline.
//!
//! The document contract is schema-light: a top-level `results` array
//! (and/or a `scenarios` array) of objects, each carrying a `backend`
//! or `scenario` label plus numeric throughput metrics. Which metrics
//! exist is discovered from the *baseline* entry: every numeric key
//! ending in `_tok_s`, `_gb_s` or `_per_s` is compared (higher is
//! better). That makes the same gate cover the serving sweep's
//! `batch_N_tok_s` columns, the ADC micro-bench's scan figures and the
//! serving scenarios' swap/prefix metrics without any of them knowing
//! about the others. A non-finite new value is a regression (a NaN
//! must never slip through a `<` comparison); a zero or non-finite
//! *baseline* can never regress, so it is warned about instead of
//! silently gating nothing.

use crate::util::json::Json;

/// Key suffixes treated as higher-is-better throughput metrics.
const METRIC_SUFFIXES: [&str; 3] = ["_tok_s", "_gb_s", "_per_s"];

/// Key suffixes treated as lower-is-better latency metrics
/// (`ttft_p99_s`, `tick_p99_s`, …). These come from log-spaced
/// histograms whose bucket width is a factor of √2, so a reading can
/// jump ~41% just by crossing a bucket boundary: a latency key only
/// fails when it exceeds the tolerance AND grows past 1.5× the
/// baseline — one full bucket plus margin.
const LATENCY_SUFFIXES: [&str; 1] = ["_p99_s"];

/// Growth factor a latency metric must exceed (in addition to the
/// tolerance) before it counts as a regression.
const LATENCY_BUCKET_GUARD: f64 = 1.5;

/// One tokens/s comparison that exceeded the tolerance (or vanished).
#[derive(Clone, Debug, PartialEq)]
pub struct Regression {
    /// entry label: `backend` or `scenario` name
    pub backend: String,
    pub metric: String,
    pub old: f64,
    /// NaN with `missing` set means the metric vanished; NaN without
    /// it means the new sweep *recorded* a non-finite value
    pub new: f64,
    pub missing: bool,
    /// latency metric: the failure was the value *growing*
    pub lower_is_better: bool,
}

impl std::fmt::Display for Regression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.missing {
            write!(
                f,
                "{} {}: present in baseline, missing from new sweep",
                self.backend, self.metric
            )
        } else if !self.new.is_finite() {
            write!(
                f,
                "{} {}: {:.1} -> {} (non-finite measurement)",
                self.backend, self.metric, self.old, self.new
            )
        } else if self.lower_is_better {
            write!(
                f,
                "{} {}: {:.1} -> {:.1} ms ({:+.1}%)",
                self.backend,
                self.metric,
                self.old * 1e3,
                self.new * 1e3,
                (self.new / self.old - 1.0) * 100.0
            )
        } else {
            write!(
                f,
                "{} {}: {:.1} -> {:.1} tok/s ({:+.1}%)",
                self.backend,
                self.metric,
                self.old,
                self.new,
                (self.new / self.old - 1.0) * 100.0
            )
        }
    }
}

/// Compare two bench documents. Returns every regression beyond
/// `max_regress` (0.10 = a 10% throughput drop fails); an empty vec is
/// a pass. `Err` means a document is structurally malformed.
pub fn compare(
    old: &Json,
    new: &Json,
    max_regress: f64,
) -> Result<Vec<Regression>, String> {
    let old_results = entries_of(old, "old")?;
    let new_results = entries_of(new, "new")?;

    let mut regressions = Vec::new();
    for entry in old_results {
        let backend = label_of(entry)
            .ok_or("old: result without backend/scenario label")?;
        let fields = entry
            .as_obj()
            .ok_or("old: result entry is not an object")?;
        let new_entry = new_results
            .iter()
            .find(|e| label_of(e) == Some(backend))
            .copied();
        for (metric, val) in fields {
            let lower_is_better =
                LATENCY_SUFFIXES.iter().any(|s| metric.ends_with(s));
            if !lower_is_better
                && !METRIC_SUFFIXES.iter().any(|s| metric.ends_with(s))
            {
                continue;
            }
            let Some(old_v) = val.as_f64() else {
                continue; // non-numeric metric-looking key
            };
            if old_v == 0.0 || !old_v.is_finite() {
                // a zero/NaN baseline can never regress — the gate
                // would silently cover nothing, so say so out loud
                crate::log_warn!(
                    "bench-check: baseline {backend} {metric} = {old_v} \
                     gates nothing"
                );
                continue;
            }
            let new_v = new_entry
                .and_then(|e| e.get(metric))
                .and_then(|v| v.as_f64());
            // a non-finite measurement must fail — NaN slips through
            // any `<` / `>` tolerance check
            let regressed = |n: f64| {
                if !n.is_finite() {
                    return true;
                }
                if lower_is_better {
                    n > old_v * (1.0 + max_regress)
                        && n > old_v * LATENCY_BUCKET_GUARD
                } else {
                    n < old_v * (1.0 - max_regress)
                }
            };
            match new_v {
                None => regressions.push(Regression {
                    backend: backend.to_string(),
                    metric: metric.clone(),
                    old: old_v,
                    new: f64::NAN,
                    missing: true,
                    lower_is_better,
                }),
                Some(n) if regressed(n) => {
                    regressions.push(Regression {
                        backend: backend.to_string(),
                        metric: metric.clone(),
                        old: old_v,
                        new: n,
                        missing: false,
                        lower_is_better,
                    })
                }
                Some(_) => {}
            }
        }
    }
    Ok(regressions)
}

/// Gatherable entries of a bench doc: the `results` array, the
/// `scenarios` array, or both. At least one must be present.
fn entries_of<'a>(
    doc: &'a Json,
    which: &str,
) -> Result<Vec<&'a Json>, String> {
    let results = doc.get("results").and_then(|r| r.as_arr());
    let scenarios = doc.get("scenarios").and_then(|r| r.as_arr());
    if results.is_none() && scenarios.is_none() {
        return Err(format!("{which}: missing results/scenarios array"));
    }
    let mut v: Vec<&Json> = Vec::new();
    v.extend(results.into_iter().flatten());
    v.extend(scenarios.into_iter().flatten());
    Ok(v)
}

/// An entry's identity: `backend` (sweeps) or `scenario` (scenarios).
fn label_of(entry: &Json) -> Option<&str> {
    entry
        .get("backend")
        .and_then(|b| b.as_str())
        .or_else(|| entry.get("scenario").and_then(|s| s.as_str()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(entries: &[(&str, &[(usize, f64)])]) -> Json {
        let mut top = Json::obj();
        top.set(
            "batch_sizes",
            Json::Arr(vec![Json::Num(1.0), Json::Num(4.0)]),
        );
        let results = entries
            .iter()
            .map(|(name, runs)| {
                let mut o = Json::obj();
                o.set("backend", Json::Str(name.to_string()));
                for (bs, tok_s) in runs.iter() {
                    o.set(
                        &format!("batch_{bs}_tok_s"),
                        Json::Num(*tok_s),
                    );
                }
                o
            })
            .collect();
        top.set("results", Json::Arr(results));
        top
    }

    #[test]
    fn identical_sweeps_pass() {
        let d = doc(&[("fp16", &[(1, 100.0), (4, 300.0)])]);
        assert!(compare(&d, &d, 0.10).unwrap().is_empty());
    }

    #[test]
    fn small_drop_within_tolerance_passes() {
        let old = doc(&[("fp16", &[(1, 100.0)])]);
        let new = doc(&[("fp16", &[(1, 91.0)])]);
        assert!(compare(&old, &new, 0.10).unwrap().is_empty());
    }

    #[test]
    fn big_drop_fails() {
        let old = doc(&[("lookat-4", &[(1, 100.0), (4, 400.0)])]);
        let new = doc(&[("lookat-4", &[(1, 100.0), (4, 350.0)])]);
        let regs = compare(&old, &new, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "batch_4_tok_s");
        assert!(regs[0].to_string().contains("lookat-4"));
    }

    #[test]
    fn missing_backend_fails() {
        let old = doc(&[("fp16", &[(1, 100.0)]), ("int8", &[(1, 90.0)])]);
        let new = doc(&[("fp16", &[(1, 100.0)])]);
        let regs = compare(&old, &new, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].backend, "int8");
        assert!(regs[0].new.is_nan());
        assert!(regs[0].missing);
        assert!(regs[0].to_string().contains("missing"));
    }

    #[test]
    fn nan_new_value_fails() {
        // a NaN measurement slips through `n < threshold` (always
        // false) — the gate must treat it as a regression, not a pass
        let old = doc(&[("fp16", &[(1, 100.0)])]);
        let new = doc(&[("fp16", &[(1, f64::NAN)])]);
        let regs = compare(&old, &new, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert!(regs[0].new.is_nan());
        assert!(!regs[0].missing, "recorded NaN is not a missing metric");
        assert!(regs[0].to_string().contains("non-finite"));
        // infinities are equally unusable as measurements
        let inf = doc(&[("fp16", &[(1, f64::INFINITY)])]);
        assert_eq!(compare(&old, &inf, 0.10).unwrap().len(), 1);
    }

    #[test]
    fn zero_or_nonfinite_baseline_warns_and_gates_nothing() {
        // 0.0 baseline: nothing can ever be 10% below it, so it must
        // not silently count as covered — it is skipped (with a log
        // warning), and a genuine metric alongside it still gates
        let old = doc(&[("fp16", &[(1, 0.0), (4, 100.0)])]);
        let new = doc(&[("fp16", &[(1, 0.0), (4, 50.0)])]);
        let regs = compare(&old, &new, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "batch_4_tok_s");
        // NaN baseline: same treatment
        let old_nan = doc(&[("fp16", &[(1, f64::NAN)])]);
        let new_any = doc(&[("fp16", &[(1, 5.0)])]);
        assert!(compare(&old_nan, &new_any, 0.10).unwrap().is_empty());
    }

    /// Build a scenarios-shaped doc (`scenario` label, not `backend`).
    fn scenario_doc(entries: &[(&str, &[(&str, f64)])]) -> Json {
        let mut top = Json::obj();
        let scenarios = entries
            .iter()
            .map(|(name, metrics)| {
                let mut o = Json::obj();
                o.set("scenario", Json::Str(name.to_string()));
                for (k, v) in metrics.iter() {
                    o.set(k, Json::Num(*v));
                }
                o
            })
            .collect();
        top.set("scenarios", Json::Arr(scenarios));
        top
    }

    #[test]
    fn scenario_entries_are_gated() {
        // the serving bench's swap/prefix scenarios live in a
        // `scenarios` array keyed by `scenario` — the same gate must
        // cover their *_tok_s metrics automatically
        let old = scenario_doc(&[(
            "swap_preempt_heavy",
            &[("swap_on_tok_s", 200.0), ("swap_off_tok_s", 100.0)],
        )]);
        let ok = scenario_doc(&[(
            "swap_preempt_heavy",
            &[("swap_on_tok_s", 195.0), ("swap_off_tok_s", 99.0)],
        )]);
        assert!(compare(&old, &ok, 0.10).unwrap().is_empty());
        let bad = scenario_doc(&[(
            "swap_preempt_heavy",
            &[("swap_on_tok_s", 120.0), ("swap_off_tok_s", 99.0)],
        )]);
        let regs = compare(&old, &bad, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].backend, "swap_preempt_heavy");
        assert_eq!(regs[0].metric, "swap_on_tok_s");
        // a vanished scenario fails like a vanished backend
        let gone = scenario_doc(&[("other", &[("x_tok_s", 1.0)])]);
        let regs = compare(&old, &gone, 0.10).unwrap();
        assert_eq!(regs.len(), 2, "both metrics reported missing");
        assert!(regs.iter().all(|r| r.missing));
    }

    #[test]
    fn new_backends_are_ignored() {
        let old = doc(&[("fp16", &[(1, 100.0)])]);
        let new = doc(&[
            ("fp16", &[(1, 100.0)]),
            ("lookat-4+vpq-8", &[(1, 50.0)]),
        ]);
        assert!(compare(&old, &new, 0.10).unwrap().is_empty());
    }

    #[test]
    fn improvements_pass() {
        let old = doc(&[("fp16", &[(1, 100.0)])]);
        let new = doc(&[("fp16", &[(1, 180.0)])]);
        assert!(compare(&old, &new, 0.10).unwrap().is_empty());
    }

    #[test]
    fn malformed_docs_error() {
        let good = doc(&[("fp16", &[(1, 100.0)])]);
        assert!(compare(&Json::obj(), &good, 0.1).is_err());
        assert!(compare(&good, &Json::obj(), 0.1).is_err());
    }

    /// Build a BENCH_adc.json-shaped doc: arbitrary metric keys.
    fn adc_doc(entries: &[(&str, &[(&str, f64)])]) -> Json {
        let mut top = Json::obj();
        let results = entries
            .iter()
            .map(|(name, metrics)| {
                let mut o = Json::obj();
                o.set("backend", Json::Str(name.to_string()));
                for (k, v) in metrics.iter() {
                    o.set(k, Json::Num(*v));
                }
                o
            })
            .collect();
        top.set("results", Json::Arr(results));
        top
    }

    #[test]
    fn metric_discovery_covers_adc_scan_keys() {
        // the ADC micro-bench records GB/s and tokens/s per m; the
        // same gate must cover them without a batch_sizes array
        let old = adc_doc(&[(
            "adc-m4-lanes",
            &[("scan_gb_s", 10.0), ("scan_tok_s", 5e8), ("m", 4.0)],
        )]);
        let ok = adc_doc(&[(
            "adc-m4-lanes",
            &[("scan_gb_s", 9.5), ("scan_tok_s", 5e8), ("m", 4.0)],
        )]);
        assert!(compare(&old, &ok, 0.10).unwrap().is_empty());
        let bad = adc_doc(&[(
            "adc-m4-lanes",
            // `m` shrinking is NOT a regression (not a metric key);
            // scan_gb_s dropping 30% is
            &[("scan_gb_s", 7.0), ("scan_tok_s", 5e8), ("m", 2.0)],
        )]);
        let regs = compare(&old, &bad, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "scan_gb_s");
    }

    #[test]
    fn latency_p99_keys_gate_lower_is_better() {
        // latency growing fails; latency shrinking passes (the
        // throughput rule would read a big drop as a regression)
        let old = adc_doc(&[(
            "lookat-4",
            &[("ttft_p99_s", 0.100), ("batch_4_tok_s", 300.0)],
        )]);
        let faster = adc_doc(&[(
            "lookat-4",
            &[("ttft_p99_s", 0.020), ("batch_4_tok_s", 300.0)],
        )]);
        assert!(compare(&old, &faster, 0.10).unwrap().is_empty());
        let slower = adc_doc(&[(
            "lookat-4",
            &[("ttft_p99_s", 0.200), ("batch_4_tok_s", 300.0)],
        )]);
        let regs = compare(&old, &slower, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "ttft_p99_s");
        assert!(regs[0].lower_is_better);
        assert!(regs[0].to_string().contains("ms"), "{}", regs[0]);
    }

    #[test]
    fn latency_within_one_histogram_bucket_is_not_flagged() {
        // histogram percentiles are bucket-quantized (ratio sqrt(2)):
        // a +41% reading can be the same underlying latency landing
        // one bucket over, so only growth past 1.5x fails
        let old = adc_doc(&[("lookat-4", &[("tick_p99_s", 0.100)])]);
        let one_bucket =
            adc_doc(&[("lookat-4", &[("tick_p99_s", 0.1415)])]);
        assert!(compare(&old, &one_bucket, 0.10).unwrap().is_empty());
        // a vanished latency key still fails like any other metric
        let gone = adc_doc(&[("lookat-4", &[("other_tok_s", 1.0)])]);
        let regs = compare(&old, &gone, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert!(regs[0].missing);
    }
}
