//! BENCH_serving.json regression comparison — the CI perf gate.
//!
//! CI downloads the previous run's `BENCH_serving.json` artifact and
//! runs `lookat bench-check --old <prev> --new <current>`: any backend
//! × batch-width tokens/s figure that regresses by more than the
//! tolerance fails the job, and a backend that disappears from the
//! sweep fails it too (silent coverage loss reads as a pass otherwise).
//! New backends in the current file are ignored — they have no baseline.

use crate::util::json::Json;

/// One tokens/s comparison that exceeded the tolerance (or vanished).
#[derive(Clone, Debug, PartialEq)]
pub struct Regression {
    pub backend: String,
    pub metric: String,
    pub old: f64,
    pub new: f64,
}

impl std::fmt::Display for Regression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.new.is_nan() {
            write!(
                f,
                "{} {}: present in baseline, missing from new sweep",
                self.backend, self.metric
            )
        } else {
            write!(
                f,
                "{} {}: {:.1} -> {:.1} tok/s ({:+.1}%)",
                self.backend,
                self.metric,
                self.old,
                self.new,
                (self.new / self.old - 1.0) * 100.0
            )
        }
    }
}

/// Compare two BENCH_serving.json documents. Returns every regression
/// beyond `max_regress` (0.10 = a 10% tokens/s drop fails); an empty
/// vec is a pass. `Err` means a document is structurally malformed.
pub fn compare(
    old: &Json,
    new: &Json,
    max_regress: f64,
) -> Result<Vec<Regression>, String> {
    let old_results = results_of(old, "old")?;
    let new_results = results_of(new, "new")?;
    let batches = old
        .get("batch_sizes")
        .and_then(|b| b.as_arr())
        .ok_or("old: missing batch_sizes array")?;

    let mut regressions = Vec::new();
    for entry in old_results {
        let backend = entry
            .get("backend")
            .and_then(|b| b.as_str())
            .ok_or("old: result without backend name")?;
        let new_entry = new_results.iter().find(|e| {
            e.get("backend").and_then(|b| b.as_str()) == Some(backend)
        });
        for bs in batches {
            let metric = format!(
                "batch_{}_tok_s",
                bs.as_usize().ok_or("old: non-numeric batch size")?
            );
            let Some(old_v) =
                entry.get(&metric).and_then(|v| v.as_f64())
            else {
                continue; // metric not recorded in the baseline
            };
            let new_v = new_entry
                .and_then(|e| e.get(&metric))
                .and_then(|v| v.as_f64());
            match new_v {
                None => regressions.push(Regression {
                    backend: backend.to_string(),
                    metric,
                    old: old_v,
                    new: f64::NAN,
                }),
                Some(n) if n < old_v * (1.0 - max_regress) => {
                    regressions.push(Regression {
                        backend: backend.to_string(),
                        metric,
                        old: old_v,
                        new: n,
                    })
                }
                Some(_) => {}
            }
        }
    }
    Ok(regressions)
}

fn results_of<'a>(
    doc: &'a Json,
    which: &str,
) -> Result<&'a [Json], String> {
    doc.get("results")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| format!("{which}: missing results array"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(entries: &[(&str, &[(usize, f64)])]) -> Json {
        let mut top = Json::obj();
        top.set(
            "batch_sizes",
            Json::Arr(vec![Json::Num(1.0), Json::Num(4.0)]),
        );
        let results = entries
            .iter()
            .map(|(name, runs)| {
                let mut o = Json::obj();
                o.set("backend", Json::Str(name.to_string()));
                for (bs, tok_s) in runs.iter() {
                    o.set(
                        &format!("batch_{bs}_tok_s"),
                        Json::Num(*tok_s),
                    );
                }
                o
            })
            .collect();
        top.set("results", Json::Arr(results));
        top
    }

    #[test]
    fn identical_sweeps_pass() {
        let d = doc(&[("fp16", &[(1, 100.0), (4, 300.0)])]);
        assert!(compare(&d, &d, 0.10).unwrap().is_empty());
    }

    #[test]
    fn small_drop_within_tolerance_passes() {
        let old = doc(&[("fp16", &[(1, 100.0)])]);
        let new = doc(&[("fp16", &[(1, 91.0)])]);
        assert!(compare(&old, &new, 0.10).unwrap().is_empty());
    }

    #[test]
    fn big_drop_fails() {
        let old = doc(&[("lookat-4", &[(1, 100.0), (4, 400.0)])]);
        let new = doc(&[("lookat-4", &[(1, 100.0), (4, 350.0)])]);
        let regs = compare(&old, &new, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "batch_4_tok_s");
        assert!(regs[0].to_string().contains("lookat-4"));
    }

    #[test]
    fn missing_backend_fails() {
        let old = doc(&[("fp16", &[(1, 100.0)]), ("int8", &[(1, 90.0)])]);
        let new = doc(&[("fp16", &[(1, 100.0)])]);
        let regs = compare(&old, &new, 0.10).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].backend, "int8");
        assert!(regs[0].new.is_nan());
        assert!(regs[0].to_string().contains("missing"));
    }

    #[test]
    fn new_backends_are_ignored() {
        let old = doc(&[("fp16", &[(1, 100.0)])]);
        let new = doc(&[
            ("fp16", &[(1, 100.0)]),
            ("lookat-4+vpq-8", &[(1, 50.0)]),
        ]);
        assert!(compare(&old, &new, 0.10).unwrap().is_empty());
    }

    #[test]
    fn improvements_pass() {
        let old = doc(&[("fp16", &[(1, 100.0)])]);
        let new = doc(&[("fp16", &[(1, 180.0)])]);
        assert!(compare(&old, &new, 0.10).unwrap().is_empty());
    }

    #[test]
    fn malformed_docs_error() {
        let good = doc(&[("fp16", &[(1, 100.0)])]);
        assert!(compare(&Json::obj(), &good, 0.1).is_err());
        assert!(compare(&good, &Json::obj(), 0.1).is_err());
    }
}
