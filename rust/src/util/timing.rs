//! Per-phase wall-time accounting for the decode hot path.
//!
//! The engine owns one [`PhaseTimers`] and threads it (as an optional
//! borrow) through the per-layer [`crate::attention::DecodePlan`]s, so
//! the kernels can attribute time to `lut_build` / `scan` /
//! `value_decode` while the engine itself books `qkv` and `mlp`.
//! Counters are atomics: worker threads add durations concurrently and
//! the serving loop drains a snapshot per run into
//! [`crate::coordinator::ServingReport`].
//!
//! Semantics: each phase accumulates the *summed* duration of its
//! timed sections across all threads and overlapped pipeline stages,
//! so phase totals can legitimately exceed the run's wall time — they
//! are a breakdown of where compute went, not a partition of the
//! clock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// One timed phase of the decode tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// per-query LUT construction (LOOKAT kernels)
    LutBuild,
    /// key scoring: the ADC lane scan, or dense Q·Kᵀ on fp16/int paths
    Scan,
    /// the attention tail: α·V accumulation or the fused blocked
    /// weighted decode over PQ value codes
    ValueDecode,
    /// LN1 + QKV projection (engine stage)
    Qkv,
    /// attention-out projection + MLP tail (engine stage)
    Mlp,
}

/// Concurrent per-phase accumulators (nanoseconds).
#[derive(Debug, Default)]
pub struct PhaseTimers {
    lut_build_ns: AtomicU64,
    scan_ns: AtomicU64,
    value_decode_ns: AtomicU64,
    qkv_ns: AtomicU64,
    mlp_ns: AtomicU64,
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one timed section to a phase.
    pub fn add(&self, phase: Phase, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.cell(phase).fetch_add(ns, Ordering::Relaxed);
    }

    fn cell(&self, phase: Phase) -> &AtomicU64 {
        match phase {
            Phase::LutBuild => &self.lut_build_ns,
            Phase::Scan => &self.scan_ns,
            Phase::ValueDecode => &self.value_decode_ns,
            Phase::Qkv => &self.qkv_ns,
            Phase::Mlp => &self.mlp_ns,
        }
    }

    /// Current totals without resetting.
    pub fn snapshot(&self) -> PhaseTimes {
        let s = |c: &AtomicU64| c.load(Ordering::Relaxed) as f64 / 1e9;
        PhaseTimes {
            lut_build_s: s(&self.lut_build_ns),
            scan_s: s(&self.scan_ns),
            value_decode_s: s(&self.value_decode_ns),
            qkv_s: s(&self.qkv_ns),
            mlp_s: s(&self.mlp_ns),
        }
    }

    /// Drain the totals (read and reset) — one serving run's breakdown.
    pub fn take(&self) -> PhaseTimes {
        let s = |c: &AtomicU64| c.swap(0, Ordering::Relaxed) as f64 / 1e9;
        PhaseTimes {
            lut_build_s: s(&self.lut_build_ns),
            scan_s: s(&self.scan_ns),
            value_decode_s: s(&self.value_decode_ns),
            qkv_s: s(&self.qkv_ns),
            mlp_s: s(&self.mlp_ns),
        }
    }
}

/// A drained per-phase breakdown, in seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseTimes {
    pub lut_build_s: f64,
    pub scan_s: f64,
    pub value_decode_s: f64,
    pub qkv_s: f64,
    pub mlp_s: f64,
}

impl PhaseTimes {
    /// Serialize as a flat JSON object (the `phases` block of
    /// `ServingReport::to_json` / `BENCH_serving.json`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("lut_build_s", Json::Num(self.lut_build_s));
        o.set("scan_s", Json::Num(self.scan_s));
        o.set("value_decode_s", Json::Num(self.value_decode_s));
        o.set("qkv_s", Json::Num(self.qkv_s));
        o.set("mlp_s", Json::Num(self.mlp_s));
        o
    }

    /// Total attributed seconds across all phases.
    pub fn total_s(&self) -> f64 {
        self.lut_build_s
            + self.scan_s
            + self.value_decode_s
            + self.qkv_s
            + self.mlp_s
    }
}

/// Time one section into an optional timer set. When `timers` is
/// `None` (tests, standalone kernel use) the closure runs untimed —
/// no clock reads on the fast path.
#[inline]
pub fn timed<R>(
    timers: Option<&PhaseTimers>,
    phase: Phase,
    f: impl FnOnce() -> R,
) -> R {
    match timers {
        None => f(),
        Some(t) => {
            let t0 = std::time::Instant::now();
            let r = f();
            t.add(phase, t0.elapsed());
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate_and_drain() {
        let t = PhaseTimers::new();
        t.add(Phase::Scan, Duration::from_millis(2));
        t.add(Phase::Scan, Duration::from_millis(3));
        t.add(Phase::Qkv, Duration::from_millis(1));
        let snap = t.snapshot();
        assert!((snap.scan_s - 0.005).abs() < 1e-9);
        assert!((snap.qkv_s - 0.001).abs() < 1e-9);
        assert_eq!(snap.lut_build_s, 0.0);
        // take drains
        let taken = t.take();
        assert_eq!(taken, snap);
        assert_eq!(t.snapshot(), PhaseTimes::default());
        assert!((taken.total_s() - 0.006).abs() < 1e-9);
    }

    #[test]
    fn timed_books_into_the_right_phase() {
        let t = PhaseTimers::new();
        let r = timed(Some(&t), Phase::LutBuild, || 7);
        assert_eq!(r, 7);
        assert!(t.snapshot().lut_build_s >= 0.0);
        // None skips the clock entirely but still runs the closure
        assert_eq!(timed(None, Phase::Mlp, || 9), 9);
        assert_eq!(t.snapshot().mlp_s, 0.0);
    }

    #[test]
    fn json_has_all_phase_keys() {
        let j = PhaseTimes::default().to_json();
        for k in
            ["lut_build_s", "scan_s", "value_decode_s", "qkv_s", "mlp_s"]
        {
            assert!(j.get(k).is_some(), "missing {k}");
        }
    }
}
