//! From-scratch substrates for everything the offline image does not
//! vendor: RNG, JSON, CLI parsing, thread pool, benchmarking, statistics,
//! logging and a miniature property-testing framework.
//!
//! These are deliberately small, dependency-free and fully unit-tested —
//! see DESIGN.md §Environment constraints.

pub mod bench;
pub mod benchcmp;
pub mod cli;
pub mod fault;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timing;
