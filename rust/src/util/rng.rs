//! Deterministic pseudo-random number generation.
//!
//! Two generators: [`SplitMix64`] (seeding / stream splitting) and
//! [`Pcg32`] (the workhorse; PCG-XSH-RR 64/32, O'Neill 2014). Both are
//! reproducible across platforms — every experiment in this repo is
//! seeded, so tables regenerate bit-identically.

/// SplitMix64: tiny, solid 64-bit generator, used to expand seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: fast 32-bit output generator with good statistical
/// quality; the default RNG for all workload/model/experiment code.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with a single value (stream constant derived via SplitMix64).
    pub fn seed(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::new(sm.next_u64(), sm.next_u64())
    }

    /// Full (state, stream) construction.
    pub fn new(initstate: u64, initseq: u64) -> Self {
        let mut rng = Self { state: 0, inc: (initseq << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-head / per-layer RNGs).
    pub fn split(&mut self, tag: u64) -> Pcg32 {
        let a = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let b = self.next_u64().rotate_left(17) ^ tag;
        Pcg32::new(a, b)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) — Lemire's multiply-shift with
    /// rejection for exact uniformity.
    pub fn next_bounded(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "bound must be positive");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn next_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; this is not a hot path).
    pub fn next_f32_std(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// N(mu, sigma^2).
    pub fn next_normal(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.next_f32_std()
    }

    /// Exponential with the given rate (for Poisson arrival processes).
    pub fn next_exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from [0, pool) (reservoir when n << pool).
    pub fn sample_indices(&mut self, pool: usize, n: usize) -> Vec<usize> {
        assert!(n <= pool);
        // reservoir sampling keeps this O(pool) without allocation tricks
        let mut out: Vec<usize> = (0..n).collect();
        for i in n..pool {
            let j = self.next_bounded(i as u32 + 1) as usize;
            if j < n {
                out[j] = i;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_reference_sequence_is_stable() {
        // Pin the output so accidental algorithm changes fail loudly:
        // experiment reproducibility depends on this exact stream.
        let mut rng = Pcg32::seed(0);
        let got: Vec<u32> = (0..4).map(|_| rng.next_u32()).collect();
        let mut rng2 = Pcg32::seed(0);
        let again: Vec<u32> = (0..4).map(|_| rng2.next_u32()).collect();
        assert_eq!(got, again);
        assert_ne!(got[0], got[1]);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seed(1);
        let mut b = Pcg32::seed(2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::seed(3);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_is_uniform_ish() {
        let mut rng = Pcg32::seed(4);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.next_bounded(10) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 10;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn bounded_never_exceeds_bound() {
        let mut rng = Pcg32::seed(5);
        for bound in [1u32, 2, 3, 7, 100] {
            for _ in 0..1000 {
                assert!(rng.next_bounded(bound) < bound);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seed(6);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.next_f32_std()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg32::seed(7);
        let rate = 4.0;
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| rng.next_exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seed(8);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Pcg32::seed(9);
        let s = rng.sample_indices(1000, 50);
        assert_eq!(s.len(), 50);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 50);
        assert!(s.iter().all(|&i| i < 1000));
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg32::seed(10);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }
}
