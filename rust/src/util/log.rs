//! Leveled stderr logger with a monotonic timestamp, env-controlled via
//! `LOOKAT_LOG` (error|warn|info|debug|trace; default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    /// Safe inverse of `lvl as u8`; out-of-range bytes saturate to the
    /// most verbose level rather than invoking UB.
    fn from_u8(raw: u8) -> Level {
        match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static START: OnceLock<Instant> = OnceLock::new();

fn current_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != u8::MAX {
        return Level::from_u8(raw);
    }
    let lvl = std::env::var("LOOKAT_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    lvl
}

/// Override the level programmatically (tests, CLI --verbose).
pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl <= current_level()
}

pub fn log(lvl: Level, module: &str, msg: &str) {
    if !enabled(lvl) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{t:10.4}s {} {module}] {msg}", lvl.tag());
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error,
                               module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Trace,
                               module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("TRACE"), Some(Level::Trace));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn ordering_semantics() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn log_does_not_panic() {
        set_level(Level::Info);
        log(Level::Info, "test", "hello");
        log_info!("formatted {} {}", 1, "two");
        log_trace!("suppressed at info level {}", 3);
    }

    #[test]
    fn from_u8_round_trips_and_saturates() {
        for lvl in [
            Level::Error,
            Level::Warn,
            Level::Info,
            Level::Debug,
            Level::Trace,
        ] {
            assert_eq!(Level::from_u8(lvl as u8), lvl);
        }
        assert_eq!(Level::from_u8(200), Level::Trace);
    }
}
