//! Tiny declarative CLI argument parser (clap is not vendored offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, and positional arguments. Produces a usage string.

use std::collections::BTreeMap;

/// Declared option (always string-typed at parse time; accessors convert).
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    default: Option<String>,
    help: String,
    is_flag: bool,
}

/// A declarative command-line spec for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub program: String,
    pub about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>, // (name, help)
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    MissingPositional(String),
    Invalid(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(n) => write!(f, "unknown option --{n}"),
            CliError::MissingValue(n) => {
                write!(f, "option --{n} requires a value")
            }
            CliError::MissingPositional(n) => {
                write!(f, "missing required positional <{n}>")
            }
            CliError::Invalid(n, v) => {
                write!(f, "invalid value for --{n}: {v}")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            default: Some(default.to_string()),
            help: help.to_string(),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--name <value>` (no default).
    pub fn opt_required(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            default: None,
            help: help.to_string(),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            default: None,
            help: help.to_string(),
            is_flag: true,
        });
        self
    }

    /// Declare a required positional argument.
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    /// Render a usage/help string.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program,
                            self.about, self.program);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        if !self.opts.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        s.push('\n');
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let left = if o.is_flag {
                    format!("--{}", o.name)
                } else if let Some(d) = &o.default {
                    format!("--{} <v> (default {d})", o.name)
                } else {
                    format!("--{} <v> (required)", o.name)
                };
                s.push_str(&format!("  {left:36} {}\n", o.help));
            }
        }
        s
    }

    /// Parse a token list (no program name).
    pub fn parse(&self, tokens: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // seed defaults
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
            if o.is_flag {
                args.flags.insert(o.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(stripped) = t.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.is_flag {
                    args.flags.insert(name, true);
                } else if let Some(v) = inline {
                    args.values.insert(name, v);
                } else {
                    i += 1;
                    let v = tokens
                        .get(i)
                        .ok_or_else(|| CliError::MissingValue(name.clone()))?;
                    args.values.insert(name, v.clone());
                }
            } else {
                args.positionals.push(t.clone());
            }
            i += 1;
        }
        // required options & positionals
        for o in &self.opts {
            if !o.is_flag && !args.values.contains_key(&o.name) {
                return Err(CliError::MissingValue(o.name.clone()));
            }
        }
        if args.positionals.len() < self.positionals.len() {
            return Err(CliError::MissingPositional(
                self.positionals[args.positionals.len()].0.clone(),
            ));
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("undeclared option --{name}"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError::Invalid(name.into(), self.get(name).into()))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError::Invalid(name.into(), self.get(name).into()))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError::Invalid(name.into(), self.get(name).into()))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    /// Parse a comma-separated list of usize, e.g. "2,4,8".
    pub fn get_usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        self.get(name)
            .split(',')
            .map(|t| {
                t.trim().parse().map_err(|_| {
                    CliError::Invalid(name.into(), self.get(name).into())
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(x: &str) -> String {
        x.to_string()
    }

    fn cli() -> Cli {
        Cli::new("test", "a test command")
            .opt("depth", "4", "model depth")
            .opt("name", "gpt", "model name")
            .flag("verbose", "chatty output")
            .opt_required("out", "output path")
            .positional("input", "input file")
    }

    #[test]
    fn defaults_apply() {
        let a = cli()
            .parse(&[s("--out"), s("/tmp/x"), s("file.txt")])
            .unwrap();
        assert_eq!(a.get_usize("depth").unwrap(), 4);
        assert_eq!(a.get("name"), "gpt");
        assert!(!a.get_flag("verbose"));
        assert_eq!(a.positionals, vec![s("file.txt")]);
    }

    #[test]
    fn overrides_and_flags() {
        let a = cli()
            .parse(&[
                s("--depth=12"),
                s("--verbose"),
                s("--out"),
                s("o"),
                s("in"),
            ])
            .unwrap();
        assert_eq!(a.get_usize("depth").unwrap(), 12);
        assert!(a.get_flag("verbose"));
    }

    #[test]
    fn equals_and_space_syntax_equivalent() {
        let a = cli()
            .parse(&[s("--name"), s("abc"), s("--out=o"), s("in")])
            .unwrap();
        assert_eq!(a.get("name"), "abc");
        assert_eq!(a.get("out"), "o");
    }

    #[test]
    fn missing_required_option_errors() {
        let e = cli().parse(&[s("in")]).unwrap_err();
        assert!(matches!(e, CliError::MissingValue(n) if n == "out"));
    }

    #[test]
    fn missing_positional_errors() {
        let e = cli().parse(&[s("--out"), s("o")]).unwrap_err();
        assert!(matches!(e, CliError::MissingPositional(n) if n == "input"));
    }

    #[test]
    fn unknown_option_errors() {
        let e = cli()
            .parse(&[s("--bogus"), s("--out"), s("o"), s("in")])
            .unwrap_err();
        assert!(matches!(e, CliError::Unknown(n) if n == "bogus"));
    }

    #[test]
    fn value_missing_at_end_errors() {
        let e = cli().parse(&[s("--out")]).unwrap_err();
        assert!(matches!(e, CliError::MissingValue(_)));
    }

    #[test]
    fn usize_list() {
        let c = Cli::new("t", "t").opt("ms", "2,4,8", "subspace list");
        let a = c.parse(&[]).unwrap();
        assert_eq!(a.get_usize_list("ms").unwrap(), vec![2, 4, 8]);
    }

    #[test]
    fn usage_mentions_everything() {
        let u = cli().usage();
        assert!(u.contains("--depth"));
        assert!(u.contains("<input>"));
        assert!(u.contains("(required)"));
    }

    #[test]
    fn bad_numeric_value_errors() {
        let c = Cli::new("t", "t").opt("n", "1", "num");
        let a = c.parse(&[s("--n"), s("xyz")]).unwrap();
        assert!(a.get_usize("n").is_err());
    }
}
