//! Synthetic text corpora with per-genre statistics.
//!
//! The paper evaluates on three text types (natural prose, Python code,
//! technical writing) whose role is to vary the key-vector statistics the
//! PQ codebooks must capture. Offline we generate deterministic synthetic
//! corpora with clearly distinct distributions:
//!
//!   * Prose     — Zipf-distributed word vocabulary, sentence structure
//!   * Code      — keyword/identifier/punctuation mix, indentation
//!   * Technical — prose interleaved with symbols, numbers and citations

use crate::util::rng::Pcg32;

/// Text genre (paper §4.1's three sample types).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Genre {
    Prose,
    Code,
    Technical,
}

impl Genre {
    pub const ALL: [Genre; 3] = [Genre::Prose, Genre::Code, Genre::Technical];

    pub fn name(&self) -> &'static str {
        match self {
            Genre::Prose => "prose",
            Genre::Code => "code",
            Genre::Technical => "technical",
        }
    }
}

/// A deterministic corpus generator.
pub struct Corpus {
    rng: Pcg32,
    genre: Genre,
    prose_vocab: Vec<String>,
}

const PROSE_STEMS: &[&str] = &[
    "time", "way", "year", "work", "government", "day", "man", "world",
    "life", "part", "house", "course", "case", "system", "place", "end",
    "group", "company", "party", "information", "school", "fact", "money",
    "point", "example", "state", "business", "night", "area", "water",
    "thing", "family", "head", "hand", "order", "john", "side", "home",
    "development", "week", "power", "country", "council", "use", "service",
    "room", "market", "problem", "court", "lot", "a", "the", "of", "and",
    "to", "in", "is", "was", "it", "for", "with", "he", "be", "on", "i",
    "that", "by", "at", "you", "are", "his", "had", "not", "this", "have",
    "from", "but", "which", "she", "they", "or", "an", "were", "we",
    "their", "been", "has", "will", "one", "all", "would", "can", "if",
    "who", "more", "when", "so", "no", "out", "up", "into", "them",
];

const CODE_KEYWORDS: &[&str] = &[
    "def", "return", "if", "else", "elif", "for", "while", "import",
    "from", "class", "self", "None", "True", "False", "lambda", "try",
    "except", "raise", "with", "as", "yield", "assert", "pass", "break",
    "continue", "in", "not", "and", "or", "is", "print", "len", "range",
];

const CODE_IDENTS: &[&str] = &[
    "x", "y", "i", "j", "n", "data", "result", "value", "key", "index",
    "count", "total", "items", "args", "kwargs", "config", "model",
    "batch", "layer", "cache", "score", "query", "token", "output",
];

const TECH_TERMS: &[&str] = &[
    "algorithm", "theorem", "quantization", "vector", "matrix", "tensor",
    "subspace", "codebook", "centroid", "softmax", "attention", "latency",
    "bandwidth", "throughput", "approximation", "correlation", "gradient",
    "eigenvalue", "manifold", "entropy", "distribution", "probability",
];

impl Corpus {
    pub fn new(genre: Genre, seed: u64) -> Self {
        let rng = Pcg32::seed(seed ^ 0xC0_97_05);
        let prose_vocab =
            PROSE_STEMS.iter().map(|s| s.to_string()).collect();
        Self { rng, genre, prose_vocab }
    }

    /// Zipf-ish rank sample over [0, n): p(r) ∝ 1/(r+1).
    fn zipf(&mut self, n: usize) -> usize {
        let hn: f64 = (1..=n).map(|i| 1.0 / i as f64).sum();
        let mut target = self.rng.next_f64() * hn;
        for r in 0..n {
            target -= 1.0 / (r + 1) as f64;
            if target <= 0.0 {
                return r;
            }
        }
        n - 1
    }

    /// Generate a text of at least `min_chars` characters.
    pub fn generate(&mut self, min_chars: usize) -> String {
        let mut out = String::with_capacity(min_chars + 128);
        while out.len() < min_chars {
            match self.genre {
                Genre::Prose => self.push_sentence(&mut out),
                Genre::Code => self.push_code_line(&mut out),
                Genre::Technical => self.push_technical(&mut out),
            }
        }
        out
    }

    fn push_sentence(&mut self, out: &mut String) {
        let words = 5 + self.rng.next_bounded(12) as usize;
        for w in 0..words {
            let r = self.zipf(self.prose_vocab.len());
            let word = &self.prose_vocab[r];
            if w == 0 {
                // capitalize
                let mut cs = word.chars();
                if let Some(c) = cs.next() {
                    out.push(c.to_ascii_uppercase());
                    out.push_str(cs.as_str());
                }
            } else {
                out.push_str(word);
            }
            out.push(if w + 1 == words { '.' } else { ' ' });
        }
        out.push(' ');
    }

    fn push_code_line(&mut self, out: &mut String) {
        let indent = self.rng.next_bounded(3) as usize;
        out.push_str(&"    ".repeat(indent));
        match self.rng.next_bounded(4) {
            0 => {
                let f = CODE_IDENTS
                    [self.rng.next_bounded(CODE_IDENTS.len() as u32) as usize];
                let a = CODE_IDENTS
                    [self.rng.next_bounded(CODE_IDENTS.len() as u32) as usize];
                out.push_str(&format!("def {f}({a}):"));
            }
            1 => {
                let v = CODE_IDENTS
                    [self.rng.next_bounded(CODE_IDENTS.len() as u32) as usize];
                let n = self.rng.next_bounded(100);
                out.push_str(&format!("{v} = {v} + {n}"));
            }
            2 => {
                let kw = CODE_KEYWORDS[self
                    .rng
                    .next_bounded(CODE_KEYWORDS.len() as u32)
                    as usize];
                let v = CODE_IDENTS
                    [self.rng.next_bounded(CODE_IDENTS.len() as u32) as usize];
                out.push_str(&format!("{kw} {v}:"));
            }
            _ => {
                let v = CODE_IDENTS
                    [self.rng.next_bounded(CODE_IDENTS.len() as u32) as usize];
                out.push_str(&format!("return {v}"));
            }
        }
        out.push('\n');
    }

    fn push_technical(&mut self, out: &mut String) {
        let words = 4 + self.rng.next_bounded(8) as usize;
        for w in 0..words {
            match self.rng.next_bounded(5) {
                0 => {
                    let t = TECH_TERMS[self
                        .rng
                        .next_bounded(TECH_TERMS.len() as u32)
                        as usize];
                    out.push_str(t);
                }
                1 => {
                    out.push_str(&format!(
                        "{}.{}",
                        self.rng.next_bounded(10),
                        self.rng.next_bounded(100)
                    ));
                }
                2 => out.push_str(&format!("[{}]", self.rng.next_bounded(30))),
                _ => {
                    let r = self.zipf(self.prose_vocab.len());
                    out.push_str(&self.prose_vocab[r]);
                }
            }
            out.push(if w + 1 == words { '.' } else { ' ' });
        }
        out.push(' ');
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn deterministic_per_seed() {
        let a = Corpus::new(Genre::Prose, 42).generate(500);
        let b = Corpus::new(Genre::Prose, 42).generate(500);
        assert_eq!(a, b);
        let c = Corpus::new(Genre::Prose, 43).generate(500);
        assert_ne!(a, c);
    }

    #[test]
    fn generates_at_least_min_chars() {
        for g in Genre::ALL {
            let text = Corpus::new(g, 1).generate(1000);
            assert!(text.len() >= 1000, "{}: {}", g.name(), text.len());
        }
    }

    #[test]
    fn genres_are_statistically_distinct() {
        let prose = Corpus::new(Genre::Prose, 7).generate(3000);
        let code = Corpus::new(Genre::Code, 7).generate(3000);
        let tech = Corpus::new(Genre::Technical, 7).generate(3000);
        // code has newlines and defs; prose has none
        assert!(code.matches('\n').count() > 20);
        assert!(prose.matches('\n').count() == 0);
        assert!(code.contains("def "));
        // technical has digits and brackets far more often than prose
        let digits = |s: &str| s.chars().filter(|c| c.is_ascii_digit()).count();
        assert!(digits(&tech) > digits(&prose) * 2 + 10);
    }

    #[test]
    fn zipf_head_is_heavy() {
        let mut c = Corpus::new(Genre::Prose, 9);
        let mut counts = vec![0usize; 100];
        for _ in 0..10_000 {
            counts[c.zipf(100)] += 1;
        }
        assert!(counts[0] > counts[50] * 5);
        assert!(counts[0] > counts[10]);
    }

    #[test]
    fn prose_has_sentences() {
        let text = Corpus::new(Genre::Prose, 11).generate(800);
        assert!(text.matches('.').count() > 5);
        // vocabulary is bounded
        let words: HashSet<&str> = text
            .split_whitespace()
            .map(|w| w.trim_end_matches('.'))
            .collect();
        assert!(words.len() <= PROSE_STEMS.len() * 2 + 5);
    }
}
