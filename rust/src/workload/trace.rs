//! Serving request traces: Poisson arrivals with configurable prompt /
//! generation length distributions. Drives the coordinator benches and
//! the end-to-end `examples/serve.rs` driver.

use super::corpus::{Corpus, Genre};
use crate::util::rng::Pcg32;

/// One request in a trace.
#[derive(Clone, Debug)]
pub struct RequestSpec {
    pub id: u64,
    /// arrival offset from trace start, seconds
    pub arrival_s: f64,
    pub genre: Genre,
    pub prompt: String,
    /// tokens to generate
    pub gen_tokens: usize,
}

/// Trace shape parameters.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// mean arrival rate, requests/second (Poisson)
    pub rate: f64,
    pub num_requests: usize,
    /// prompt length bounds in characters
    pub prompt_chars: (usize, usize),
    /// generation length bounds in tokens
    pub gen_tokens: (usize, usize),
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            rate: 4.0,
            num_requests: 32,
            prompt_chars: (200, 800),
            gen_tokens: (8, 64),
            seed: 0x7ACE,
        }
    }
}

/// Generates deterministic request traces.
pub struct TraceGenerator {
    cfg: TraceConfig,
    rng: Pcg32,
    next_id: u64,
    clock_s: f64,
}

impl TraceGenerator {
    /// Panics on degenerate bounds (`hi < lo`): `next_request` samples
    /// `lo + bounded(hi - lo + 1)`, which would underflow in debug and
    /// produce a garbage bound in release — fail loudly at
    /// construction instead.
    pub fn new(cfg: TraceConfig) -> Self {
        assert!(
            cfg.prompt_chars.1 >= cfg.prompt_chars.0,
            "prompt_chars bounds inverted: ({}, {})",
            cfg.prompt_chars.0,
            cfg.prompt_chars.1
        );
        assert!(
            cfg.gen_tokens.1 >= cfg.gen_tokens.0,
            "gen_tokens bounds inverted: ({}, {})",
            cfg.gen_tokens.0,
            cfg.gen_tokens.1
        );
        let rng = Pcg32::seed(cfg.seed);
        Self { cfg, rng, next_id: 0, clock_s: 0.0 }
    }

    /// Generate the full trace, sorted by arrival time.
    pub fn generate(&mut self) -> Vec<RequestSpec> {
        (0..self.cfg.num_requests).map(|_| self.next_request()).collect()
    }

    /// Generate the next request (arrivals are cumulative exponential
    /// inter-arrival gaps — a Poisson process).
    pub fn next_request(&mut self) -> RequestSpec {
        let gap = self.rng.next_exp(self.cfg.rate);
        self.clock_s += gap;
        let id = self.next_id;
        self.next_id += 1;
        let genre = *[Genre::Prose, Genre::Code, Genre::Technical]
            .get(self.rng.next_bounded(3) as usize)
            .unwrap();
        let (lo, hi) = self.cfg.prompt_chars;
        let chars = lo + self.rng.next_bounded((hi - lo + 1) as u32) as usize;
        let prompt = Corpus::new(genre, self.cfg.seed ^ id).generate(chars);
        let (glo, ghi) = self.cfg.gen_tokens;
        let gen_tokens =
            glo + self.rng.next_bounded((ghi - glo + 1) as u32) as usize;
        RequestSpec { id, arrival_s: self.clock_s, genre, prompt, gen_tokens }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let cfg = TraceConfig { num_requests: 50, ..Default::default() };
        let a = TraceGenerator::new(cfg.clone()).generate();
        let b = TraceGenerator::new(cfg).generate();
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt, y.prompt);
        }
        for w in a.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
    }

    #[test]
    fn arrival_rate_approximately_matches() {
        let cfg = TraceConfig {
            rate: 10.0,
            num_requests: 2000,
            ..Default::default()
        };
        let trace = TraceGenerator::new(cfg).generate();
        let span = trace.last().unwrap().arrival_s;
        let measured = 2000.0 / span;
        assert!(
            (measured - 10.0).abs() < 1.0,
            "measured rate {measured}"
        );
    }

    #[test]
    fn lengths_respect_bounds() {
        let cfg = TraceConfig {
            prompt_chars: (100, 200),
            gen_tokens: (5, 9),
            num_requests: 64,
            ..Default::default()
        };
        for r in TraceGenerator::new(cfg).generate() {
            assert!(r.prompt.len() >= 100);
            assert!((5..=9).contains(&r.gen_tokens));
        }
    }

    #[test]
    fn ids_are_sequential() {
        let trace = TraceGenerator::new(TraceConfig {
            num_requests: 10,
            ..Default::default()
        })
        .generate();
        for (i, r) in trace.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    #[should_panic(expected = "prompt_chars bounds inverted")]
    fn inverted_prompt_bounds_panic_at_construction() {
        TraceGenerator::new(TraceConfig {
            prompt_chars: (200, 100),
            ..Default::default()
        });
    }

    #[test]
    #[should_panic(expected = "gen_tokens bounds inverted")]
    fn inverted_gen_bounds_panic_at_construction() {
        TraceGenerator::new(TraceConfig {
            gen_tokens: (64, 8),
            ..Default::default()
        });
    }

    #[test]
    fn genres_are_mixed() {
        let trace = TraceGenerator::new(TraceConfig {
            num_requests: 100,
            ..Default::default()
        })
        .generate();
        let n_prose = trace.iter().filter(|r| r.genre == Genre::Prose).count();
        let n_code = trace.iter().filter(|r| r.genre == Genre::Code).count();
        let n_tech =
            trace.iter().filter(|r| r.genre == Genre::Technical).count();
        assert!(n_prose > 10 && n_code > 10 && n_tech > 10);
    }
}
