//! Workload generation: synthetic text corpora with genre-specific token
//! statistics (substituting the paper's prose / code / technical samples
//! — see DESIGN.md) and Poisson request traces for the serving benches.

mod corpus;
mod trace;

pub use corpus::{Corpus, Genre};
pub use trace::{RequestSpec, TraceConfig, TraceGenerator};
