//! `lookat` — the leader binary: experiments, serving, and utilities.
//!
//! Subcommands:
//!
//! ```text
//! experiment <id>   regenerate a paper table/figure (table1..4,
//!                   figure3, figure4, efficiency, all)
//! serve             run the serving coordinator over a synthetic trace
//! stats <addr>      query a running serve-tcp server's telemetry
//! info              print artifact + platform info
//! ```
//!
//! Examples:
//!
//! ```text
//! lookat experiment table1
//! lookat serve --backend lookat-4 --requests 16 --rate 4
//! lookat serve-tcp --metrics-addr 127.0.0.1:9091 --trace-out t.json
//! lookat stats 127.0.0.1:7070 --interval 2
//! lookat info
//! ```

use lookat::coordinator::{
    AttentionBackend, BatcherConfig, CompressionPolicy, EngineConfig,
    Router, RouterConfig, SchedulerPolicy, ValueBackend,
};
use lookat::model::ModelConfig;
use lookat::util::cli::Cli;
use lookat::workload::{TraceConfig, TraceGenerator};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn parse_backend(s: &str) -> anyhow::Result<AttentionBackend> {
    Ok(match s {
        "fp16" => AttentionBackend::Fp16Exact,
        "int8" => AttentionBackend::ScalarQuant { bits: 8 },
        "int4" => AttentionBackend::ScalarQuant { bits: 4 },
        "pjrt-fp16" => AttentionBackend::PjrtFp16,
        other => {
            if let Some(spec) = other.strip_prefix("lookat-") {
                let (m, k) = parse_m_k(spec, "--backend")?;
                AttentionBackend::Lookat { m, k }
            } else if let Some(m) = other.strip_prefix("pjrt-lookat-") {
                AttentionBackend::PjrtLookat {
                    m: validate_m(m.parse()?, "--backend")?,
                }
            } else {
                anyhow::bail!(
                    "unknown backend '{other}' (fp16, int8, int4, \
                     lookat-<m>[-k<K>], pjrt-fp16, pjrt-lookat-<m>)"
                );
            }
        }
    })
}

/// `<m>` or `<m>-k<K>` — the PQ geometry spec shared by `--backend
/// lookat-…` and `--value-backend pq-…`. K defaults to the paper's 256;
/// `-k16` selects the nibble-packed 4-bit fast-scan mode. K is checked
/// here so a bad value is a usage error, not a training panic.
fn parse_m_k(spec: &str, flag: &str) -> anyhow::Result<(usize, usize)> {
    let (m_str, k) = match spec.split_once("-k") {
        Some((m_str, k_str)) => {
            let k: usize = k_str
                .parse()
                .map_err(|_| anyhow::anyhow!("{flag}: bad K '{k_str}'"))?;
            lookat::pq::validate_k(k)
                .map_err(|e| anyhow::anyhow!("{flag}: {e}"))?;
            (m_str, k)
        }
        None => (spec, 256),
    };
    Ok((validate_m(m_str.parse()?, flag)?, k))
}

/// Subspace counts the serving geometry (d_k = 64) supports — checked
/// at parse time so a bad `m` is a usage error, not a panic inside
/// codebook training.
fn validate_m(m: usize, flag: &str) -> anyhow::Result<usize> {
    if m == 0 || 64 % m != 0 {
        anyhow::bail!(
            "{flag}: m={m} must be a divisor of d_k=64 \
             (1, 2, 4, 8, 16, 32, 64)"
        );
    }
    Ok(m)
}

/// `on|off` switches (`--pipeline`, `--swap`, `--prefix-cache`): every
/// one is a bit-identical A/B toggle.
fn parse_on_off(flag: &str, s: &str) -> anyhow::Result<bool> {
    match s {
        "on" => Ok(true),
        "off" => Ok(false),
        other => anyhow::bail!("unknown --{flag} '{other}' (on, off)"),
    }
}

/// `--policy` spellings live in [`CompressionPolicy::parse`]; this
/// adapter only lifts its message into `anyhow`.
fn parse_policy(s: &str) -> anyhow::Result<CompressionPolicy> {
    CompressionPolicy::parse(s).map_err(|e| anyhow::anyhow!(e))
}

fn parse_scheduler(s: &str) -> anyhow::Result<SchedulerPolicy> {
    Ok(match s {
        "fcfs" => SchedulerPolicy::Fcfs,
        "preempt" => SchedulerPolicy::Preempt,
        other => anyhow::bail!(
            "unknown scheduler '{other}' (fcfs, preempt)"
        ),
    })
}

/// `--faults` spec (empty falls back to the `LOOKAT_FAULTS` env var;
/// both unset = injection disabled). Grammar and determinism live in
/// [`lookat::util::fault::FaultPlan`].
fn parse_faults(s: &str) -> anyhow::Result<lookat::util::fault::FaultPlan> {
    let cli = if s.is_empty() { None } else { Some(s) };
    lookat::util::fault::FaultPlan::resolve(cli)
}

/// `--timeout-ms` (0 = no server-side default deadline).
fn parse_timeout_ms(ms: u64) -> Option<u64> {
    if ms == 0 {
        None
    } else {
        Some(ms)
    }
}

fn parse_value_backend(s: &str) -> anyhow::Result<ValueBackend> {
    Ok(match s {
        "fp32" => ValueBackend::Fp32,
        other => {
            if let Some(spec) = other.strip_prefix("pq-") {
                let (m, k) = parse_m_k(spec, "--value-backend")?;
                ValueBackend::Pq { m, k }
            } else {
                anyhow::bail!(
                    "unknown value backend '{other}' (fp32, \
                     pq-<m>[-k<K>])"
                );
            }
        }
    })
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    match cmd.as_str() {
        "experiment" => {
            let cli = Cli::new("lookat experiment",
                               "regenerate a paper table/figure")
                .flag("quick", "CI-sized run")
                .positional("id", "table1..4 | figure3 | figure4 | \
                                   efficiency | all");
            let a = cli.parse(&args[1..])?;
            lookat::experiments::run(&a.positionals[0], a.get_flag("quick"))
        }
        "serve" => {
            let cli = Cli::new("lookat serve",
                               "serve a synthetic trace")
                .opt("backend", "lookat-4",
                     "fp16|int8|int4|lookat-<m>[-k<K>]|pjrt-fp16|\
                      pjrt-lookat-<m> (K=16 = 4-bit fast-scan)")
                .opt("value-backend", "fp32",
                     "fp32|pq-<m>[-k<K>] (PQ-coded values, fused decode)")
                .opt("requests", "16", "number of requests")
                .opt("rate", "4", "arrival rate, req/s")
                .opt("max-batch", "4", "max concurrent sequences")
                .opt("gen-tokens", "16", "max new tokens per request")
                .opt("layers", "2", "model depth")
                .opt("threads", "0", "decode worker threads (0 = auto)")
                .opt("prefill-chunk", "0",
                     "prefill chunk tokens (0 = monolithic)")
                .opt("scheduler", "fcfs",
                     "fcfs|preempt (preempt evicts under block pressure)")
                .opt("pipeline", "on",
                     "on|off: software-pipelined layer executor \
                      (bit-identical A/B)")
                .opt("swap", "on",
                     "on|off: spill preempted sequences to the swap \
                      tier instead of re-prefilling")
                .opt("prefix-cache", "on",
                     "on|off: share identical full prompt-prefix \
                      blocks copy-on-write across sequences")
                .opt("policy", "uniform",
                     "uniform|calibrated-<bits>|prune-<frac>: \
                      compression policy (per-(layer,head) subspace \
                      budgets / L2-norm token pruning)")
                .opt("trace-out", "",
                     "write a Chrome trace_event JSON of the run here \
                      (open in Perfetto; empty = disabled)")
                .opt("timeout-ms", "0",
                     "default per-request deadline in ms; past it the \
                      request expires and frees its blocks (0 = none)")
                .opt("faults", "",
                     "deterministic fault-injection plan, e.g. \
                      'seed:1,alloc:0.05,swap_in:err@3,tick_delay:20ms' \
                      (empty = LOOKAT_FAULTS env, unset = disabled)")
                .opt("seed", "7", "rng seed");
            let a = cli.parse(&args[1..])?;
            let backend = parse_backend(a.get("backend"))?;
            let value_backend =
                parse_value_backend(a.get("value-backend"))?;
            let policy = parse_scheduler(a.get("scheduler"))?;
            let compression = parse_policy(a.get("policy"))?;
            let pipeline = parse_on_off("pipeline", a.get("pipeline"))?;
            let swap = parse_on_off("swap", a.get("swap"))?;
            let prefix_cache =
                parse_on_off("prefix-cache", a.get("prefix-cache"))?;
            let trace_out = a.get("trace-out").to_string();
            let faults = parse_faults(a.get("faults"))?;
            let deadline_ms = parse_timeout_ms(a.get_u64("timeout-ms")?);
            let mut model = ModelConfig::gpt2_layer0();
            model.n_layer = a.get_usize("layers")?;
            let mut router = Router::build(RouterConfig {
                engine: EngineConfig {
                    model,
                    backend,
                    value_backend,
                    seed: a.get_u64("seed")?,
                    cache_blocks: 512,
                    calib_tokens: 256,
                    decode_threads: a.get_usize("threads")?,
                    prefill_chunk: a.get_usize("prefill-chunk")?,
                    pipeline,
                    prefix_cache,
                    policy: compression,
                    faults: faults.clone(),
                },
                batcher: BatcherConfig {
                    max_batch: a.get_usize("max-batch")?,
                    max_queue: 256,
                    policy,
                    swap,
                    deadline_ms,
                    faults,
                    ..BatcherConfig::default()
                },
                max_prompt_tokens: 120,
            })?;
            let trace = TraceGenerator::new(TraceConfig {
                rate: a.get_f64("rate")?,
                num_requests: a.get_usize("requests")?,
                prompt_chars: (100, 400),
                gen_tokens: (4, a.get_usize("gen-tokens")?.max(5)),
                seed: a.get_u64("seed")?,
            })
            .generate();
            let tracer = if trace_out.is_empty() {
                None
            } else {
                let t = std::sync::Arc::new(
                    lookat::telemetry::TraceRing::new(65536),
                );
                router.set_tracer(t.clone());
                Some(t)
            };
            let reqs = router.tokenize_trace(&trace);
            let report = router.serve_trace(reqs)?;
            println!("{}", report.pretty());
            if let Some(t) = tracer {
                std::fs::write(&trace_out, t.dump_chrome_json())?;
                println!("trace written to {trace_out}");
            }
            Ok(())
        }
        "serve-tcp" => {
            let cli = Cli::new("lookat serve-tcp",
                               "serve newline-JSON requests over TCP")
                .opt("backend", "lookat-4",
                     "attention backend (see `lookat serve`)")
                .opt("value-backend", "fp32", "fp32|pq-<m>[-k<K>]")
                .opt("addr", "127.0.0.1:7070", "bind address")
                .opt("max-batch", "4", "max concurrent sequences")
                .opt("layers", "2", "model depth")
                .opt("threads", "0", "decode worker threads (0 = auto)")
                .opt("prefill-chunk", "0",
                     "prefill chunk tokens (0 = monolithic)")
                .opt("scheduler", "fcfs",
                     "fcfs|preempt (preempt evicts under block pressure)")
                .opt("pipeline", "on",
                     "on|off: software-pipelined layer executor \
                      (bit-identical A/B)")
                .opt("swap", "on",
                     "on|off: spill preempted sequences to the swap \
                      tier instead of re-prefilling")
                .opt("prefix-cache", "on",
                     "on|off: share identical full prompt-prefix \
                      blocks copy-on-write across sequences")
                .opt("policy", "uniform",
                     "uniform|calibrated-<bits>|prune-<frac>: \
                      compression policy (per-(layer,head) subspace \
                      budgets / L2-norm token pruning)")
                .opt("metrics-addr", "",
                     "also serve Prometheus text metrics on this \
                      HOST:PORT (empty = disabled)")
                .opt("trace-out", "",
                     "enable per-request tracing; Chrome trace_event \
                      JSON written here on shutdown and served by the \
                      trace-dump verb (empty = disabled)")
                .opt("timeout-ms", "0",
                     "default per-request deadline in ms for requests \
                      without their own \"timeout_ms\"; past it the \
                      request is answered {\"error\": \"deadline\"} \
                      (0 = none)")
                .opt("faults", "",
                     "deterministic fault-injection plan, e.g. \
                      'seed:1,alloc:0.05,swap_in:err@3,tick_delay:20ms' \
                      (empty = LOOKAT_FAULTS env, unset = disabled)")
                .opt("seed", "7", "rng seed");
            let a = cli.parse(&args[1..])?;
            let backend = parse_backend(a.get("backend"))?;
            let value_backend =
                parse_value_backend(a.get("value-backend"))?;
            let policy = parse_scheduler(a.get("scheduler"))?;
            let compression = parse_policy(a.get("policy"))?;
            let pipeline = parse_on_off("pipeline", a.get("pipeline"))?;
            let swap = parse_on_off("swap", a.get("swap"))?;
            let prefix_cache =
                parse_on_off("prefix-cache", a.get("prefix-cache"))?;
            let opt_str = |s: &str| {
                if s.is_empty() {
                    None
                } else {
                    Some(s.to_string())
                }
            };
            let metrics_addr = opt_str(a.get("metrics-addr"));
            let trace_out = opt_str(a.get("trace-out"));
            let faults = parse_faults(a.get("faults"))?;
            let deadline_ms = parse_timeout_ms(a.get_u64("timeout-ms")?);
            let mut model = ModelConfig::gpt2_layer0();
            model.n_layer = a.get_usize("layers")?;
            let server = lookat::coordinator::Server::start(
                lookat::coordinator::ServerConfig {
                    engine: EngineConfig {
                        model,
                        backend,
                        value_backend,
                        seed: a.get_u64("seed")?,
                        cache_blocks: 512,
                        calib_tokens: 256,
                        decode_threads: a.get_usize("threads")?,
                        prefill_chunk: a.get_usize("prefill-chunk")?,
                        pipeline,
                        prefix_cache,
                        policy: compression,
                        faults: faults.clone(),
                    },
                    batcher: BatcherConfig {
                        max_batch: a.get_usize("max-batch")?,
                        max_queue: 256,
                        policy,
                        swap,
                        deadline_ms,
                        faults,
                        ..BatcherConfig::default()
                    },
                    max_prompt_tokens: 120,
                    addr: a.get("addr").to_string(),
                    metrics_addr,
                    trace_out,
                },
            )?;
            println!("listening on {}", server.local_addr);
            if let Some(m) = server.metrics_addr {
                println!("prometheus metrics on http://{m}/metrics");
            }
            println!(
                "protocol: one JSON per line, e.g. \
                 {{\"prompt\": \"hi\", \"max_new_tokens\": 8, \
                 \"timeout_ms\": 5000}}; \
                 control verbs: {{\"cmd\": \"stats\"}}, \
                 {{\"cmd\": \"trace-dump\"}}, {{\"cmd\": \"drain\"}}"
            );
            // serve until killed or drained over the wire
            server.wait();
            println!("drained; exiting");
            Ok(())
        }
        "stats" => {
            let cli = Cli::new("lookat stats",
                               "query a serve-tcp server's telemetry")
                .opt("interval", "0",
                     "poll every N seconds, printing throughput deltas \
                      (0 = print once and exit)")
                .positional("addr", "server address, e.g. 127.0.0.1:7070");
            let a = cli.parse(&args[1..])?;
            let addr = a.positionals[0].clone();
            let interval = a.get_f64("interval")?;
            let mut prev: Option<lookat::util::json::Json> = None;
            loop {
                let snap = fetch_stats(&addr)?;
                print_stats(&snap, prev.as_ref());
                if interval <= 0.0 {
                    break;
                }
                prev = Some(snap);
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    interval,
                ));
            }
            Ok(())
        }
        "bench-check" => {
            let cli = Cli::new(
                "lookat bench-check",
                "fail on BENCH_serving.json tokens/s regressions",
            )
            .opt_required("old", "previous BENCH_serving.json (baseline)")
            .opt_required("new", "current BENCH_serving.json")
            .opt("max-regress", "0.10",
                 "fractional tokens/s drop that fails (0.10 = 10%)");
            let a = cli.parse(&args[1..])?;
            let tol = a.get_f64("max-regress")?;
            let read =
                |path: &str| -> anyhow::Result<lookat::util::json::Json> {
                    let text = std::fs::read_to_string(path)
                        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                    lookat::util::json::Json::parse(&text)
                        .map_err(|e| anyhow::anyhow!("{path}: {e}"))
                };
            let old = read(a.get("old"))?;
            let new = read(a.get("new"))?;
            let regs = lookat::util::benchcmp::compare(&old, &new, tol)
                .map_err(|e| anyhow::anyhow!("bench-check: {e}"))?;
            if regs.is_empty() {
                println!(
                    "bench-check: no backend regressed beyond {:.0}%",
                    tol * 100.0
                );
                Ok(())
            } else {
                for r in &regs {
                    eprintln!("REGRESSION: {r}");
                }
                anyhow::bail!(
                    "{} tokens/s regression(s) beyond {:.0}%",
                    regs.len(),
                    tol * 100.0
                );
            }
        }
        "info" => {
            let dir = lookat::runtime::default_artifacts_dir();
            println!("artifacts dir: {}", dir.display());
            if dir.join("manifest.json").exists() {
                let rt = lookat::runtime::Runtime::open(&dir)?;
                println!("platform: {}", rt.platform());
                println!("artifacts ({}):", rt.manifest.artifacts.len());
                for a in &rt.manifest.artifacts {
                    println!(
                        "  {:30} kind={:12} L={:?} m={:?}",
                        a.name,
                        a.kind(),
                        a.meta_usize("L"),
                        a.meta_usize("m")
                    );
                }
            } else {
                println!("artifacts not built — run `make artifacts`");
            }
            Ok(())
        }
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try --help)"),
    }
}

/// One stats round trip over the line protocol.
fn fetch_stats(addr: &str) -> anyhow::Result<lookat::util::json::Json> {
    use std::io::{BufRead, BufReader, Write};
    let mut s = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    writeln!(s, "{{\"cmd\": \"stats\"}}")?;
    s.flush()?;
    let mut reader = BufReader::new(s);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    lookat::util::json::Json::parse(line.trim())
        .map_err(|e| anyhow::anyhow!("bad stats response: {e}"))
}

/// Render a stats snapshot; with a previous snapshot, also print
/// throughput rates over the elapsed window.
fn print_stats(
    snap: &lookat::util::json::Json,
    prev: Option<&lookat::util::json::Json>,
) {
    use lookat::util::json::Json;
    let num = |block: &str, key: &str| -> f64 {
        snap.get(block)
            .and_then(|b| b.get(key))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    let pct = |name: &str, q: &str| -> String {
        snap.get("histograms")
            .and_then(|h| h.get(name))
            .and_then(|h| h.get(q))
            .and_then(Json::as_f64)
            .map_or_else(|| "n/a".into(), |v| format!("{:.1}ms", v * 1e3))
    };
    let uptime = snap
        .get("uptime_s")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    println!("uptime   {uptime:.1}s");
    println!(
        "requests submitted={} completed={} rejected={} queue={} \
         active={} preempt={} swap={}/{} prefix_hits={}",
        num("counters", "requests_submitted"),
        num("counters", "requests_completed"),
        num("counters", "requests_rejected"),
        num("gauges", "queue_depth"),
        num("gauges", "active_seqs"),
        num("counters", "preemptions"),
        num("counters", "swap_outs"),
        num("counters", "swap_ins"),
        num("counters", "prefix_hits"),
    );
    println!(
        "tokens   decode={} prefill={} ticks={} scan_bytes={:.3e}",
        num("counters", "decode_tokens"),
        num("counters", "prefill_tokens"),
        num("counters", "ticks"),
        num("counters", "scan_bytes"),
    );
    println!(
        "cache    blocks={}/{} free={} shared={} key_bytes={:.3e} \
         value_bytes={:.3e} swapped_seqs={} swap_bytes={:.3e}",
        num("gauges", "blocks_used"),
        num("gauges", "blocks_total"),
        num("gauges", "blocks_free"),
        num("gauges", "shared_blocks"),
        num("gauges", "key_cache_bytes"),
        num("gauges", "value_cache_bytes"),
        num("gauges", "swapped_seqs"),
        num("gauges", "swap_resident_bytes"),
    );
    println!(
        "scratch  leases={} fresh={} zeroed={} held_bytes={:.3e} \
         peak_bytes={:.3e}",
        num("gauges", "scratch_leases"),
        num("gauges", "scratch_fresh"),
        num("gauges", "scratch_zeroed"),
        num("gauges", "scratch_held_bytes"),
        num("gauges", "scratch_peak_bytes"),
    );
    println!(
        "latency  ttft p50={}/p90={}/p99={}  itl p50={}/p99={}  \
         tick p50={}/p99={}",
        pct("ttft_s", "p50"),
        pct("ttft_s", "p90"),
        pct("ttft_s", "p99"),
        pct("itl_s", "p50"),
        pct("itl_s", "p99"),
        pct("tick_s", "p50"),
        pct("tick_s", "p99"),
    );
    if let Some(prev) = prev {
        let pnum = |block: &str, key: &str| -> f64 {
            prev.get(block)
                .and_then(|b| b.get(key))
                .and_then(Json::as_f64)
                .unwrap_or(0.0)
        };
        let dt = (uptime
            - prev
                .get("uptime_s")
                .and_then(Json::as_f64)
                .unwrap_or(0.0))
        .max(1e-9);
        println!(
            "rates    decode_tok/s={:.1} scan_bytes/s={:.3e} \
             ticks/s={:.1}",
            (num("counters", "decode_tokens")
                - pnum("counters", "decode_tokens"))
                / dt,
            (num("counters", "scan_bytes")
                - pnum("counters", "scan_bytes"))
                / dt,
            (num("counters", "ticks") - pnum("counters", "ticks")) / dt,
        );
    }
    println!();
}

fn print_usage() {
    println!(
        "lookat — LOOKAT paper reproduction (PQ+ADC KV-cache compression)

USAGE:
  lookat experiment <id> [--quick]   regenerate table1..4 / figure3 /
                                     figure4 / efficiency / all
  lookat serve [--backend B] [--value-backend V] [--requests N]
               [--rate R] [--prefill-chunk T] [--scheduler fcfs|preempt]
               [--pipeline on|off] [--swap on|off] [--prefix-cache on|off]
               [--policy uniform|calibrated-<bits>|prune-<frac>]
               [--trace-out FILE] [--timeout-ms MS] [--faults SPEC]
  lookat serve-tcp [--backend B] [--value-backend V] [--addr HOST:PORT]
                   [--prefill-chunk T] [--scheduler fcfs|preempt]
                   [--pipeline on|off] [--swap on|off]
                   [--prefix-cache on|off]
                   [--policy uniform|calibrated-<bits>|prune-<frac>]
                   [--metrics-addr HOST:PORT] [--trace-out FILE]
                   [--timeout-ms MS] [--faults SPEC]
      SPEC example: 'seed:1,alloc:0.05,swap_in:err@3,tick_delay:20ms'
      (also read from LOOKAT_FAULTS when the flag is absent)
  lookat stats <addr> [--interval S]   query a serve-tcp server's
                                       telemetry (counters, gauges,
                                       latency percentiles)
  lookat bench-check --old PREV.json --new CUR.json [--max-regress F]
  lookat info"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_specs_parse_with_and_without_k() {
        assert_eq!(
            parse_backend("lookat-4").unwrap(),
            AttentionBackend::Lookat { m: 4, k: 256 }
        );
        assert_eq!(
            parse_backend("lookat-8-k16").unwrap(),
            AttentionBackend::Lookat { m: 8, k: 16 }
        );
        assert_eq!(
            parse_value_backend("pq-8-k16").unwrap(),
            ValueBackend::Pq { m: 8, k: 16 }
        );
        assert_eq!(
            parse_value_backend("pq-4").unwrap(),
            ValueBackend::Pq { m: 4, k: 256 }
        );
    }

    #[test]
    fn bad_backend_specs_are_usage_errors() {
        // K outside 2..=256 or non-power-of-two fails at parse, not
        // inside codebook training
        assert!(parse_backend("lookat-4-k7").is_err());
        assert!(parse_backend("lookat-4-k512").is_err());
        assert!(parse_backend("lookat-4-k0").is_err());
        assert!(parse_backend("lookat-5").is_err());
        assert!(parse_value_backend("pq-4-kx").is_err());
    }

    #[test]
    fn on_off_errors_name_the_flag_and_accepted_values() {
        // a typo'd A/B switch must say WHICH flag broke and what it
        // takes, not a generic parse failure
        for flag in ["pipeline", "swap", "prefix-cache"] {
            assert!(parse_on_off(flag, "on").unwrap());
            assert!(!parse_on_off(flag, "off").unwrap());
            let err =
                parse_on_off(flag, "yes").unwrap_err().to_string();
            assert!(
                err.contains(&format!("--{flag}")),
                "error does not name --{flag}: {err}"
            );
            assert!(err.contains("'yes'"), "missing offending value: {err}");
            assert!(
                err.contains("on") && err.contains("off"),
                "missing accepted values: {err}"
            );
        }
    }

    #[test]
    fn faults_and_timeout_flags_parse() {
        let plan = parse_faults("seed:1,alloc:0.5,tick:err@2").unwrap();
        assert!(plan.is_active());
        let err = parse_faults("alloc:bogus").unwrap_err();
        assert!(
            format!("{err:#}").contains("--faults"),
            "error does not name the flag: {err:#}"
        );
        assert_eq!(parse_timeout_ms(0), None);
        assert_eq!(parse_timeout_ms(250), Some(250));
    }

    #[test]
    fn policy_specs_parse_and_reject_garbage() {
        assert_eq!(
            parse_policy("uniform").unwrap(),
            CompressionPolicy::Uniform
        );
        assert_eq!(
            parse_policy("calibrated-384").unwrap(),
            CompressionPolicy::Calibrated { bits: 384 }
        );
        assert_eq!(
            parse_policy("prune-0.25").unwrap(),
            CompressionPolicy::Prune { frac: 0.25 }
        );
        let err = parse_policy("smallest").unwrap_err().to_string();
        assert!(err.contains("--policy"), "{err}");
        assert!(err.contains("prune-<frac>"), "{err}");
    }
}
