//! Attention decode-step implementations: the FP16 oracle, the LOOKAT
//! ADC path (paper Algorithm 1), and scalar-quantized baselines.
//!
//! The free functions here are single-head, single-query (decode-step)
//! primitives; [`kernel`] wraps them in the batched `AttentionKernel`
//! backends the serving engine fans out over (seq, head) work items.
//! Shapes follow the paper: `q` is (d_k), the cache holds `n`
//! keys/values of dimension d_k.
//!
//! Bit-parity contract: the batched kernels are *definitionally* equal
//! to these primitives — same score math, same softmax, same subspace
//! accumulation order (`0..m`), same block iteration order — so a
//! batched decode over paged cache blocks must produce the identical
//! f32 bits as the flat single-query call (`tests/decode_parity.rs`
//! enforces it per backend). Causal masking is expressed as a per-row
//! key-prefix length, either derived from the span geometry or carried
//! explicitly on the work item ([`WorkItem::prefixes`], used when
//! token pruning makes logical positions diverge from stored rows).

pub mod kernel;

pub use kernel::{AttentionKernel, DecodePlan, WorkItem};

use crate::kvcache::BlockView;
use crate::pq::{LookupTable, PqCodec};
use crate::quant;
use crate::tensor::{dot, softmax_inplace};

/// Output of one attention step: the context vector and the attention
/// distribution (kept for the §4.2 metrics).
#[derive(Clone, Debug)]
pub struct AttnOutput {
    pub out: Vec<f32>,
    pub weights: Vec<f32>,
}

/// Exact FP16-storage attention (paper's baseline): scores by full dot
/// products, softmax(s/√d_k), weighted value sum.
pub fn exact_attention(q: &[f32], keys: &[f32], values: &[f32], n: usize)
    -> AttnOutput
{
    let d_k = q.len();
    assert_eq!(keys.len(), n * d_k);
    assert_eq!(values.len(), n * d_k);
    let scores: Vec<f32> = (0..n)
        .map(|l| dot(q, &keys[l * d_k..(l + 1) * d_k]))
        .collect();
    finish_attention(scores, values, d_k)
}

/// LOOKAT attention (Algorithm 1): LUT build + ADC scan; keys exist only
/// as PQ codes. `codes` is (n × m) row-major u8.
pub fn lookat_attention(
    q: &[f32],
    codes: &[u8],
    codec: &PqCodec,
    values: &[f32],
    n: usize,
) -> AttnOutput {
    let d_k = q.len();
    assert_eq!(values.len(), n * d_k);
    let lut = LookupTable::build(q, &codec.codebook);
    let scores = lut.scores(codes, n);
    finish_attention(scores, values, d_k)
}

/// LOOKAT attention with a pre-built LUT (the serving hot path re-uses
/// tables across cache segments).
pub fn lookat_attention_with_lut(
    lut: &LookupTable,
    codes: &[u8],
    values: &[f32],
    n: usize,
    d_k: usize,
) -> AttnOutput {
    let scores = lut.scores(codes, n);
    finish_attention(scores, values, d_k)
}

/// Fully-compressed LOOKAT attention (paper §5.2 extension): keys *and*
/// values are PQ codes. Scores come from key-side ADC; the output comes
/// from [`crate::pq::values::weighted_decode`]'s transposed aggregation
/// — neither cache side is ever dequantized per-token.
pub fn lookat_kv_attention(
    q: &[f32],
    key_codes: &[u8],
    key_codec: &PqCodec,
    value_codes: &[u8],
    value_codec: &PqCodec,
    n: usize,
) -> AttnOutput {
    let d_k = q.len();
    let lut = LookupTable::build(q, &key_codec.codebook);
    let mut scores = lut.scores(key_codes, n);
    let inv = 1.0 / (d_k as f32).sqrt();
    for s in scores.iter_mut() {
        *s *= inv;
    }
    softmax_inplace(&mut scores);
    let out = crate::pq::values::weighted_decode(
        &scores, value_codes, value_codec);
    AttnOutput { out, weights: scores }
}

/// Scalar-quantized baseline: keys round-trip through INT`bits`
/// (dequantize-then-matmul, the bandwidth-bound path of paper §3.2).
pub fn scalar_quant_attention(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    n: usize,
    bits: u8,
) -> AttnOutput {
    let deq = quant::quant_roundtrip(keys, bits);
    exact_attention(q, &deq, values, n)
}

/// Shared tail: scale by 1/√d_k, softmax, α·V. Takes the scores buffer
/// by value and moves it into [`AttnOutput::weights`] — the hot path
/// allocates no copy of the distribution, and the context buffer is
/// leased from the thread pool's scratch arena so the serving loop can
/// recycle it once consumed.
pub(crate) fn finish_attention(
    mut scores: Vec<f32>,
    values: &[f32],
    d_k: usize,
) -> AttnOutput {
    let inv = 1.0 / (d_k as f32).sqrt();
    for s in scores.iter_mut() {
        *s *= inv;
    }
    softmax_inplace(&mut scores);
    let n = scores.len();
    let mut out = crate::util::threadpool::scratch().take_f32(d_k);
    for l in 0..n {
        let a = scores[l];
        if a > 0.0 {
            crate::tensor::axpy(&mut out, a, &values[l * d_k..(l + 1) * d_k]);
        }
    }
    AttnOutput { out, weights: scores }
}

/// Block-resident attention tail: softmax the raw scores, then
/// accumulate α·V straight from the paged cache's [`BlockView`]s — no
/// contiguous value gather. Token order (and therefore every float op)
/// matches [`finish_attention`] over the gathered equivalent, so the
/// two tails are bit-identical.
///
/// The blocks may carry *more* tokens than there are scores: a prefill
/// span's row `r` attends only its causal prefix, so the tail stops
/// after `scores.len()` tokens and ignores the rest of the stream.
pub fn finish_attention_blocks<'a>(
    mut scores: Vec<f32>,
    blocks: impl Iterator<Item = BlockView<'a>>,
    d_k: usize,
) -> AttnOutput {
    let inv = 1.0 / (d_k as f32).sqrt();
    for s in scores.iter_mut() {
        *s *= inv;
    }
    softmax_inplace(&mut scores);
    let mut out = crate::util::threadpool::scratch().take_f32(d_k);
    let mut l = 0usize;
    'blocks: for blk in blocks {
        for t in 0..blk.len {
            if l == scores.len() {
                break 'blocks;
            }
            let a = scores[l];
            if a > 0.0 {
                crate::tensor::axpy(
                    &mut out, a, &blk.values[t * d_k..(t + 1) * d_k]);
            }
            l += 1;
        }
    }
    debug_assert_eq!(l, scores.len(), "blocks/scores length mismatch");
    AttnOutput { out, weights: scores }
}

/// Fully-fused block-resident attention tail for PQ-coded values (the
/// §5.2 extension in the serving path): softmax the raw scores, then
/// scatter-accumulate the post-softmax weights into per-subspace (K,)
/// tables while streaming the cache's subspace-major value-code lanes,
/// finishing with one m × K × d_sub centroid matvec
/// ([`crate::pq::values::weighted_decode_lanes`]). Values are never
/// dequantized per token and never gathered — zero per-step value
/// copies. Per-cell accumulation order matches the flat path, so the
/// output is bit-identical to [`lookat_kv_attention`] over the
/// gathered codes. Like [`finish_attention_blocks`], the lane stream
/// may extend past `scores.len()` tokens (a prefill span row's causal
/// prefix); excess tokens are truncated by shrinking each lane's
/// claimed length. K ≤ 16 value codecs store nibble-packed lanes, so
/// the tail routes them through the packed decode variant — same
/// accumulation order, still bit-identical.
pub fn finish_attention_kv_blocks<'a>(
    mut scores: Vec<f32>,
    blocks: impl Iterator<Item = BlockView<'a>>,
    value_codec: &PqCodec,
    d_k: usize,
) -> AttnOutput {
    let inv = 1.0 / (d_k as f32).sqrt();
    for s in scores.iter_mut() {
        *s *= inv;
    }
    softmax_inplace(&mut scores);
    let mut left = scores.len();
    let lanes = blocks.filter_map(move |b| {
        if left == 0 {
            return None;
        }
        let take = b.len.min(left);
        left -= take;
        Some((b.value_codes, take))
    });
    let out = if value_codec.packed() {
        crate::pq::values::weighted_decode_lanes_packed(
            &scores, lanes, value_codec)
    } else {
        crate::pq::values::weighted_decode_lanes(&scores, lanes, value_codec)
    };
    AttnOutput { out, weights: scores }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::TrainOpts;
    use crate::util::rng::Pcg32;

    fn case(n: usize, d_k: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::seed(seed);
        let q = (0..d_k).map(|_| rng.next_f32_std()).collect();
        let keys = (0..n * d_k).map(|_| rng.next_f32_std()).collect();
        let values = (0..n * d_k).map(|_| rng.next_f32_std()).collect();
        (q, keys, values)
    }

    #[test]
    fn exact_attention_weights_sum_to_one() {
        let (q, keys, values) = case(100, 64, 1);
        let r = exact_attention(&q, &keys, &values, 100);
        let s: f32 = r.weights.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert_eq!(r.out.len(), 64);
    }

    #[test]
    fn single_key_attends_fully() {
        let (q, keys, values) = case(1, 16, 2);
        let r = exact_attention(&q, &keys, &values, 1);
        assert!((r.weights[0] - 1.0).abs() < 1e-6);
        for (o, v) in r.out.iter().zip(&values) {
            assert!((o - v).abs() < 1e-5);
        }
    }

    #[test]
    fn dominant_key_wins() {
        // craft a cache where key 3 is exactly q scaled up
        let d_k = 32;
        let (q, mut keys, values) = case(10, d_k, 3);
        for i in 0..d_k {
            keys[3 * d_k + i] = q[i] * 10.0;
        }
        let r = exact_attention(&q, &keys, &values, 10);
        let top = crate::metrics::top_k_indices(&r.weights, 1)[0];
        assert_eq!(top, 3);
    }

    #[test]
    fn lookat_matches_exact_on_reconstructed_keys() {
        // keys that coincide with their PQ reconstruction make ADC exact
        let d_k = 64;
        let n = 64;
        let (q, raw_keys, values) = case(n, d_k, 4);
        let codec = PqCodec::train(&raw_keys, d_k, 4, 32,
                                   &TrainOpts::default());
        let codes = codec.encode_batch(&raw_keys, n);
        // reconstruct: these are the keys LOOKAT "sees"
        let recon: Vec<f32> = (0..n)
            .flat_map(|l| codec.decode(&codes[l * 4..(l + 1) * 4]))
            .collect();
        let want = exact_attention(&q, &recon, &values, n);
        let got = lookat_attention(&q, &codes, &codec, &values, n);
        for (a, b) in want.out.iter().zip(&got.out) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in want.weights.iter().zip(&got.weights) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn lookat_high_fidelity_on_trained_codebook() {
        let d_k = 64;
        let n = 256;
        let (q, keys, values) = case(n, d_k, 5);
        let codec = PqCodec::train(&keys, d_k, 8, 256,
                                   &TrainOpts::default());
        let codes = codec.encode_batch(&keys, n);
        let exact = exact_attention(&q, &keys, &values, n);
        let approx = lookat_attention(&q, &codes, &codec, &values, n);
        let rep = crate::metrics::FidelityReport::compare(
            &exact.out, &approx.out, &exact.weights, &approx.weights);
        assert!(rep.cosine > 0.9, "cosine {}", rep.cosine);
        assert!(rep.spearman > 0.8, "spearman {}", rep.spearman);
    }

    #[test]
    fn with_lut_variant_matches_plain() {
        let d_k = 64;
        let n = 128;
        let (q, keys, values) = case(n, d_k, 6);
        let codec = PqCodec::train(&keys, d_k, 4, 64, &TrainOpts::default());
        let codes = codec.encode_batch(&keys, n);
        let a = lookat_attention(&q, &codes, &codec, &values, n);
        let lut = LookupTable::build(&q, &codec.codebook);
        let b = lookat_attention_with_lut(&lut, &codes, &values, n, d_k);
        assert_eq!(a.out, b.out);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn int8_baseline_nearly_exact() {
        let (q, keys, values) = case(200, 64, 7);
        let exact = exact_attention(&q, &keys, &values, 200);
        let int8 = scalar_quant_attention(&q, &keys, &values, 200, 8);
        let rep = crate::metrics::FidelityReport::compare(
            &exact.out, &int8.out, &exact.weights, &int8.weights);
        assert!(rep.cosine > 0.999, "cosine {}", rep.cosine);
        assert!(rep.spearman > 0.999);
    }

    #[test]
    fn int4_worse_than_int8() {
        let (q, keys, values) = case(200, 64, 8);
        let exact = exact_attention(&q, &keys, &values, 200);
        let r4 = scalar_quant_attention(&q, &keys, &values, 200, 4);
        let r8 = scalar_quant_attention(&q, &keys, &values, 200, 8);
        let f4 = crate::metrics::FidelityReport::compare(
            &exact.out, &r4.out, &exact.weights, &r4.weights);
        let f8 = crate::metrics::FidelityReport::compare(
            &exact.out, &r8.out, &exact.weights, &r8.weights);
        assert!(f8.cosine >= f4.cosine);
        assert!(f8.kl <= f4.kl + 1e-9);
    }

    #[test]
    fn kv_compressed_attention_tracks_exact() {
        let d_k = 64;
        let n = 256;
        let (q, keys, values) = case(n, d_k, 21);
        let kc = PqCodec::train(&keys, d_k, 8, 256, &TrainOpts::default());
        let vc = PqCodec::train(&values, d_k, 8, 256,
                                &TrainOpts::default());
        let key_codes = kc.encode_batch(&keys, n);
        let value_codes = vc.encode_batch(&values, n);
        let exact = exact_attention(&q, &keys, &values, n);
        let got = lookat_kv_attention(
            &q, &key_codes, &kc, &value_codes, &vc, n);
        let rep = crate::metrics::FidelityReport::compare(
            &exact.out, &got.out, &exact.weights, &got.weights);
        assert!(rep.cosine > 0.85, "cosine {}", rep.cosine);
        assert!(rep.spearman > 0.8, "spearman {}", rep.spearman);
    }

    #[test]
    fn kv_compressed_weights_match_key_only_path() {
        // value compression must not change the attention distribution
        let d_k = 32;
        let n = 100;
        let (q, keys, values) = case(n, d_k, 22);
        let kc = PqCodec::train(&keys, d_k, 4, 64, &TrainOpts::default());
        let vc = PqCodec::train(&values, d_k, 4, 64, &TrainOpts::default());
        let key_codes = kc.encode_batch(&keys, n);
        let value_codes = vc.encode_batch(&values, n);
        let key_only = lookat_attention(&q, &key_codes, &kc, &values, n);
        let kv = lookat_kv_attention(
            &q, &key_codes, &kc, &value_codes, &vc, n);
        assert_eq!(key_only.weights, kv.weights);
    }

    #[test]
    fn fused_kv_tail_bit_identical_to_primitive() {
        // finish_attention_kv_blocks over chunked value codes must equal
        // lookat_kv_attention over the flat equivalents, bit for bit
        let d_k = 32;
        let n = 100;
        let (q, keys, values) = case(n, d_k, 30);
        let kc = PqCodec::train(&keys, d_k, 4, 64, &TrainOpts::default());
        let vc = PqCodec::train(&values, d_k, 4, 64, &TrainOpts::default());
        let key_codes = kc.encode_batch(&keys, n);
        let value_codes = vc.encode_batch(&values, n);
        let want = lookat_kv_attention(
            &q, &key_codes, &kc, &value_codes, &vc, n);

        let lut = LookupTable::build(&q, &kc.codebook);
        let scores = lut.scores(&key_codes, n);
        for bt in [32usize, 48, 7] {
            // blocks expose subspace-major value-code lanes
            let lanes = crate::testkit::fixtures::interleave_lanes(
                &value_codes, 4, bt);
            let views = lanes.iter().map(|(lane, len)| BlockView {
                len: *len,
                keys: &[],
                codes: &[],
                values: &[],
                value_codes: &lane[..],
            });
            let got = finish_attention_kv_blocks(
                scores.clone(), views, &vc, d_k);
            assert_eq!(
                want.out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "block_tokens={bt}"
            );
            assert_eq!(want.weights, got.weights, "block_tokens={bt}");
        }
    }

    #[test]
    fn fused_kv_tail_packed_bit_identical_to_primitive() {
        // K = 16 value codecs nibble-pack their lanes; the fused tail
        // must still match the flat primitive bit for bit
        let d_k = 32;
        let n = 100;
        let (q, keys, values) = case(n, d_k, 31);
        let kc = PqCodec::train(&keys, d_k, 4, 64, &TrainOpts::default());
        let vc = PqCodec::train(&values, d_k, 8, 16, &TrainOpts::default());
        assert!(vc.packed());
        let key_codes = kc.encode_batch(&keys, n);
        let value_codes = vc.encode_batch(&values, n);
        let want = lookat_kv_attention(
            &q, &key_codes, &kc, &value_codes, &vc, n);

        let lut = LookupTable::build(&q, &kc.codebook);
        let scores = lut.scores(&key_codes, n);
        for bt in [32usize, 48, 6] {
            let lanes = crate::testkit::fixtures::interleave_lanes_packed(
                &value_codes, 8, bt);
            let views = lanes.iter().map(|(lane, len)| BlockView {
                len: *len,
                keys: &[],
                codes: &[],
                values: &[],
                value_codes: &lane[..],
            });
            let got = finish_attention_kv_blocks(
                scores.clone(), views, &vc, d_k);
            assert_eq!(
                want.out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "block_tokens={bt}"
            );
            assert_eq!(want.weights, got.weights, "block_tokens={bt}");
        }
    }

    #[test]
    fn softmax_invariant_under_score_shift() {
        // adding a constant to all scores must not change weights:
        // exercised via keys shifted along q's orthogonal complement
        let (q, keys, values) = case(50, 16, 9);
        let r1 = exact_attention(&q, &keys, &values, 50);
        // scale q by 2: ranks preserved, weights sharpen but order same
        let q2: Vec<f32> = q.iter().map(|x| x * 2.0).collect();
        let r2 = exact_attention(&q2, &keys, &values, 50);
        let i1 = crate::metrics::top_k_indices(&r1.weights, 1)[0];
        let i2 = crate::metrics::top_k_indices(&r2.weights, 1)[0];
        assert_eq!(i1, i2);
    }
}
